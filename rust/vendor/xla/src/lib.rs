//! Stub of the `xla` (PJRT C API) crate type surface used by blocksparse's
//! feature-gated `runtime` module.
//!
//! This container has no PJRT plugin and no registry access, so the real
//! crate cannot be built here. This stub keeps `--features pjrt` compiling
//! and type-checked; every operation fails at *runtime* with a clear
//! "PJRT support is stubbed" error. To run the AOT/HLO path for real,
//! replace this path dependency with the actual `xla` crate.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error {
    what: &'static str,
}

impl Error {
    fn stubbed(what: &'static str) -> Self {
        Error { what }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla::{}: PJRT support is stubbed in this build (vendor/xla); \
             swap in the real xla crate to execute HLO artifacts",
            self.what
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    U64,
    F32,
    F64,
}

/// Element types exchangeable with a `Literal`.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug, Default)]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal {}
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stubbed("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stubbed("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stubbed("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::stubbed("Literal::array_shape"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stubbed("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stubbed("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stubbed("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stubbed("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stubbed("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_stubbed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
        let e = Literal::default().array_shape().unwrap_err();
        assert!(e.to_string().contains("stubbed"));
    }
}
