//! Minimal, dependency-free substitute for the `anyhow` crate, vendored so
//! the workspace builds with no registry access. Implements exactly the
//! surface this repo uses: `Error`, `Result`, `anyhow!`, `bail!`, and the
//! `Context` extension trait for `Result`/`Option`.
//!
//! Semantics match `anyhow` where it matters here:
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   `Error` via `?`;
//! * `context`/`with_context` prepend a message to the cause chain;
//! * `{e}` prints the outermost message, `{e:#}` prints the whole chain
//!   separated by `": "`.

use std::fmt;

/// Error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (outermost position).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `context`/`with_context` to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt", args...)` — construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", args...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading config".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("absent").unwrap_err()), "absent");
    }
}
