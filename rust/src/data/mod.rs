//! Data pipeline substrate: in-memory datasets, shuffled batch iteration,
//! and assembly into backend-agnostic `HostValue` batches (the PJRT
//! backend converts them to literals at its own boundary).
//!
//! No torchvision / no network in this environment: `synth` generates
//! MNIST-like and CIFAR-like classification data with class structure
//! (DESIGN.md §5 substitution), `idx` reads real MNIST IDX files when the
//! user drops them under `data/`, and `corpus` synthesizes a Markov byte
//! stream for the LM end-to-end example.

pub mod corpus;
pub mod idx;
pub mod synth;

use anyhow::{bail, Result};

use crate::tensor::{HostValue, Tensor};
use crate::util::rng::Rng;

/// A supervised dataset held in host memory.
///
/// `x` is row-major (n × features) f32 for images, or (n × seq) i32 token
/// ids for LMs (stored in `tokens`). `y` is the per-example class id, or
/// per-position targets for LMs (stored in `targets`).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: usize,
    pub classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub n: usize,
    pub is_tokens: bool,
}

impl Dataset {
    pub fn from_images(features: usize, classes: usize, x: Vec<f32>, y: Vec<i32>) -> Result<Self> {
        if x.len() % features != 0 || x.len() / features != y.len() {
            bail!("inconsistent dataset dims");
        }
        let n = y.len();
        Ok(Self { features, classes, x, y, tokens: vec![], targets: vec![], n, is_tokens: false })
    }

    pub fn from_tokens(seq: usize, vocab: usize, tokens: Vec<i32>, targets: Vec<i32>) -> Result<Self> {
        if tokens.len() != targets.len() || tokens.len() % seq != 0 {
            bail!("inconsistent token dataset dims");
        }
        let n = tokens.len() / seq;
        Ok(Self {
            features: seq,
            classes: vocab,
            x: vec![],
            y: vec![],
            tokens,
            targets,
            n,
            is_tokens: true,
        })
    }

    /// Split off the last `k` examples as a held-out set.
    pub fn split(mut self, k: usize) -> (Dataset, Dataset) {
        assert!(k < self.n);
        let train_n = self.n - k;
        let test = if self.is_tokens {
            let seq = self.features;
            Dataset {
                features: seq,
                classes: self.classes,
                x: vec![],
                y: vec![],
                tokens: self.tokens.split_off(train_n * seq),
                targets: self.targets.split_off(train_n * seq),
                n: k,
                is_tokens: true,
            }
        } else {
            Dataset {
                features: self.features,
                classes: self.classes,
                x: self.x.split_off(train_n * self.features),
                y: self.y.split_off(train_n),
                tokens: vec![],
                targets: vec![],
                n: k,
                is_tokens: false,
            }
        };
        self.n = train_n;
        (self, test)
    }
}

/// A materialized batch ready for any `backend::Backend`.
pub struct Batch {
    pub x: HostValue,
    pub y: HostValue,
    pub size: usize,
}

/// Shuffling batch iterator. Batch size is static (baked into the AOT
/// executables), so the trailing remainder of each epoch is dropped —
/// standard drop_last=True semantics.
pub struct Batcher<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    shuffle: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64, shuffle: bool) -> Self {
        assert!(batch <= data.n, "batch {} > dataset {}", batch, data.n);
        let mut order: Vec<usize> = (0..data.n).collect();
        let mut rng = Rng::new(seed);
        if shuffle {
            rng.shuffle(&mut order);
        }
        Self { data, batch, order, cursor: 0, rng, shuffle }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.data.n / self.batch
    }

    /// Next batch, re-shuffling at epoch boundaries.
    pub fn next_batch(&mut self) -> Result<Batch> {
        if self.cursor + self.batch > self.order.len() {
            self.cursor = 0;
            if self.shuffle {
                self.rng.shuffle(&mut self.order);
            }
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        assemble_batch(self.data, idx)
    }
}

/// Gather rows `idx` into one host-value batch.
pub fn assemble_batch(data: &Dataset, idx: &[usize]) -> Result<Batch> {
    let b = idx.len();
    if data.is_tokens {
        let seq = data.features;
        let mut xs = Vec::with_capacity(b * seq);
        let mut ys = Vec::with_capacity(b * seq);
        for &i in idx {
            xs.extend_from_slice(&data.tokens[i * seq..(i + 1) * seq]);
            ys.extend_from_slice(&data.targets[i * seq..(i + 1) * seq]);
        }
        let x = HostValue::I32 { shape: vec![b, seq], data: xs };
        let y = HostValue::I32 { shape: vec![b, seq], data: ys };
        Ok(Batch { x, y, size: b })
    } else {
        let f = data.features;
        let mut xs = Vec::with_capacity(b * f);
        let mut ys = Vec::with_capacity(b);
        for &i in idx {
            xs.extend_from_slice(&data.x[i * f..(i + 1) * f]);
            ys.push(data.y[i]);
        }
        let x = HostValue::F32(Tensor::new(&[b, f], xs)?);
        let y = HostValue::I32 { shape: vec![b], data: ys };
        Ok(Batch { x, y, size: b })
    }
}

/// Sequential (non-shuffled) full sweep for evaluation.
///
/// With `include_tail`, a final partial batch covers the `n % batch`
/// remainder so no test example is silently dropped. Backends whose
/// executables are compiled for one exact batch size (AOT/PJRT) pass
/// `false` and keep the historical full-batches-only sweep.
pub fn eval_batches(data: &Dataset, batch: usize, include_tail: bool) -> Vec<Vec<usize>> {
    let full = data.n / batch;
    let mut out: Vec<Vec<usize>> =
        (0..full).map(|b| (b * batch..(b + 1) * batch).collect()).collect();
    if include_tail && data.n % batch != 0 {
        out.push((full * batch..data.n).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let y: Vec<i32> = (0..10).map(|i| i % 3).collect();
        Dataset::from_images(4, 3, x, y).unwrap()
    }

    #[test]
    fn batcher_covers_epoch_without_repeats() {
        let d = tiny();
        let mut b = Batcher::new(&d, 2, 1, true);
        assert_eq!(b.batches_per_epoch(), 5);
        // one epoch = 5 batches of 2: each index exactly once
        let mut seen = vec![0usize; 10];
        for _ in 0..5 {
            let batch = b.next_batch().unwrap();
            let ys = batch.y.i32_data().unwrap();
            assert_eq!(ys.len(), 2);
            let xs = batch.x.as_f32().unwrap();
            assert_eq!(xs.shape(), &[2, 4]);
            for chunk in xs.data().chunks(4) {
                seen[(chunk[0] / 4.0) as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn split_sizes() {
        let d = tiny();
        let (tr, te) = d.split(3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.y.len(), 3);
    }

    #[test]
    fn token_batches() {
        let tokens: Vec<i32> = (0..24).collect();
        let targets: Vec<i32> = (1..25).collect();
        let d = Dataset::from_tokens(6, 32, tokens, targets).unwrap();
        assert_eq!(d.n, 4);
        let b = assemble_batch(&d, &[1, 3]).unwrap();
        assert_eq!(b.x.i32_data().unwrap()[0], 6);
        assert_eq!(b.y.i32_data().unwrap()[0], 7);
        assert_eq!(b.x.shape(), &[2, 6]);
    }

    #[test]
    fn eval_batch_indices() {
        let d = tiny(); // n = 10
        let bs = eval_batches(&d, 4, false);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[1], vec![4, 5, 6, 7]);
        // with the tail, the 10 % 4 = 2 remainder examples are covered too
        let bs = eval_batches(&d, 4, true);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[2], vec![8, 9]);
        assert_eq!(bs.iter().map(Vec::len).sum::<usize>(), d.n);
        // no empty tail when batch divides n
        assert_eq!(eval_batches(&d, 5, true).len(), 2);
    }
}
