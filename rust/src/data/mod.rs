//! Data pipeline substrate: in-memory datasets, shuffled batch iteration,
//! and assembly into backend-agnostic `HostValue` batches (the PJRT
//! backend converts them to literals at its own boundary).
//!
//! No torchvision / no network in this environment: `synth` generates
//! MNIST-like and CIFAR-like classification data with class structure
//! (DESIGN.md §5 substitution), `idx` reads real MNIST IDX files when the
//! user drops them under `data/`, and `corpus` synthesizes a Markov byte
//! stream for the LM end-to-end example.

pub mod corpus;
pub mod idx;
pub mod synth;

use anyhow::{bail, Result};

use crate::tensor::{HostValue, Tensor};
use crate::util::rng::Rng;

/// A supervised dataset held in host memory.
///
/// `x` is row-major (n × features) f32 for images, or (n × seq) i32 token
/// ids for LMs (stored in `tokens`). `y` is the per-example class id, or
/// per-position targets for LMs (stored in `targets`).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: usize,
    pub classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub n: usize,
    pub is_tokens: bool,
}

impl Dataset {
    pub fn from_images(features: usize, classes: usize, x: Vec<f32>, y: Vec<i32>) -> Result<Self> {
        if x.len() % features != 0 || x.len() / features != y.len() {
            bail!("inconsistent dataset dims");
        }
        let n = y.len();
        Ok(Self { features, classes, x, y, tokens: vec![], targets: vec![], n, is_tokens: false })
    }

    pub fn from_tokens(seq: usize, vocab: usize, tokens: Vec<i32>, targets: Vec<i32>) -> Result<Self> {
        if tokens.len() != targets.len() || tokens.len() % seq != 0 {
            bail!("inconsistent token dataset dims");
        }
        let n = tokens.len() / seq;
        Ok(Self {
            features: seq,
            classes: vocab,
            x: vec![],
            y: vec![],
            tokens,
            targets,
            n,
            is_tokens: true,
        })
    }

    /// Split off the last `k` examples as a held-out set.
    pub fn split(mut self, k: usize) -> (Dataset, Dataset) {
        assert!(k < self.n);
        let train_n = self.n - k;
        let test = if self.is_tokens {
            let seq = self.features;
            Dataset {
                features: seq,
                classes: self.classes,
                x: vec![],
                y: vec![],
                tokens: self.tokens.split_off(train_n * seq),
                targets: self.targets.split_off(train_n * seq),
                n: k,
                is_tokens: true,
            }
        } else {
            Dataset {
                features: self.features,
                classes: self.classes,
                x: self.x.split_off(train_n * self.features),
                y: self.y.split_off(train_n),
                tokens: vec![],
                targets: vec![],
                n: k,
                is_tokens: false,
            }
        };
        self.n = train_n;
        (self, test)
    }
}

/// A materialized batch ready for any `backend::Backend`.
pub struct Batch {
    pub x: HostValue,
    pub y: HostValue,
    pub size: usize,
}

/// Shuffling batch iterator. Batch size is static (baked into the AOT
/// executables), so the trailing remainder of each epoch is dropped —
/// standard drop_last=True semantics.
pub struct Batcher<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    shuffle: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64, shuffle: bool) -> Self {
        assert!(batch <= data.n, "batch {} > dataset {}", batch, data.n);
        let mut order: Vec<usize> = (0..data.n).collect();
        let mut rng = Rng::new(seed);
        if shuffle {
            rng.shuffle(&mut order);
        }
        Self { data, batch, order, cursor: 0, rng, shuffle }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.data.n / self.batch
    }

    /// Next batch, re-shuffling at epoch boundaries.
    pub fn next_batch(&mut self) -> Result<Batch> {
        if self.cursor + self.batch > self.order.len() {
            self.cursor = 0;
            if self.shuffle {
                self.rng.shuffle(&mut self.order);
            }
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        assemble_batch(self.data, idx)
    }
}

/// Deterministic data-parallel batch/shard plan: which examples form step
/// `s`'s batch and how that batch splits into gradient micro-shards.
///
/// This is the **pure-function** twin of the stateful [`Batcher`], built
/// for the data-parallel trainer's determinism contract (unit-tested here
/// and end-to-end in `tests/parallel.rs`):
///
/// * the epoch-`e` permutation is drawn from a fresh [`Rng`] keyed by
///   `(seed, e)` only — unlike a continuing shuffle stream, neither the
///   batch size, the step count, nor the replica count can shift any
///   epoch's order, so `batch_indices(step)` is a pure function of
///   `(seed, n, batch, step)`;
/// * micro-shard boundaries depend only on the batch size (fixed
///   [`ShardPlan::SHARD`]-wide slices plus one shorter tail), **never on
///   the replica count** — replicas only decide which worker computes a
///   shard, so the gradient reduction tree is identical for every R.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    seed: u64,
    n: usize,
    batch: usize,
    shard: usize,
    /// one-entry (epoch → permutation) memo so the per-step queries are
    /// O(batch) amortized instead of reshuffling all `n` examples every
    /// step; invisible to the plan's pure-function semantics
    cache: Option<(usize, Vec<usize>)>,
}

impl ShardPlan {
    /// Default micro-shard width in examples: small enough that a
    /// batch-64 spec spreads across 8 replicas, large enough that
    /// per-shard kernel launches stay amortized.
    pub const SHARD: usize = 8;

    pub fn new(seed: u64, n: usize, batch: usize) -> Result<Self> {
        if batch == 0 || batch > n {
            bail!("shard plan wants 0 < batch <= n, got batch {batch}, n {n}");
        }
        Ok(Self { seed, n, batch, shard: Self::SHARD, cache: None })
    }

    /// Override the micro-shard width (tests drive tail shards with it).
    /// Changing the width changes the reduction tree — it is part of the
    /// run's definition, like the batch size — but for any fixed width
    /// the result stays independent of the replica count.
    pub fn with_shard_width(mut self, shard: usize) -> Self {
        assert!(shard > 0, "shard width must be positive");
        self.shard = shard;
        self
    }

    pub fn shard_width(&self) -> usize {
        self.shard
    }

    /// Batches per epoch (drop-last semantics, like [`Batcher`]).
    pub fn steps_per_epoch(&self) -> usize {
        (self.n / self.batch).max(1)
    }

    /// The epoch-`e` permutation of all `n` examples — pure in
    /// `(seed, epoch)`.
    pub fn epoch_order(&self, epoch: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.n).collect();
        let mut rng =
            Rng::new(self.seed ^ (epoch as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        rng.shuffle(&mut order);
        order
    }

    /// Example indices of step `s`'s batch. Takes `&mut self` only for
    /// the epoch-permutation memo — the result is the same pure function
    /// of `(seed, n, batch, step)` regardless of query order or history.
    pub fn batch_indices(&mut self, step: usize) -> Vec<usize> {
        let spe = self.steps_per_epoch();
        let (epoch, slot) = (step / spe, step % spe);
        if self.cache.as_ref().map(|(e, _)| *e) != Some(epoch) {
            self.cache = Some((epoch, self.epoch_order(epoch)));
        }
        let order = &self.cache.as_ref().expect("epoch memo just filled").1;
        order[slot * self.batch..(slot + 1) * self.batch].to_vec()
    }

    /// Step `s`'s batch already split into per-shard index slices
    /// (replica-count-independent).
    pub fn step_shards(&mut self, step: usize) -> Vec<Vec<usize>> {
        let idx = self.batch_indices(step);
        shard_ranges(idx.len(), self.shard)
            .into_iter()
            .map(|(lo, len)| idx[lo..lo + len].to_vec())
            .collect()
    }
}

/// Split `0..n` into fixed `width`-wide ranges `(start, len)` plus one
/// shorter tail when `width` does not divide `n`.
pub fn shard_ranges(n: usize, width: usize) -> Vec<(usize, usize)> {
    assert!(width > 0, "shard width must be positive");
    let mut out = Vec::with_capacity((n + width - 1) / width);
    let mut lo = 0usize;
    while lo < n {
        let len = width.min(n - lo);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// Gather rows `idx` into one host-value batch.
pub fn assemble_batch(data: &Dataset, idx: &[usize]) -> Result<Batch> {
    let b = idx.len();
    if data.is_tokens {
        let seq = data.features;
        let mut xs = Vec::with_capacity(b * seq);
        let mut ys = Vec::with_capacity(b * seq);
        for &i in idx {
            xs.extend_from_slice(&data.tokens[i * seq..(i + 1) * seq]);
            ys.extend_from_slice(&data.targets[i * seq..(i + 1) * seq]);
        }
        let x = HostValue::I32 { shape: vec![b, seq], data: xs };
        let y = HostValue::I32 { shape: vec![b, seq], data: ys };
        Ok(Batch { x, y, size: b })
    } else {
        let f = data.features;
        let mut xs = Vec::with_capacity(b * f);
        let mut ys = Vec::with_capacity(b);
        for &i in idx {
            xs.extend_from_slice(&data.x[i * f..(i + 1) * f]);
            ys.push(data.y[i]);
        }
        let x = HostValue::F32(Tensor::new(&[b, f], xs)?);
        let y = HostValue::I32 { shape: vec![b], data: ys };
        Ok(Batch { x, y, size: b })
    }
}

/// Sequential (non-shuffled) full sweep for evaluation.
///
/// With `include_tail`, a final partial batch covers the `n % batch`
/// remainder so no test example is silently dropped. Backends whose
/// executables are compiled for one exact batch size (AOT/PJRT) pass
/// `false` and keep the historical full-batches-only sweep.
pub fn eval_batches(data: &Dataset, batch: usize, include_tail: bool) -> Vec<Vec<usize>> {
    let full = data.n / batch;
    let mut out: Vec<Vec<usize>> =
        (0..full).map(|b| (b * batch..(b + 1) * batch).collect()).collect();
    if include_tail && data.n % batch != 0 {
        out.push((full * batch..data.n).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let y: Vec<i32> = (0..10).map(|i| i % 3).collect();
        Dataset::from_images(4, 3, x, y).unwrap()
    }

    #[test]
    fn batcher_covers_epoch_without_repeats() {
        let d = tiny();
        let mut b = Batcher::new(&d, 2, 1, true);
        assert_eq!(b.batches_per_epoch(), 5);
        // one epoch = 5 batches of 2: each index exactly once
        let mut seen = vec![0usize; 10];
        for _ in 0..5 {
            let batch = b.next_batch().unwrap();
            let ys = batch.y.i32_data().unwrap();
            assert_eq!(ys.len(), 2);
            let xs = batch.x.as_f32().unwrap();
            assert_eq!(xs.shape(), &[2, 4]);
            for chunk in xs.data().chunks(4) {
                seen[(chunk[0] / 4.0) as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn split_sizes() {
        let d = tiny();
        let (tr, te) = d.split(3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.y.len(), 3);
    }

    #[test]
    fn token_batches() {
        let tokens: Vec<i32> = (0..24).collect();
        let targets: Vec<i32> = (1..25).collect();
        let d = Dataset::from_tokens(6, 32, tokens, targets).unwrap();
        assert_eq!(d.n, 4);
        let b = assemble_batch(&d, &[1, 3]).unwrap();
        assert_eq!(b.x.i32_data().unwrap()[0], 6);
        assert_eq!(b.y.i32_data().unwrap()[0], 7);
        assert_eq!(b.x.shape(), &[2, 6]);
    }

    #[test]
    fn shard_plan_is_pure_and_batch_independent() {
        // same (seed, n): the epoch permutation must not depend on the
        // batch size, the replica count, or any prior calls
        let mut a = ShardPlan::new(9, 40, 8).unwrap();
        let b = ShardPlan::new(9, 40, 10).unwrap();
        for e in 0..3 {
            assert_eq!(a.epoch_order(e), b.epoch_order(e), "epoch {e}");
            let mut sorted = a.epoch_order(e);
            sorted.sort_unstable();
            assert_eq!(sorted, (0..40).collect::<Vec<_>>(), "not a permutation");
        }
        assert_ne!(a.epoch_order(0), a.epoch_order(1), "epochs must reshuffle");
        assert_ne!(
            ShardPlan::new(10, 40, 8).unwrap().epoch_order(0),
            a.epoch_order(0),
            "seed must matter"
        );
        // repeated queries of the same step are identical (pure function),
        // including across the epoch memo (query epoch 1, then 0 again)
        let first = a.batch_indices(7);
        let _other_epoch = a.batch_indices(a.steps_per_epoch() + 1);
        assert_eq!(first, a.batch_indices(7));
        // one epoch covers each example at most once
        let mut seen = vec![0usize; 40];
        for s in 0..a.steps_per_epoch() {
            for i in a.batch_indices(s) {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c <= 1), "{seen:?}");
    }

    #[test]
    fn shard_plan_shards_are_replica_independent_fixed_width() {
        let mut plan = ShardPlan::new(3, 64, 20).unwrap(); // default width 8
        let shards = plan.step_shards(0);
        assert_eq!(
            shards.iter().map(Vec::len).collect::<Vec<_>>(),
            vec![8, 8, 4],
            "fixed 8-wide shards + tail"
        );
        assert_eq!(shards.concat(), plan.batch_indices(0));
        // width override: batch 96 at width 36 leaves a 24-example tail
        assert_eq!(shard_ranges(96, 36), vec![(0, 36), (36, 36), (72, 24)]);
        assert_eq!(shard_ranges(96, 16).len(), 6);
        assert_eq!(shard_ranges(0, 8), vec![]);
        assert_eq!(shard_ranges(5, 8), vec![(0, 5)]);
        let mut wide = ShardPlan::new(3, 64, 20).unwrap().with_shard_width(64);
        assert_eq!(wide.step_shards(0).len(), 1);
        assert!(ShardPlan::new(3, 4, 0).is_err());
        assert!(ShardPlan::new(3, 4, 5).is_err());
    }

    #[test]
    fn eval_batch_indices() {
        let d = tiny(); // n = 10
        let bs = eval_batches(&d, 4, false);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[1], vec![4, 5, 6, 7]);
        // with the tail, the 10 % 4 = 2 remainder examples are covered too
        let bs = eval_batches(&d, 4, true);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[2], vec![8, 9]);
        assert_eq!(bs.iter().map(Vec::len).sum::<usize>(), d.n);
        // no empty tail when batch divides n
        assert_eq!(eval_batches(&d, 5, true).len(), 2);
    }
}
