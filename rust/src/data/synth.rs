//! Synthetic image-classification datasets (DESIGN.md §5 substitution).
//!
//! No network access in this environment, so MNIST / CIFAR-100 are
//! replaced by class-conditional generative models at the same shapes:
//!
//! * `mnist_like`  — 28×28×1, 10 classes. Each class is a smooth prototype
//!   (sum of Gaussian strokes at class-deterministic positions); samples
//!   apply a random ±2px shift, per-sample intensity scaling and pixel
//!   noise. Linear separability is imperfect (≈90% linear-probe ceiling),
//!   so the method ordering in Table 1/2 is meaningful.
//! * `cifar_like`  — 32×32×3, 100 classes. Low-frequency color blobs per
//!   class + class-colored texture + strong noise; hard enough that tiny
//!   ViTs do not saturate.
//!
//! If real IDX files exist under `data/`, prefer `idx::load_mnist_dir`.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Deterministic per-class stroke positions derived from a seed.
fn class_prototype_28(rng: &mut Rng) -> [f32; 784] {
    let mut proto = [0.0f32; 784];
    // 3-5 gaussian "strokes" per class
    let strokes = 3 + rng.below(3);
    for _ in 0..strokes {
        let cx = rng.range(6.0, 22.0);
        let cy = rng.range(6.0, 22.0);
        let sx = rng.range(1.5, 4.0);
        let sy = rng.range(1.5, 4.0);
        let amp = rng.range(0.6, 1.0);
        for i in 0..28 {
            for j in 0..28 {
                let dx = (i as f32 - cx) / sx;
                let dy = (j as f32 - cy) / sy;
                proto[i * 28 + j] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
            }
        }
    }
    proto
}

/// 28×28 grayscale, `classes` classes, `n` samples.
pub fn mnist_like(seed: u64, n: usize, classes: usize) -> Dataset {
    let mut proto_rng = Rng::new(seed ^ 0xD1617);
    let protos: Vec<[f32; 784]> =
        (0..classes).map(|_| class_prototype_28(&mut proto_rng)).collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 784);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        y.push(c as i32);
        let proto = &protos[c];
        // random integer shift in [-2, 2]^2
        let si = rng.below(7) as isize - 3;
        let sj = rng.below(7) as isize - 3;
        let gain = rng.range(0.6, 1.4);
        let noise = 0.45;
        // 3% label noise keeps the linear-probe ceiling near the paper's
        // MNIST numbers (~85-90%) instead of saturating
        if rng.uniform() < 0.03 {
            *y.last_mut().unwrap() = rng.below(classes) as i32;
        }
        for i in 0..28isize {
            for j in 0..28isize {
                let pi = i - si;
                let pj = j - sj;
                let base = if (0..28).contains(&pi) && (0..28).contains(&pj) {
                    proto[(pi * 28 + pj) as usize]
                } else {
                    0.0
                };
                let v = gain * base + noise * rng.normal();
                x.push(v.clamp(0.0, 1.5));
            }
        }
    }
    Dataset::from_images(784, classes, x, y).expect("mnist_like dims")
}

/// Low-frequency color prototype on 32×32×3.
fn class_prototype_32c(rng: &mut Rng) -> Vec<f32> {
    let mut proto = vec![0.0f32; 3 * 32 * 32];
    let blobs = 2 + rng.below(3);
    for _ in 0..blobs {
        let cx = rng.range(4.0, 28.0);
        let cy = rng.range(4.0, 28.0);
        let s = rng.range(3.0, 8.0);
        let color = [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)];
        for ch in 0..3 {
            for i in 0..32 {
                for j in 0..32 {
                    let dx = (i as f32 - cx) / s;
                    let dy = (j as f32 - cy) / s;
                    proto[ch * 1024 + i * 32 + j] +=
                        color[ch] * (-0.5 * (dx * dx + dy * dy)).exp();
                }
            }
        }
    }
    proto
}

/// 32×32 RGB, `classes` classes (CIFAR-100-shaped when classes=100).
pub fn cifar_like(seed: u64, n: usize, classes: usize) -> Dataset {
    let mut proto_rng = Rng::new(seed ^ 0xC1FA6);
    let protos: Vec<Vec<f32>> =
        (0..classes).map(|_| class_prototype_32c(&mut proto_rng)).collect();
    let dim = 3 * 32 * 32;
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        y.push(c as i32);
        let proto = &protos[c];
        let gain = rng.range(0.7, 1.3);
        let noise = 0.35;
        for d in 0..dim {
            x.push((gain * proto[d] + noise * rng.normal()).clamp(-2.0, 2.0));
        }
    }
    Dataset::from_images(dim, classes, x, y).expect("cifar_like dims")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = mnist_like(1, 200, 10);
        assert_eq!(d.n, 200);
        assert_eq!(d.features, 784);
        assert!(d.y.iter().all(|&c| (0..10).contains(&c)));
        assert!(d.y.iter().any(|&c| c != d.y[0]), "labels not all identical");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = mnist_like(7, 50, 10);
        let b = mnist_like(7, 50, 10);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = mnist_like(8, 50, 10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn class_signal_exists() {
        // nearest-prototype classification on clean means must beat chance:
        // estimate class means from one half, classify the other half
        let d = mnist_like(3, 2000, 10);
        let half = 1000;
        let mut means = vec![vec![0.0f32; 784]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..half {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for j in 0..784 {
                means[c][j] += d.x[i * 784 + j];
            }
        }
        for c in 0..10 {
            for j in 0..784 {
                means[c][j] /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in half..2000 {
            let row = &d.x[i * 784..(i + 1) * 784];
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for c in 0..10 {
                let dist: f32 =
                    row.iter().zip(&means[c]).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            if best == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / half as f32;
        assert!(acc > 0.5, "nearest-prototype acc too low: {acc}");
    }

    #[test]
    fn cifar_like_shape() {
        let d = cifar_like(1, 100, 100);
        assert_eq!(d.features, 3072);
        assert_eq!(d.classes, 100);
    }
}
