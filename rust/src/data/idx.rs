//! IDX (MNIST) file-format loader. If the user places the real MNIST files
//! (`train-images-idx3-ubyte`, `train-labels-idx1-ubyte`) under a
//! directory, the coordinator uses them instead of the synthetic
//! generator — same code path downstream.
//!
//! Files must be uncompressed: the offline-hermetic build carries no
//! gzip implementation, so `.gz` inputs are rejected with a clear error
//! instead of silently mis-parsing.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;

fn read_file(path: &Path) -> Result<Vec<u8>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if path.extension().map(|e| e == "gz").unwrap_or(false)
        || raw.starts_with(&[0x1f, 0x8b])
    {
        bail!("{path:?} is gzipped — gunzip it first (offline build has no flate2)");
    }
    Ok(raw)
}

fn be_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX3 image file -> (n, rows, cols, pixels normalized to [0,1]).
pub fn parse_idx3(bytes: &[u8]) -> Result<(usize, usize, usize, Vec<f32>)> {
    if bytes.len() < 16 || be_u32(bytes, 0) != 0x0000_0803 {
        bail!("not an idx3 image file");
    }
    let n = be_u32(bytes, 4) as usize;
    let rows = be_u32(bytes, 8) as usize;
    let cols = be_u32(bytes, 12) as usize;
    let want = 16 + n * rows * cols;
    if bytes.len() < want {
        bail!("idx3 truncated: {} < {}", bytes.len(), want);
    }
    let pixels = bytes[16..want].iter().map(|&b| b as f32 / 255.0).collect();
    Ok((n, rows, cols, pixels))
}

/// Parse an IDX1 label file -> labels.
pub fn parse_idx1(bytes: &[u8]) -> Result<Vec<i32>> {
    if bytes.len() < 8 || be_u32(bytes, 0) != 0x0000_0801 {
        bail!("not an idx1 label file");
    }
    let n = be_u32(bytes, 4) as usize;
    if bytes.len() < 8 + n {
        bail!("idx1 truncated");
    }
    Ok(bytes[8..8 + n].iter().map(|&b| b as i32).collect())
}

/// Load `<dir>/{images},{labels}` into a Dataset.
pub fn load_mnist(images: &Path, labels: &Path, classes: usize) -> Result<Dataset> {
    let (n, rows, cols, x) = parse_idx3(&read_file(images)?)?;
    let y = parse_idx1(&read_file(labels)?)?;
    if y.len() != n {
        bail!("image/label count mismatch: {} vs {}", n, y.len());
    }
    Dataset::from_images(rows * cols, classes, x, y)
}

/// Probe a directory for the standard MNIST file names. The `.gz` names
/// are still probed so gzipped downloads surface `read_file`'s
/// "gunzip it first" error instead of silently falling back to the
/// synthetic dataset.
pub fn load_mnist_dir(dir: &Path) -> Option<Result<Dataset>> {
    for (img, lbl) in [
        ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
        ("train-images-idx3-ubyte.gz", "train-labels-idx1-ubyte.gz"),
    ] {
        let ip = dir.join(img);
        let lp = dir.join(lbl);
        if ip.exists() && lp.exists() {
            return Some(load_mnist(&ip, &lp, 10));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_idx3(n: usize, rows: usize, cols: usize) -> Vec<u8> {
        let mut b = vec![];
        b.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&(rows as u32).to_be_bytes());
        b.extend_from_slice(&(cols as u32).to_be_bytes());
        b.extend((0..n * rows * cols).map(|i| (i % 256) as u8));
        b
    }

    fn fake_idx1(n: usize) -> Vec<u8> {
        let mut b = vec![];
        b.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend((0..n).map(|i| (i % 10) as u8));
        b
    }

    #[test]
    fn roundtrip() {
        let (n, r, c, x) = parse_idx3(&fake_idx3(5, 4, 4)).unwrap();
        assert_eq!((n, r, c), (5, 4, 4));
        assert_eq!(x.len(), 80);
        assert!((x[255.min(x.len() - 1)] - (255 % 256) as f32 / 255.0).abs() < 1.0);
        let y = parse_idx1(&fake_idx1(5)).unwrap();
        assert_eq!(y, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bad_magic() {
        assert!(parse_idx3(&[0, 0, 8, 1, 0, 0, 0, 0]).is_err());
        assert!(parse_idx1(&[0, 0, 8, 3, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn gzip_inputs_are_rejected_with_guidance() {
        let dir = std::env::temp_dir().join("bs_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.gz");
        // gzip magic header followed by junk
        std::fs::write(&p, [0x1f, 0x8b, 0x08, 0x00]).unwrap();
        let err = read_file(&p).unwrap_err();
        assert!(format!("{err:#}").contains("gunzip"), "{err:#}");
    }

    #[test]
    fn raw_files_load() {
        let dir = std::env::temp_dir().join("bs_idx_test_raw");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels");
        std::fs::write(&p, fake_idx1(7)).unwrap();
        let bytes = read_file(&p).unwrap();
        assert_eq!(parse_idx1(&bytes).unwrap().len(), 7);
    }
}
