//! Synthetic byte corpus for the LM end-to-end example: an order-1 Markov
//! chain over a small alphabet with skewed, sparse transitions. Order-1
//! keeps the per-token conditional entropy low (~1.5 bits vs log2(64)=6),
//! so a transformer's cross-entropy visibly drops well below log(vocab)
//! within a CPU-budget run — the loss-curve signal EXPERIMENTS.md records.
//! (An order-2 chain looks nearly uniform to a model that has not yet
//! learned attention, which made early loss curves flat.)

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Build a sparse order-1 transition table over `vocab` symbols: for each
/// previous symbol only `fanout` successors have non-zero probability.
pub struct MarkovSource {
    vocab: usize,
    fanout: usize,
    /// successors[(a*vocab+b)*fanout + k] and matching cumulative weights
    succ: Vec<u16>,
    cum: Vec<f32>,
}

impl MarkovSource {
    pub fn new(seed: u64, vocab: usize, fanout: usize) -> Self {
        assert!(vocab <= u16::MAX as usize);
        let mut rng = Rng::new(seed);
        let ctx = vocab;
        let mut succ = Vec::with_capacity(ctx * fanout);
        let mut cum = Vec::with_capacity(ctx * fanout);
        for _ in 0..ctx {
            let mut total = 0.0f32;
            let picks = rng.choose(vocab, fanout);
            let mut weights: Vec<f32> = (0..fanout).map(|_| rng.range(0.1, 1.0)).collect();
            // skew: make one successor strongly dominant so the chain's
            // conditional entropy sits well below log2(vocab) — the LM
            // then has clear structure to learn
            weights[0] += 6.0;
            for k in 0..fanout {
                total += weights[k];
                succ.push(picks[k] as u16);
                cum.push(total);
            }
            let last = cum.len() - fanout;
            for v in &mut cum[last..] {
                *v /= total;
            }
        }
        Self { vocab, fanout, succ, cum }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&self, b: usize, rng: &mut Rng) -> usize {
        let base = b * self.fanout;
        let u = rng.uniform();
        for k in 0..self.fanout {
            if u <= self.cum[base + k] {
                return self.succ[base + k] as usize;
            }
        }
        self.succ[base + self.fanout - 1] as usize
    }

    /// Sample a token stream of length `len`.
    pub fn sample(&self, seed: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(len);
        let mut b = rng.below(self.vocab);
        for _ in 0..len {
            let c = self.step(b, &mut rng);
            out.push(c as i32);
            b = c;
        }
        out
    }
}

/// Chunk a stream into (input, next-token target) sequences of length `seq`.
pub fn lm_dataset(seed: u64, vocab: usize, seq: usize, n_seqs: usize) -> Dataset {
    let src = MarkovSource::new(seed ^ 0x11A2, vocab, 8.min(vocab));
    let stream = src.sample(seed, n_seqs * seq + 1);
    let mut tokens = Vec::with_capacity(n_seqs * seq);
    let mut targets = Vec::with_capacity(n_seqs * seq);
    for i in 0..n_seqs * seq {
        tokens.push(stream[i]);
        targets.push(stream[i + 1]);
    }
    Dataset::from_tokens(seq, vocab, tokens, targets).expect("lm dataset dims")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let d = lm_dataset(1, 64, 16, 20);
        assert_eq!(d.n, 20);
        assert!(d.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert!(d.targets.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let d = lm_dataset(2, 32, 8, 10);
        // within a sequence, target[i] == token[i+1]
        for s in 0..10 {
            for i in 0..7 {
                assert_eq!(d.targets[s * 8 + i], d.tokens[s * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn chain_is_learnable() {
        // Order-1 conditional entropy H(X_t | X_{t-1}) must sit far
        // below log2(vocab): a bigram-capable LM has clear signal.
        let src = MarkovSource::new(3, 64, 8);
        let s = src.sample(4, 400_000);
        use std::collections::HashMap;
        let mut counts: HashMap<(i32, i32), f64> = HashMap::new();
        let mut ctx_tot: HashMap<i32, f64> = HashMap::new();
        for w in s.windows(2) {
            *counts.entry((w[0], w[1])).or_default() += 1.0;
            *ctx_tot.entry(w[0]).or_default() += 1.0;
        }
        let n: f64 = ctx_tot.values().sum();
        let mut h = 0.0f64;
        for ((a, _b), cnt) in &counts {
            let p_joint = cnt / n;
            let p_cond = cnt / ctx_tot[a];
            h -= p_joint * p_cond.log2();
        }
        assert!(h < 3.0, "order-1 conditional entropy {h} not < 3 bits (log2(64)=6)");
    }

    #[test]
    fn deterministic() {
        // same seed → the identical dataset, inputs and targets both;
        // a different seed must actually change the stream
        let a = lm_dataset(9, 32, 8, 5);
        let b = lm_dataset(9, 32, 8, 5);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.targets, b.targets);
        let c = lm_dataset(10, 32, 8, 5);
        assert_ne!(a.tokens, c.tokens, "seed must steer the corpus");
    }

    #[test]
    fn same_seed_same_transition_table() {
        // the Markov chain itself is a pure function of its seed: the
        // successor table and cumulative weights are bitwise identical
        // across constructions, and sampling is a pure function of
        // (table, sample seed)
        let a = MarkovSource::new(11, 48, 6);
        let b = MarkovSource::new(11, 48, 6);
        assert_eq!(a.succ, b.succ);
        assert_eq!(a.cum, b.cum);
        assert_eq!(a.sample(5, 1000), b.sample(5, 1000));
        assert_ne!(a.sample(5, 1000), a.sample(6, 1000));
        let c = MarkovSource::new(12, 48, 6);
        assert_ne!(a.succ, c.succ, "seed must steer the transition table");
    }

    #[test]
    fn train_eval_split_partitions_the_sequences() {
        // split is positional over whole sequences: train ++ test
        // reassembles the full corpus exactly, so the two sides cannot
        // share (or drop) a sequence
        let full = lm_dataset(13, 32, 8, 12);
        let (all_tokens, all_targets) = (full.tokens.clone(), full.targets.clone());
        let (train, test) = full.split(3);
        assert_eq!(train.n, 9);
        assert_eq!(test.n, 3);
        assert_eq!(train.tokens.len(), 9 * 8);
        assert_eq!(test.tokens.len(), 3 * 8);
        let mut rejoined = train.tokens.clone();
        rejoined.extend(&test.tokens);
        assert_eq!(rejoined, all_tokens);
        let mut rejoined_t = train.targets.clone();
        rejoined_t.extend(&test.targets);
        assert_eq!(rejoined_t, all_targets);
    }
}
