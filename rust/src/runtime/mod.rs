//! PJRT runtime: loads the AOT artifacts and executes them on the hot path.
//!
//! Wraps the `xla` crate (PJRT C API): HLO text -> `HloModuleProto` ->
//! `XlaComputation` -> `PjRtLoadedExecutable`. Executables are compiled
//! once and cached; training state lives as `xla::Literal`s in manifest
//! argument order so a step is a single `execute` call with zero
//! re-marshalling of parameters on the host.
//!
//! All computations are lowered with `return_tuple=True`, so every execute
//! returns one tuple buffer; `run` decomposes it back into leaves.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::{ExecEntry, Manifest, SpecEntry};
use crate::tensor::{HostValue, Tensor};

/// A compiled executable plus its manifest signature.
pub struct Executable {
    pub entry: ExecEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional literal arguments; returns the decomposed
    /// output leaves (manifest `outputs` order).
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}.{}: expected {} args, got {}",
                self.entry.spec,
                self.entry.exec,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let res = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}.{}", self.entry.spec, self.entry.exec))?;
        let lit = res[0][0].to_literal_sync()?;
        let leaves = lit.to_tuple()?;
        if leaves.len() != self.entry.outputs.len() {
            bail!(
                "{}.{}: manifest promises {} outputs, PJRT returned {}",
                self.entry.spec,
                self.entry.exec,
                self.entry.outputs.len(),
                leaves.len()
            );
        }
        Ok(leaves)
    }
}

/// Mutable training state for one spec: parameter + optimizer literals in
/// manifest order, threaded through consecutive train steps.
pub struct TrainState {
    pub spec: String,
    pub param_names: Vec<String>,
    pub opt_names: Vec<String>,
    pub params: Vec<xla::Literal>,
    pub opt: Vec<xla::Literal>,
}

impl TrainState {
    pub fn param(&self, key: &str) -> Result<&xla::Literal> {
        let i = self
            .param_names
            .iter()
            .position(|n| n == key)
            .ok_or_else(|| anyhow!("no param '{key}' in spec {}", self.spec))?;
        Ok(&self.params[i])
    }

    pub fn param_tensor(&self, key: &str) -> Result<Tensor> {
        match HostValue::from_literal(self.param(key)?)? {
            HostValue::F32(t) => Ok(t),
            _ => bail!("param '{key}' is not f32"),
        }
    }

    pub fn set_param(&mut self, key: &str, value: &HostValue) -> Result<()> {
        let i = self
            .param_names
            .iter()
            .position(|n| n == key)
            .ok_or_else(|| anyhow!("no param '{key}'"))?;
        self.params[i] = value.to_literal()?;
        Ok(())
    }
}

/// The runtime: one PJRT client + a compile cache over the manifest.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<(String, String), Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { manifest, client, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) one executable of a spec.
    pub fn load(&self, spec: &str, exec: &str) -> Result<Arc<Executable>> {
        let key = (spec.to_string(), exec.to_string());
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let entry = self.manifest.exec(spec, exec)?.clone();
        let path = self.manifest.hlo_path(&entry);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}.{}", spec, exec))?;
        let arc = Arc::new(Executable { entry, exe });
        self.cache.lock().unwrap().insert(key, arc.clone());
        Ok(arc)
    }

    pub fn spec(&self, key: &str) -> Result<&SpecEntry> {
        self.manifest.spec(key)
    }

    /// Run the spec's `init` executable -> fresh TrainState.
    pub fn init_state(&self, spec: &str, seed: u32) -> Result<TrainState> {
        let exe = self.load(spec, "init")?;
        let seed_lit = HostValue::scalar_u32(seed).to_literal()?;
        let leaves = exe.run(&[&seed_lit])?;
        let mut params = Vec::new();
        let mut opt = Vec::new();
        let mut param_names = Vec::new();
        let mut opt_names = Vec::new();
        for (slot, lit) in exe.entry.outputs.iter().zip(leaves) {
            if let Some(p) = slot.param_key() {
                param_names.push(p.to_string());
                params.push(lit);
            } else if let Some(o) = slot.opt_key() {
                opt_names.push(o.to_string());
                opt.push(lit);
            } else {
                bail!("unexpected init output '{}'", slot.name);
            }
        }
        Ok(TrainState { spec: spec.to_string(), param_names, opt_names, params, opt })
    }

    /// One training step: consumes/updates `state`, returns the metrics
    /// vector (names in `spec.metrics`).
    pub fn train_step(
        &self,
        state: &mut TrainState,
        x: &xla::Literal,
        y: &xla::Literal,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let exe = self.load(&state.spec, "train_step")?;
        if hyper.len() != exe.entry.hyper.len() {
            bail!(
                "{} train_step wants hyper {:?}, got {} values",
                state.spec,
                exe.entry.hyper,
                hyper.len()
            );
        }
        let hyper_lits: Vec<xla::Literal> =
            hyper.iter().map(|&h| xla::Literal::scalar(h)).collect();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(exe.entry.inputs.len());
        args.extend(state.params.iter());
        args.extend(state.opt.iter());
        args.push(x);
        args.push(y);
        args.extend(hyper_lits.iter());
        let mut leaves = exe.run(&args)?;
        // outputs: params' ++ opt' ++ metrics
        let np = state.params.len();
        let no = state.opt.len();
        let metrics_lit =
            leaves.pop().ok_or_else(|| anyhow!("train_step returned no outputs"))?;
        if leaves.len() != np + no {
            bail!("train_step output arity mismatch: {} vs {}", leaves.len(), np + no);
        }
        let opt_new = leaves.split_off(np);
        state.params = leaves;
        state.opt = opt_new;
        metrics_lit.to_vec::<f32>().map_err(Into::into)
    }

    /// Evaluation step on the current parameters.
    pub fn eval_step(
        &self,
        state: &TrainState,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<Vec<f32>> {
        let exe = self.load(&state.spec, "eval_step")?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(exe.entry.inputs.len());
        args.extend(state.params.iter());
        args.push(x);
        args.push(y);
        let leaves = exe.run(&args)?;
        leaves[0].to_vec::<f32>().map_err(Into::into)
    }

    /// KPD only: reconstruct the block-wise sparse W of every slot.
    pub fn materialize(&self, state: &TrainState) -> Result<Vec<(String, Tensor)>> {
        let exe = self.load(&state.spec, "materialize")?;
        let args: Vec<&xla::Literal> = state.params.iter().collect();
        let leaves = exe.run(&args)?;
        exe.entry
            .outputs
            .iter()
            .zip(leaves)
            .map(|(slot, lit)| {
                let name =
                    slot.name.strip_prefix("W:").unwrap_or(&slot.name).to_string();
                match HostValue::from_literal(&lit)? {
                    HostValue::F32(t) => Ok((name, t)),
                    _ => bail!("materialize output not f32"),
                }
            })
            .collect()
    }

    /// Blockwise-RigL mask update (paper §6.1 baseline).
    pub fn rigl_update(
        &self,
        state: &mut TrainState,
        gnorm: &[f32],
        alpha: f32,
    ) -> Result<()> {
        let exe = self.load(&state.spec, "rigl_update")?;
        let g = xla::Literal::vec1(gnorm);
        let a = xla::Literal::scalar(alpha);
        let mut args: Vec<&xla::Literal> = state.params.iter().collect();
        args.push(&g);
        args.push(&a);
        let leaves = exe.run(&args)?;
        state.params = leaves;
        Ok(())
    }

    /// Iterative-pruning step to a global sparsity target.
    pub fn prune(&self, state: &mut TrainState, target: f32) -> Result<()> {
        let exe = self.load(&state.spec, "prune")?;
        let t = xla::Literal::scalar(target);
        let mut args: Vec<&xla::Literal> = state.params.iter().collect();
        args.push(&t);
        let leaves = exe.run(&args)?;
        state.params = leaves;
        Ok(())
    }
}
