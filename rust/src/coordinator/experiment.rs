//! Experiment runner: multi-seed sweeps + the accounting columns.
//!
//! `run_spec` trains a spec over all configured seeds, aggregates accuracy
//! and sparsity as mean±std (the paper reports 5-run std devs; we default
//! to 3 seeds on this CPU testbed), and attaches the Training-Params /
//! Training-FLOPs columns computed from the closed forms in `crate::flops`.

use anyhow::Result;

use crate::backend::Backend;
use crate::config::TrainConfig;
use crate::coordinator::{dataset_for, probe, trainer::Trainer};
use crate::flops::{self, KpdDims};
use crate::manifest::SpecEntry;
use crate::metrics::History;
use crate::util::mean_std;

/// Aggregated result of a spec sweep (one table row).
pub struct SpecResult {
    pub spec: String,
    pub method: String,
    pub acc_mean: f64,
    pub acc_std: f64,
    pub sparsity_mean: f64,
    pub sparsity_std: f64,
    /// per-layer sparsity (slot name, mean %, std %) in slot order —
    /// populated for multi-slot (mlp) and single-slot specs alike, empty
    /// for pattern specs
    pub layer_sparsity: Vec<(String, f64, f64)>,
    pub train_params: u64,
    pub step_flops: u64,
    pub wall_secs: f64,
    /// per-seed loss histories (figures / loss curves)
    pub histories: Vec<History>,
    /// per-pattern accuracies per seed (pattern specs)
    pub pattern_accs: Vec<Vec<f64>>,
}

/// KPD shapes per slot from the manifest's info blob.
pub fn kpd_dims(spec: &SpecEntry) -> Vec<(String, KpdDims)> {
    let mut out = Vec::new();
    if let Some(shapes) = spec.info.get("shapes").and_then(|j| j.as_obj()) {
        for (name, v) in shapes {
            let d = KpdDims {
                m1: v.get("m1").and_then(|x| x.as_usize()).unwrap_or(1),
                n1: v.get("n1").and_then(|x| x.as_usize()).unwrap_or(1),
                m2: v.get("m2").and_then(|x| x.as_usize()).unwrap_or(1),
                n2: v.get("n2").and_then(|x| x.as_usize()).unwrap_or(1),
                r: v.get("r").and_then(|x| x.as_usize()).unwrap_or(1),
            };
            out.push((name.clone(), d));
        }
    }
    out
}

/// The Training-Params / Training-FLOPs columns for one spec. Slot-level
/// accounting: dense-parameterized methods (group LASSO, elastic GL, RigL,
/// pruning, dense) all pay the full W cost; the KPD method pays the
/// factorized cost (Prop. 2). Backbone (convs/embeddings/norms) params are
/// included via the manifest's exact `params_total`; backbone FLOPs are
/// identical across methods within a table and are excluded, matching how
//  the paper's comparisons are read.
pub fn accounting(spec: &SpecEntry) -> (u64, u64) {
    let nb = spec.batch as u64;
    let step_flops = match spec.method.as_str() {
        "kpd" => {
            let dims = kpd_dims(spec);
            flops::total_flops(&flops::kpd_model_cost(nb, &dims))
        }
        m if m.starts_with("pattern") => {
            // K pattern copies train jointly
            let mut total = 0u64;
            if let Some(pats) = spec.info.get("patterns").and_then(|j| j.as_arr()) {
                let r = spec.rank().unwrap_or(1);
                for pat in pats {
                    for slot in &spec.slots {
                        if let Some(b) =
                            pat.get(&slot.name).and_then(|j| j.as_arr())
                        {
                            let (m2, n2) = (
                                b[0].as_usize().unwrap_or(1),
                                b[1].as_usize().unwrap_or(1),
                            );
                            let d = KpdDims::from_block(slot.m, slot.n, m2, n2, r);
                            total += flops::kpd_step_flops(nb, d);
                        }
                    }
                }
            }
            total
        }
        _ => {
            let slots: Vec<(String, usize, usize)> = spec
                .slots
                .iter()
                .map(|s| (s.name.clone(), s.m, s.n))
                .collect();
            flops::total_flops(&flops::dense_model_cost(nb, &slots))
        }
    };
    (spec.params_total as u64, step_flops)
}

/// Train a spec over all seeds in the config; aggregate.
pub fn run_spec(be: &dyn Backend, cfg: &TrainConfig) -> Result<SpecResult> {
    let spec = be.spec(&cfg.spec)?.clone();
    let (train, test) = dataset_for(&spec, cfg.data_seed, cfg.train_examples,
                                    cfg.test_examples)?;
    let trainer = Trainer::new(be, cfg);
    let mut accs = Vec::new();
    let mut spars = Vec::new();
    let mut layer_rates: Vec<(String, Vec<f64>)> = Vec::new();
    let mut histories = Vec::new();
    let mut pattern_accs = Vec::new();
    let mut wall = 0.0;
    for &seed in &cfg.seeds {
        let outcome = trainer.run(seed, &train, &test)?;
        // one probe pass: whole-model rate + per-layer breakdown (KPD
        // specs materialize the dense stack once, not twice)
        let (sp, layers) = probe::sparsity_report(be, &spec, &outcome.state)?;
        // per-layer rates, aggregated positionally (slot order is fixed)
        for (j, (name, rate)) in layers.into_iter().enumerate() {
            if layer_rates.len() <= j {
                layer_rates.push((name, Vec::new()));
            }
            layer_rates[j].1.push(rate);
        }
        crate::info!(
            "[{}] seed {seed}: acc {:.2}% sparsity {:.2}% ({:.1}s)",
            cfg.spec, outcome.test_acc, sp, outcome.wall_secs
        );
        accs.push(outcome.test_acc);
        spars.push(sp);
        wall += outcome.wall_secs;
        histories.push(outcome.history);
        pattern_accs.push(outcome.pattern_accs);
    }
    let (am, astd) = mean_std(&accs);
    let (sm, sstd) = mean_std(&spars);
    let layer_sparsity: Vec<(String, f64, f64)> = layer_rates
        .into_iter()
        .map(|(name, rates)| {
            let (m, s) = mean_std(&rates);
            (name, m, s)
        })
        .collect();
    let (train_params, step_flops) = accounting(&spec);
    Ok(SpecResult {
        spec: cfg.spec.clone(),
        method: spec.method.clone(),
        acc_mean: am,
        acc_std: astd,
        sparsity_mean: sm,
        sparsity_std: sstd,
        layer_sparsity,
        train_params,
        step_flops,
        wall_secs: wall,
        histories,
        pattern_accs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn kpd_spec() -> SpecEntry {
        SpecEntry {
            key: "x".into(),
            model: "linear".into(),
            batch: 128,
            tags: vec![],
            input_shape: vec![784],
            input_dtype: crate::tensor::DType::F32,
            num_classes: 10,
            slots: vec![crate::manifest::SlotInfo { name: "fc".into(), m: 10, n: 784 }],
            method: "kpd".into(),
            hyper: vec![],
            metrics: vec![],
            params_total: 5890,
            info: Json::parse(
                r#"{"shapes": {"fc": {"m1": 5, "n1": 49, "m2": 2, "n2": 16, "r": 2}}}"#,
            )
            .unwrap(),
        }
    }

    #[test]
    fn kpd_dims_parsed() {
        let dims = kpd_dims(&kpd_spec());
        assert_eq!(dims.len(), 1);
        assert_eq!(dims[0].1, KpdDims { m1: 5, n1: 49, m2: 2, n2: 16, r: 2 });
    }

    #[test]
    fn accounting_kpd_below_dense() {
        let spec = kpd_spec();
        let (_params, kpd_flops) = accounting(&spec);
        let mut dense = spec.clone();
        dense.method = "group_lasso".into();
        let (_dp, dense_flops) = accounting(&dense);
        assert!(kpd_flops > 0);
        assert!(dense_flops > kpd_flops, "{kpd_flops} !< {dense_flops}");
    }
}
