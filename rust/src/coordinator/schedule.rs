//! Regularization & learning-rate schedules.
//!
//! The paper's pattern-selection experiments (§6.1/6.2) set λ1 = λ2 = 0.01
//! and *increase them by 0.002 every 5 epochs* until exactly one pattern's
//! S matrices survive. `LambdaSchedule` reproduces that staircase ramp;
//! the plain method uses a constant λ.

/// Staircase λ(t): base + ramp · floor(step / every)   (every=0 → constant)
#[derive(Clone, Debug)]
pub struct LambdaSchedule {
    pub base: f64,
    pub ramp: f64,
    pub every: usize,
}

impl LambdaSchedule {
    pub fn constant(v: f64) -> Self {
        Self { base: v, ramp: 0.0, every: 0 }
    }

    pub fn staircase(base: f64, ramp: f64, every: usize) -> Self {
        Self { base, ramp, every }
    }

    pub fn at(&self, step: usize) -> f64 {
        if self.every == 0 || self.ramp == 0.0 {
            return self.base;
        }
        self.base + self.ramp * (step / self.every) as f64
    }
}

/// Cosine LR decay with warmup — used by the transformer runs; the linear
/// and LeNet tables use a constant LR like the paper's released configs.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f64,
    pub warmup: usize,
    pub total: usize,
    pub cosine: bool,
}

impl LrSchedule {
    pub fn constant(v: f64) -> Self {
        Self { base: v, warmup: 0, total: 0, cosine: false }
    }

    pub fn cosine(base: f64, warmup: usize, total: usize) -> Self {
        Self { base, warmup, total, cosine: true }
    }

    pub fn at(&self, step: usize) -> f64 {
        if !self.cosine {
            return self.base;
        }
        if self.warmup > 0 && step < self.warmup {
            return self.base * (step + 1) as f64 / self.warmup as f64;
        }
        if self.total <= self.warmup {
            return self.base;
        }
        let t = (step - self.warmup) as f64 / (self.total - self.warmup) as f64;
        let t = t.clamp(0.0, 1.0);
        self.base * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// Gradual iterative-pruning schedule: `rounds` prune events spread evenly
/// over `steps`, ramping linearly to the final `target` sparsity.
///
/// On small step budgets the naive spacing `steps·k/(rounds+1)` maps
/// several rounds onto the same step (which would prune twice to different
/// targets within one step) and can land round 1 on step 0, before any
/// training. Colliding rounds are deduplicated keeping only the *final*
/// (largest-k) target per step, and the schedule is clamped into
/// `[1, steps-1]` — the trainer's loop runs steps `0..steps`, so a prune
/// scheduled at `steps` would silently never fire. With fewer than two
/// steps there is no post-training step to prune at, so the schedule is
/// empty.
pub fn prune_schedule(steps: usize, rounds: usize, target: f64) -> Vec<(usize, f32)> {
    if steps < 2 {
        return vec![];
    }
    let mut by_step = std::collections::BTreeMap::new();
    for k in 1..=rounds {
        let step = (steps * k / (rounds + 1)).clamp(1, steps - 1);
        let t = target * k as f64 / rounds as f64;
        // ascending k: a later round landing on an occupied step overwrites
        // it with the deeper target
        by_step.insert(step, t as f32);
    }
    by_step.into_iter().collect()
}

/// RigL drop-fraction schedule: α · decay^(updates so far), mirroring the
/// cosine-decayed α of Evci et al. with a simpler exponential.
#[derive(Clone, Debug)]
pub struct RiglSchedule {
    pub alpha0: f64,
    pub decay: f64,
    pub every: usize,
}

impl RiglSchedule {
    /// α for the k-th mask update (k = step / every).
    pub fn alpha(&self, step: usize) -> f64 {
        if self.every == 0 {
            return 0.0;
        }
        let k = step / self.every;
        self.alpha0 * self.decay.powi(k as i32)
    }

    pub fn is_update_step(&self, step: usize) -> bool {
        self.every > 0 && step > 0 && step % self.every == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staircase_matches_paper_schedule() {
        // λ = 0.01 + 0.002 every 5 "epochs" (here: schedule units)
        let s = LambdaSchedule::staircase(0.01, 0.002, 5);
        assert!((s.at(0) - 0.01).abs() < 1e-12);
        assert!((s.at(4) - 0.01).abs() < 1e-12);
        assert!((s.at(5) - 0.012).abs() < 1e-12);
        assert!((s.at(23) - 0.018).abs() < 1e-12);
    }

    #[test]
    fn constant_is_constant() {
        let s = LambdaSchedule::constant(0.5);
        assert_eq!(s.at(0), s.at(1_000_000));
    }

    #[test]
    fn cosine_monotone_after_warmup() {
        let s = LrSchedule::cosine(0.1, 10, 100);
        assert!(s.at(0) < s.at(9));
        assert!((s.at(10) - 0.1).abs() < 1e-6);
        assert!(s.at(50) > s.at(90));
        assert!(s.at(99) >= 0.0);
    }

    #[test]
    fn prune_schedule_spaces_rounds_evenly() {
        // comfortable budget: no collisions, monotone targets, final target hit
        let s = prune_schedule(100, 4, 0.8);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].0, 20);
        assert_eq!(s[3].0, 80);
        for w in s.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1, "{s:?}");
        }
        assert!((s[3].1 - 0.8).abs() < 1e-6);
    }

    #[test]
    fn prune_schedule_dedupes_collisions_and_never_fires_at_step_zero() {
        // steps=3, rounds=4 → naive steps are 3k/5 = [0, 1, 1, 2]: round 1
        // lands on step 0 and rounds 2/3 collide on step 1
        let s = prune_schedule(3, 4, 0.8);
        assert!(s.iter().all(|&(step, _)| step >= 1), "{s:?}");
        for w in s.windows(2) {
            assert!(w[0].0 < w[1].0, "duplicate prune step: {s:?}");
        }
        // colliding rounds keep the final (deeper) target: step 1 gets
        // round 3's 0.6, not round 2's 0.4
        assert_eq!(s, vec![(1, 0.6), (2, 0.8)]);
    }

    #[test]
    fn prune_schedule_empty_without_rounds() {
        assert!(prune_schedule(100, 0, 0.5).is_empty());
    }

    #[test]
    fn prune_schedule_stays_inside_the_step_range() {
        // a 1-step run has no step ≥ 1 to prune at: empty, not step==steps
        assert!(prune_schedule(1, 4, 0.8).is_empty());
        assert!(prune_schedule(0, 4, 0.8).is_empty());
        // every scheduled step is executable by a loop over 0..steps
        for steps in 2..12 {
            for rounds in 1..6 {
                for &(step, _) in &prune_schedule(steps, rounds, 0.5) {
                    assert!(step >= 1 && step < steps, "steps={steps} rounds={rounds}");
                }
            }
        }
    }

    #[test]
    fn rigl_cadence() {
        let r = RiglSchedule { alpha0: 0.3, decay: 0.5, every: 100 };
        assert!(!r.is_update_step(0));
        assert!(r.is_update_step(100));
        assert!(!r.is_update_step(150));
        assert!((r.alpha(0) - 0.3).abs() < 1e-12);
        assert!((r.alpha(200) - 0.075).abs() < 1e-12);
    }
}
