//! The L3 coordinator: owns the training loop and every control decision
//! the paper's method needs at run time.
//!
//! * `schedule`   — λ ramping (the Eq. 7 / Figure 3 schedule) and LR plans
//! * `trainer`    — one (spec, seed) training run: batches → train_step →
//!   method controllers (RigL mask updates, pruning rounds) → eval
//! * `probe`      — sparsity measurement per method (materialize / masks)
//! * `experiment` — multi-seed sweeps, mean±std aggregation, and the
//!   params/FLOPs columns every paper table reports

pub mod experiment;
pub mod probe;
pub mod schedule;
pub mod trainer;

pub use experiment::{run_spec, SpecResult};
pub use schedule::LambdaSchedule;
pub use trainer::{RunOutcome, Trainer};

use anyhow::Result;

use crate::data::{corpus, synth, Dataset};
use crate::manifest::SpecEntry;

/// Build the dataset a spec trains on. Model families map to the paper's
/// datasets (MNIST → `synth::mnist_like` for linear/mlp/lenet5, CIFAR-100
/// → `synth::cifar_like`, LM → Markov corpus); real IDX files under
/// `data/` take precedence for the MNIST-shaped models.
pub fn dataset_for(spec: &SpecEntry, data_seed: u64, train_n: usize,
                   test_n: usize) -> Result<(Dataset, Dataset)> {
    let total = train_n + test_n;
    let full = if spec.model.starts_with("lm_") {
        let seq = spec.input_shape[0];
        corpus::lm_dataset(data_seed, spec.num_classes, seq, total)
    } else if spec.model == "linear" || spec.model == "mlp" || spec.model == "lenet5" {
        if let Some(loaded) = crate::data::idx::load_mnist_dir(std::path::Path::new("data")) {
            let d = loaded?;
            crate::info!("using real MNIST from data/ ({} examples)", d.n);
            d
        } else {
            synth::mnist_like(data_seed, total, spec.num_classes)
        }
    } else {
        // vit_* / swin_proxy: CIFAR-100-shaped
        synth::cifar_like(data_seed, total, spec.num_classes)
    };
    let total = full.n.min(total);
    let test_n = test_n.min(total / 4);
    Ok(full.split(test_n))
}
