//! Sparsity probing: the "Sparsity Rate" column, method by method.
//!
//! * kpd          — materialize W_r per slot, measure block-level sparsity
//!                  at the spec's block size (zero blocks come from S ≈ 0)
//! * group_lasso / elastic_gl — measure block sparsity of the dense W
//! * rigl_block   — read the explicit block masks
//! * iter_prune   — read the elementwise masks
//! * dense        — trivially 0 (reported as "-" by the tables)
//!
//! All rates are aggregated over slots weighted by element count, like the
//! paper's whole-model sparsity numbers.

use anyhow::Result;

use crate::backend::{Backend, TrainState};
use crate::manifest::SpecEntry;
use crate::sparsity::{self, DEFAULT_EPS_REL};

/// Whole-model sparsity rate in percent for a trained state.
pub fn measure_sparsity(be: &dyn Backend, spec: &SpecEntry, state: &TrainState) -> Result<f64> {
    let mut parts: Vec<(f64, usize)> = Vec::new();
    match spec.method.as_str() {
        "kpd" => {
            for (slot_name, w) in be.materialize(state)? {
                let (m2, n2) = spec
                    .block_of(&slot_name)
                    .unwrap_or((1, 1));
                let rate = sparsity::block_sparsity(&w, m2, n2, DEFAULT_EPS_REL)?;
                parts.push((rate, w.len()));
            }
        }
        "group_lasso" | "elastic_gl" => {
            for slot in &spec.slots {
                let w = state.param_tensor(&format!("{}.W", slot.name))?;
                let (m2, n2) = spec.block_of(&slot.name).unwrap_or((1, 1));
                let rate = sparsity::block_sparsity(&w, m2, n2, DEFAULT_EPS_REL)?;
                parts.push((rate, w.len()));
            }
        }
        "rigl_block" => {
            for slot in &spec.slots {
                let mask = state.param_tensor(&format!("{}.mask", slot.name))?;
                let rate = sparsity::mask_sparsity(&mask);
                parts.push((rate, slot.m * slot.n));
            }
        }
        "iter_prune" => {
            for slot in &spec.slots {
                let mask = state.param_tensor(&format!("{}.emask", slot.name))?;
                let rate = sparsity::mask_sparsity(&mask);
                parts.push((rate, slot.m * slot.n));
            }
        }
        "dense" => return Ok(0.0),
        m if m.starts_with("pattern") => {
            // per-pattern S sparsity of the surviving pattern is what
            // matters; report the max-sparsity pattern's S rate
            let k = spec.num_patterns().unwrap_or(1);
            let mut best = 0.0f64;
            for p in 0..k {
                let mut pp: Vec<(f64, usize)> = Vec::new();
                for slot in &spec.slots {
                    let s = state.param_tensor(&format!("p{p}.{}.S", slot.name))?;
                    pp.push((sparsity::element_sparsity(&s, DEFAULT_EPS_REL), s.len()));
                }
                best = best.max(sparsity::aggregate(&pp));
            }
            return Ok(100.0 * best);
        }
        other => anyhow::bail!("sparsity probe: unknown method '{other}'"),
    }
    Ok(100.0 * sparsity::aggregate(&parts))
}

/// Per-pattern Σ‖S‖₁ read directly from parameters (end-of-run snapshot of
/// the Figure-3 series; the in-training series comes from train metrics).
pub fn pattern_s_norms(spec: &SpecEntry, state: &TrainState) -> Result<Vec<f64>> {
    let k = spec.num_patterns().unwrap_or(0);
    let mut out = Vec::with_capacity(k);
    for p in 0..k {
        let mut total = 0.0f64;
        for slot in &spec.slots {
            let s = state.param_tensor(&format!("p{p}.{}.S", slot.name))?;
            total += s.abs_sum() as f64;
        }
        out.push(total);
    }
    Ok(out)
}
