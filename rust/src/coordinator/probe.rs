//! Sparsity probing: the "Sparsity Rate" column, method by method.
//!
//! * kpd          — materialize W_r per slot, measure block-level sparsity
//!                  at the spec's block size (zero blocks come from S ≈ 0)
//! * group_lasso / elastic_gl — measure block sparsity of the dense W
//! * rigl_block   — read the explicit block masks
//! * iter_prune   — read the elementwise masks
//! * dense        — trivially 0 (reported as "-" by the tables)
//!
//! All rates are aggregated over slots weighted by element count, like the
//! paper's whole-model sparsity numbers.

use anyhow::{anyhow, bail, Result};

use crate::backend::{Backend, TrainState};
use crate::manifest::SpecEntry;
use crate::metrics::History;
use crate::sparsity::{self, DEFAULT_EPS_REL};

/// Per-slot sparsity parts (slot name, rate as a fraction, element count)
/// — the single measurement behind both the whole-model rate and the
/// per-layer reporting of multi-slot (mlp) specs. Methods with explicit
/// masks read them per slot; KPD/group-LASSO measure block sparsity at
/// each slot's own block size. Pattern specs have no per-slot notion
/// (candidates share every slot) and return an error here — callers go
/// through [`measure_sparsity`], which handles them.
fn layer_parts(
    be: &dyn Backend,
    spec: &SpecEntry,
    state: &TrainState,
) -> Result<Vec<(String, f64, usize)>> {
    let mut parts: Vec<(String, f64, usize)> = Vec::new();
    match spec.method.as_str() {
        "kpd" => {
            for (slot_name, w) in be.materialize(state)? {
                let (m2, n2) = spec.block_of(&slot_name).unwrap_or((1, 1));
                let rate = sparsity::block_sparsity(&w, m2, n2, DEFAULT_EPS_REL)?;
                let len = w.len();
                parts.push((slot_name, rate, len));
            }
        }
        "group_lasso" | "elastic_gl" => {
            for slot in &spec.slots {
                let w = state.param_tensor(&format!("{}.W", slot.name))?;
                let (m2, n2) = spec.block_of(&slot.name).unwrap_or((1, 1));
                let rate = sparsity::block_sparsity(&w, m2, n2, DEFAULT_EPS_REL)?;
                parts.push((slot.name.clone(), rate, w.len()));
            }
        }
        "rigl_block" => {
            for slot in &spec.slots {
                let mask = state.param_tensor(&format!("{}.mask", slot.name))?;
                let rate = sparsity::mask_sparsity(&mask);
                parts.push((slot.name.clone(), rate, slot.m * slot.n));
            }
        }
        "iter_prune" => {
            for slot in &spec.slots {
                let mask = state.param_tensor(&format!("{}.emask", slot.name))?;
                let rate = sparsity::mask_sparsity(&mask);
                parts.push((slot.name.clone(), rate, slot.m * slot.n));
            }
        }
        "dense" => {
            for slot in &spec.slots {
                parts.push((slot.name.clone(), 0.0, slot.m * slot.n));
            }
        }
        other => bail!("sparsity probe: no per-slot measurement for method '{other}'"),
    }
    Ok(parts)
}

/// Per-layer sparsity in percent, in slot order — the Table-2 style
/// per-layer breakdown for multi-slot specs. Empty for pattern specs
/// (their sparsity lives in per-candidate S vectors, not per slot).
pub fn layer_sparsity(
    be: &dyn Backend,
    spec: &SpecEntry,
    state: &TrainState,
) -> Result<Vec<(String, f64)>> {
    if spec.method.starts_with("pattern") {
        return Ok(vec![]);
    }
    Ok(layer_parts(be, spec, state)?
        .into_iter()
        .map(|(name, rate, _)| (name, 100.0 * rate))
        .collect())
}

/// One-shot probe: whole-model rate (percent) plus the per-layer
/// breakdown from a *single* measurement pass — KPD specs materialize the
/// dense stack exactly once. What `experiment::run_spec` consumes;
/// [`measure_sparsity`] / [`layer_sparsity`] remain for callers that need
/// only one of the two.
pub fn sparsity_report(
    be: &dyn Backend,
    spec: &SpecEntry,
    state: &TrainState,
) -> Result<(f64, Vec<(String, f64)>)> {
    if spec.method.starts_with("pattern") {
        return Ok((measure_sparsity(be, spec, state)?, vec![]));
    }
    let parts = layer_parts(be, spec, state)?;
    let agg: Vec<(f64, usize)> = parts.iter().map(|(_, rate, len)| (*rate, *len)).collect();
    let total = 100.0 * sparsity::aggregate(&agg);
    Ok((total, parts.into_iter().map(|(name, rate, _)| (name, 100.0 * rate)).collect()))
}

/// Whole-model sparsity rate in percent for a trained state: the
/// element-weighted aggregate of [`layer_sparsity`]'s per-slot rates
/// (pattern specs instead report the max-sparsity candidate's S rate).
pub fn measure_sparsity(be: &dyn Backend, spec: &SpecEntry, state: &TrainState) -> Result<f64> {
    if spec.method.starts_with("pattern") {
        // per-pattern S sparsity of the surviving pattern is what
        // matters; report the max-sparsity pattern's S rate
        let k = spec.num_patterns().unwrap_or(1);
        let mut best = 0.0f64;
        for p in 0..k {
            let mut pp: Vec<(f64, usize)> = Vec::new();
            for slot in &spec.slots {
                let s = state.param_tensor(&format!("p{p}.{}.S", slot.name))?;
                pp.push((sparsity::element_sparsity(&s, DEFAULT_EPS_REL), s.len()));
            }
            best = best.max(sparsity::aggregate(&pp));
        }
        return Ok(100.0 * best);
    }
    if spec.method == "dense" {
        return Ok(0.0);
    }
    let parts: Vec<(f64, usize)> = layer_parts(be, spec, state)?
        .into_iter()
        .map(|(_, rate, len)| (rate, len))
        .collect();
    Ok(100.0 * sparsity::aggregate(&parts))
}

/// Per-pattern Σ‖S‖₁ read directly from parameters (end-of-run snapshot of
/// the Figure-3 series; the in-training series comes from train metrics).
pub fn pattern_s_norms(spec: &SpecEntry, state: &TrainState) -> Result<Vec<f64>> {
    let k = spec.num_patterns().unwrap_or(0);
    let mut out = Vec::with_capacity(k);
    for p in 0..k {
        let mut total = 0.0f64;
        for slot in &spec.slots {
            let s = state.param_tensor(&format!("p{p}.{}.S", slot.name))?;
            total += s.abs_sum() as f64;
        }
        out.push(total);
    }
    Ok(out)
}

/// Per-pattern normalized retention ‖S^(k)‖₁ / ‖S^(k)(0)‖₁. S^(k) is
/// initialized to all-ones, so the initial norm is the candidate's S entry
/// count, derived here from the spec's pattern grid. The survivor is read
/// as max retention everywhere (CLI, Figure-3 bench, tests); the native
/// backend's `materialize` applies the same criterion through its
/// dims-based twin `backend::native::pattern::survivor` — the two must
/// stay in agreement (count = Σ_slots (m/m2)·(n/n2) = m1·n1 per slot).
pub fn pattern_retention(spec: &SpecEntry, state: &TrainState) -> Result<Vec<f64>> {
    let norms = pattern_s_norms(spec, state)?;
    let pats = spec
        .info
        .get("patterns")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| anyhow!("spec {} has no pattern grid info", spec.key))?;
    if pats.len() != norms.len() {
        bail!(
            "spec {}: {} pattern entries but num_patterns = {}",
            spec.key,
            pats.len(),
            norms.len()
        );
    }
    let mut out = Vec::with_capacity(norms.len());
    for (p, pat) in pats.iter().enumerate() {
        let mut count = 0usize;
        for slot in &spec.slots {
            let b = pat
                .get(&slot.name)
                .and_then(|j| j.as_arr())
                .ok_or_else(|| {
                    anyhow!("pattern {p} of spec {} lacks slot '{}'", spec.key, slot.name)
                })?;
            // manifest-sourced specs reach here too: validate the grid
            // instead of panicking on a malformed artifact
            let (m2, n2) = match (b.first().and_then(|v| v.as_usize()),
                                  b.get(1).and_then(|v| v.as_usize())) {
                (Some(m2), Some(n2)) if m2 > 0 && n2 > 0 => (m2, n2),
                _ => bail!(
                    "pattern {p} of spec {}: malformed block entry for slot '{}'",
                    spec.key,
                    slot.name
                ),
            };
            if slot.m % m2 != 0 || slot.n % n2 != 0 {
                bail!(
                    "pattern {p} of spec {}: block ({m2},{n2}) does not tile \
                     slot '{}' ({}x{})",
                    spec.key,
                    slot.name,
                    slot.m,
                    slot.n
                );
            }
            count += (slot.m / m2) * (slot.n / n2);
        }
        out.push(norms[p] / count.max(1) as f64);
    }
    Ok(out)
}

/// Backend-agnostic retention: the initial ‖S^(k)‖₁ is *measured* from the
/// first recorded `s_l1_p{k}` train metric (correct for any backend's S
/// init, including manifest/PJRT executables that don't start S at ones),
/// falling back to [`pattern_retention`]'s entry-count normalization when
/// the series is absent.
pub fn pattern_retention_measured(
    spec: &SpecEntry,
    state: &TrainState,
    history: &History,
) -> Result<Vec<f64>> {
    let norms = pattern_s_norms(spec, state)?;
    // the entry-count fallback needs grid info that manifest-sourced specs
    // may lack: only derive it if some series is actually missing
    let mut fallback: Option<Vec<f64>> = None;
    let mut out = Vec::with_capacity(norms.len());
    for (p, &norm) in norms.iter().enumerate() {
        let series = history.series(&format!("s_l1_p{p}"));
        match series.first() {
            Some(&(_, init)) if init > 0.0 => out.push(norm / init),
            _ => {
                if fallback.is_none() {
                    fallback = Some(pattern_retention(spec, state)?);
                }
                out.push(fallback.as_ref().unwrap()[p]);
            }
        }
    }
    Ok(out)
}

/// The survivor criterion: index of the max-retention pattern, via the
/// shared [`crate::util::argmax`] that `materialize`'s survivor extraction
/// also uses — the pattern the tools report and the pattern `materialize`
/// extracts cannot diverge.
pub fn pattern_survivor(retention: &[f64]) -> usize {
    crate::util::argmax(retention)
}

/// Cost-aware survivor criterion: `(1−α)·retention̂ − α·latencŷ` over
/// min-max-normalized axes. A thin delegation to
/// [`crate::backend::native::pattern::survivor_cost_aware`] — one scoring
/// definition for the CLI, the sweep bench and the native backend, the
/// same single-criterion discipline as [`pattern_survivor`].
pub fn pattern_survivor_cost_aware(
    retention: &[f64],
    latency_ms: &[f64],
    alpha: f64,
) -> Result<usize> {
    crate::backend::native::pattern::survivor_cost_aware(retention, latency_ms, alpha)
}
