//! One training run: spec + seed + config → trained state + metrics.
//!
//! The trainer is method-aware through the spec entry only: hyper-parameter
//! names select the λ/lr wiring, and the method string enables the RigL
//! and iterative-pruning controllers (which call the backend's dedicated
//! entry points between train steps — exactly the role this layer has in
//! the architecture: *all* control flow lives here, *all* math lives in
//! the `Backend` implementation, HLO or native).

use anyhow::{bail, Result};

use crate::backend::{Backend, TrainState};
use crate::config::TrainConfig;
use crate::coordinator::schedule::{LambdaSchedule, LrSchedule, RiglSchedule};
use crate::data::{Batcher, Dataset};
use crate::manifest::HyperParam;
use crate::metrics::{History, Record};

/// Outcome of one (spec, seed) run.
pub struct RunOutcome {
    pub state: TrainState,
    pub history: History,
    /// test accuracy in percent
    pub test_acc: f64,
    /// per-pattern test accuracy (pattern-selection specs only)
    pub pattern_accs: Vec<f64>,
    pub test_loss: f64,
    pub steps_done: usize,
    pub wall_secs: f64,
}

pub struct Trainer<'a> {
    pub be: &'a dyn Backend,
    pub cfg: &'a TrainConfig,
}

/// Shared per-run machinery of the fused and data-parallel drivers:
/// schedules, the RigL/pruning controllers and the metric history. Both
/// drivers feed every step's metrics vector through [`RunLoop::after_step`],
/// so the controller behavior and the recorded series cannot differ
/// between them.
struct RunLoop {
    spec: crate::manifest::SpecEntry,
    lam: LambdaSchedule,
    lr: LrSchedule,
    rigl: RiglSchedule,
    prune_at: Vec<(usize, f32)>,
    is_rigl: bool,
    gnorm_len: usize,
    gnorm_acc: Vec<f32>,
    history: History,
}

impl RunLoop {
    fn new(t: &Trainer, spec: crate::manifest::SpecEntry, steps_per_epoch: usize) -> Result<Self> {
        let cfg = t.cfg;
        // schedules: ramp unit is epochs when ramp_every==0 was not set
        let ramp_every_steps = if cfg.ramp_every > 0 {
            cfg.ramp_every
        } else {
            5 * steps_per_epoch.max(1) // the paper's "+ramp every 5 epochs"
        };
        let lam = if spec.method.starts_with("pattern") {
            LambdaSchedule::staircase(cfg.lambda, cfg.lambda_ramp, ramp_every_steps)
        } else {
            LambdaSchedule::constant(cfg.lambda)
        };
        let lr = if spec.model.starts_with("vit") || spec.model.starts_with("lm")
            || spec.model.starts_with("swin")
        {
            LrSchedule::cosine(cfg.lr, cfg.steps / 20, cfg.steps)
        } else {
            LrSchedule::constant(cfg.lr)
        };
        let rigl = RiglSchedule {
            alpha0: cfg.rigl_alpha,
            decay: cfg.rigl_alpha_decay,
            every: cfg.rigl_every,
        };

        // pruning rounds: prune after each segment boundary (gradual target,
        // deduplicated per step and never before the first train step)
        let prune_at: Vec<(usize, f32)> = if spec.method == "iter_prune" {
            crate::coordinator::schedule::prune_schedule(
                cfg.steps,
                cfg.prune_rounds,
                cfg.prune_target,
            )
        } else {
            vec![]
        };

        let is_rigl = spec.method == "rigl_block";
        // metrics = [loss, ce, acc] ++ gnorm blocks (RigL specs only)
        let gnorm_len: usize = if is_rigl { t.be.gnorm_len(&cfg.spec)? } else { 0 };
        Ok(RunLoop {
            spec,
            lam,
            lr,
            rigl,
            prune_at,
            is_rigl,
            gnorm_len,
            gnorm_acc: vec![0.0; gnorm_len],
            history: History::new(),
        })
    }

    fn hyper(&self, cfg: &TrainConfig, step: usize) -> Result<Vec<f32>> {
        build_hyper(&self.spec.hyper, self.lam.at(step), cfg.lambda2, self.lr.at(step))
    }

    /// Controllers + history for one completed step (identical for the
    /// fused and sharded drivers).
    fn after_step(
        &mut self,
        t: &Trainer,
        state: &mut TrainState,
        step: usize,
        seed: u64,
        metrics: &[f32],
        test: &Dataset,
    ) -> Result<()> {
        let cfg = t.cfg;
        if self.is_rigl && metrics.len() >= 3 + self.gnorm_len {
            // exponential moving average of the dense-grad block norms
            for (a, m) in self.gnorm_acc.iter_mut().zip(&metrics[3..3 + self.gnorm_len]) {
                *a = 0.7 * *a + 0.3 * m;
            }
            if self.rigl.is_update_step(step) {
                t.be.rigl_update(state, &self.gnorm_acc, self.rigl.alpha(step) as f32)?;
            }
        }
        for &(pstep, ptarget) in &self.prune_at {
            if step == pstep {
                t.be.prune(state, ptarget)?;
                crate::debug!("pruned to target {ptarget} at step {step}");
            }
        }

        let mut rec = Record::new(step as u64).with("loss", metrics[0] as f64);
        // every *named* scalar series goes to the history: ce/acc, the
        // whole-model s_l1, the per-layer s_l1_{slot} series of mlp
        // specs and the per-pattern s_l1_p{k} Figure-3 series. RigL's
        // unnamed gnorm tail stays out (it is a controller input, and
        // fine-block MLP grids make it ~10⁵ values per step).
        for (i, name) in self.spec.metrics.iter().enumerate().skip(1) {
            if i >= metrics.len() {
                break;
            }
            if name == "ce" || name == "acc" || name == "s_l1" || name.starts_with("s_l1_") {
                rec = rec.with(name, metrics[i] as f64);
            }
        }
        self.history.push(rec)?;

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let (acc, loss, _) = t.evaluate(state, &self.spec, test)?;
            self.history.push(
                Record::new(step as u64).with("test_acc", acc).with("test_loss", loss),
            )?;
            crate::info!(
                "[{}] seed {seed} step {}/{}: loss {:.4} test_acc {:.2}%",
                cfg.spec, step + 1, cfg.steps, metrics[0], acc
            );
        }
        Ok(())
    }

    /// Final evaluation + outcome assembly.
    fn finish(
        self,
        t: &Trainer,
        state: TrainState,
        test: &Dataset,
        sw: crate::util::Stopwatch,
    ) -> Result<RunOutcome> {
        let (test_acc, test_loss, pattern_accs) = t.evaluate(&state, &self.spec, test)?;
        Ok(RunOutcome {
            state,
            history: self.history,
            test_acc,
            test_loss,
            pattern_accs,
            steps_done: t.cfg.steps,
            wall_secs: sw.elapsed_secs(),
        })
    }
}

impl<'a> Trainer<'a> {
    pub fn new(be: &'a dyn Backend, cfg: &'a TrainConfig) -> Self {
        Self { be, cfg }
    }

    /// Train `spec` from `seed`, evaluating on `test` at the end (and every
    /// `eval_every` steps into the history).
    ///
    /// `cfg.replicas > 1` delegates to the data-parallel driver
    /// ([`Trainer::run_sharded`]) when the backend has a separable
    /// gradient path; backends without one (AOT/PJRT) log a warning and
    /// fall back to this fused single-replica loop.
    pub fn run(&self, seed: u64, train: &Dataset, test: &Dataset) -> Result<RunOutcome> {
        if self.cfg.replicas > 1 {
            if self.be.supports_grad_step(&self.cfg.spec) {
                return self.run_sharded(self.cfg.replicas, seed, train, test);
            }
            crate::warn_!(
                "[{}] backend '{}' has no separable gradient path; \
                 falling back to the fused single-replica step",
                self.cfg.spec,
                self.be.name()
            );
        }
        let cfg = self.cfg;
        let spec = self.be.spec(&cfg.spec)?.clone();
        let mut state = self.be.init_state(&cfg.spec, seed as u32)?;
        let mut batcher = Batcher::new(train, spec.batch, seed ^ 0xBA7C4, true);
        let mut lp = RunLoop::new(self, spec, batcher.batches_per_epoch())?;

        let sw = crate::util::Stopwatch::start();
        for step in 0..cfg.steps {
            let batch = batcher.next_batch()?;
            let hyper = lp.hyper(cfg, step)?;
            let metrics = self.be.train_step(&mut state, &batch.x, &batch.y, &hyper)?;
            lp.after_step(self, &mut state, step, seed, &metrics, test)?;
        }
        lp.finish(self, state, test, sw)
    }

    /// The data-parallel run loop: batches come from the pure
    /// [`crate::data::ShardPlan`] and every step runs through the
    /// [`crate::train::DataParallelTrainer`], so the whole run — final
    /// parameters, optimizer state, metric stream, RigL decisions — is a
    /// pure function of (spec, seed, data, hyper) for **any** replica
    /// count ≥ 1. Public so the bit-exactness suite and the scaling bench
    /// can drive it at R = 1 as the comparison baseline. (The fused
    /// `replicas == 1` path keeps the historical `Batcher` order, so it
    /// matches this driver statistically, not bitwise.)
    pub fn run_sharded(
        &self,
        replicas: usize,
        seed: u64,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<RunOutcome> {
        let cfg = self.cfg;
        let spec = self.be.spec(&cfg.spec)?.clone();
        let mut state = self.be.init_state(&cfg.spec, seed as u32)?;
        let dp = crate::train::DataParallelTrainer::new(self.be, &cfg.spec, replicas)?;
        // one source of truth for the micro-shard width: the plan splits
        // batches exactly as wide as the driver expects
        let mut plan = crate::data::ShardPlan::new(seed ^ 0xBA7C4, train.n, spec.batch)?
            .with_shard_width(dp.shard_width());
        let mut lp = RunLoop::new(self, spec, plan.steps_per_epoch())?;

        let sw = crate::util::Stopwatch::start();
        for step in 0..cfg.steps {
            let shards = plan
                .step_shards(step)
                .iter()
                .map(|idx| crate::data::assemble_batch(train, idx))
                .collect::<Result<Vec<_>>>()?;
            let hyper = lp.hyper(cfg, step)?;
            let metrics = dp.step_shards(&mut state, &shards, &hyper)?;
            lp.after_step(self, &mut state, step, seed, &metrics, test)?;
        }
        lp.finish(self, state, test, sw)
    }

    /// Full-test-set evaluation. Returns (accuracy %, mean loss, per-pattern
    /// accuracies % for pattern specs).
    ///
    /// Backends that accept variable batch sizes (the native backend) get a
    /// trailing partial batch so *every* test example is scored; fixed-batch
    /// backends (AOT/PJRT executables) keep full batches only. The mean loss
    /// is weighted by batch size, so a partial tail cannot skew it.
    pub fn evaluate(
        &self,
        state: &TrainState,
        spec: &crate::manifest::SpecEntry,
        test: &Dataset,
    ) -> Result<(f64, f64, Vec<f64>)> {
        let batches =
            crate::data::eval_batches(test, spec.batch, !self.be.fixed_batch());
        if batches.is_empty() {
            bail!("test set smaller than one batch ({} < {})", test.n, spec.batch);
        }
        let k = spec.num_patterns().unwrap_or(0);
        let mut total = 0usize;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut pat_correct = vec![0.0f64; k];
        for idx in &batches {
            let b = crate::data::assemble_batch(test, idx)?;
            let m = self.be.eval_step(state, &b.x, &b.y)?;
            let weight = b.size as f64;
            if k > 0 {
                // pattern eval layout: [ce_0..ce_{k-1}, acc_0..acc_{k-1}]
                for p in 0..k {
                    loss_sum += m[p] as f64 * weight / k as f64;
                    pat_correct[p] += m[k + p] as f64;
                }
            } else {
                loss_sum += m[0] as f64 * weight;
                correct += m[1] as f64;
            }
            total += b.size;
        }
        // LMs count per-token accuracy
        let denom = if spec.input_dtype == crate::tensor::DType::I32 {
            (total * spec.input_shape[0]) as f64
        } else {
            total as f64
        };
        let loss = loss_sum / total as f64;
        if k > 0 {
            let accs: Vec<f64> =
                pat_correct.iter().map(|c| 100.0 * c / denom).collect();
            let best = accs.iter().cloned().fold(f64::MIN, f64::max);
            Ok((best, loss, accs))
        } else {
            Ok((100.0 * correct / denom, loss, vec![]))
        }
    }
}

/// Map manifest hyper names to config values (via the shared
/// [`HyperParam`] vocabulary, so this cannot drift from backend parsing).
fn build_hyper(names: &[String], lam: f64, lam2: f64, lr: f64) -> Result<Vec<f32>> {
    names
        .iter()
        .map(|n| {
            Ok(match HyperParam::parse(n)? {
                HyperParam::Lambda1 => lam as f32,
                HyperParam::Lambda2 => lam2 as f32,
                HyperParam::Lr => lr as f32,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hyper_mapping() {
        let names: Vec<String> =
            ["lambda1", "lambda2", "lr"].iter().map(|s| s.to_string()).collect();
        let h = build_hyper(&names, 0.01, 0.001, 0.1).unwrap();
        assert_eq!(h, vec![0.01, 0.001, 0.1]);
        assert!(build_hyper(&["bogus".to_string()], 0.0, 0.0, 0.0).is_err());
    }
}
