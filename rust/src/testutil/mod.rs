//! Property-testing mini-framework (no proptest in the offline cache).
//!
//! Usage:
//! ```ignore
//! prop_check("kron reconstruction", 100, |g| {
//!     let m1 = g.usize_in(1, 4);
//!     ...
//!     prop_assert!(cond, "message {x}");
//!     Ok(())
//! });
//! ```
//! Each case gets a deterministic seed derived from the property name and
//! the case index, so failures print a reproducible case id. On failure we
//! re-run with the same seed to confirm, then panic with the case id —
//! simple deterministic replay instead of shrinking.

use crate::util::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick a divisor of x uniformly.
    pub fn divisor_of(&mut self, x: usize) -> usize {
        let ds = crate::blockopt::divisors(x).expect("divisor_of wants x ≥ 1");
        ds[self.rng.below(ds.len())]
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the property name
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` random cases of `prop`. `prop` returns Err(msg) on failure.
pub fn prop_check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = prop(&mut g) {
            // deterministic replay to rule out flaky environment effects
            let mut g2 = Gen { rng: Rng::new(seed), case };
            let second = prop(&mut g2);
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\
                 \nreplay: {}",
                match second {
                    Err(m) => format!("reproduces ({m})"),
                    Ok(()) => "DID NOT reproduce (nondeterministic property!)".into(),
                }
            );
        }
    }
}

/// assert! that returns Err instead of panicking (for use inside props).
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float comparison helper for properties.
pub fn close(a: f32, b: f32, atol: f32, rtol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0usize);
        prop_check("always-true", 50, |g| {
            counter.set(counter.get() + 1);
            let x = g.usize_in(1, 10);
            prop_assert!((1..=10).contains(&x), "range");
            Ok(())
        });
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_case() {
        prop_check("always-false", 10, |_g| Err("nope".to_string()));
    }

    #[test]
    fn gen_divisor() {
        prop_check("divisor divides", 100, |g| {
            let x = g.usize_in(1, 500);
            let d = g.divisor_of(x);
            prop_assert!(x % d == 0, "{d} !| {x}");
            Ok(())
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-6, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 1e-5));
    }
}
