//! `blocksparse` — an efficient training framework for block-wise sparse
//! models via Kronecker product decomposition (KPD).
//!
//! Reproduction of *"An Efficient Training Algorithm for Models with
//! Block-wise Sparsity"* (Zhu, Zuo, Khalili; 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas KPD-forward and block-
//!   sparse-matmul kernels, interpret-mode for the CPU PJRT plugin.
//! * **L2** (`python/compile/`): JAX models (linear / LeNet-5 / ViT /
//!   transformer-LM), the paper's method + all baselines as pure train-step
//!   functions, AOT-lowered to HLO text once at build time.
//! * **L3** (this crate): the coordinator that owns the training loop —
//!   data pipeline, PJRT execution, regularization schedules, RigL/pruning
//!   controllers, pattern selection, sparsity/FLOPs accounting, metrics.
//!
//! Python never runs at training time: `make artifacts` lowers everything
//! to `artifacts/*.hlo.txt` + `manifest.json`, and the rust binary is then
//! self-contained.

pub mod bench;
pub mod blockopt;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod manifest;
pub mod metrics;
pub mod runtime;
pub mod sparsity;
pub mod tensor;
pub mod testutil;
pub mod util;

/// Default artifact directory, overridable via `BLOCKSPARSE_ARTIFACTS`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("BLOCKSPARSE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
