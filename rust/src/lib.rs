//! `blocksparse` — an efficient training framework for block-wise sparse
//! models via Kronecker product decomposition (KPD).
//!
//! Reproduction of *"An Efficient Training Algorithm for Models with
//! Block-wise Sparsity"* (Zhu, Zuo, Khalili; 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas KPD-forward and block-
//!   sparse-matmul kernels, interpret-mode for the CPU PJRT plugin.
//! * **L2** (`python/compile/`): JAX models (linear / LeNet-5 / ViT /
//!   transformer-LM), the paper's method + all baselines as pure train-step
//!   functions, AOT-lowered to HLO text once at build time.
//! * **L3** (this crate): the coordinator that owns the training loop —
//!   data pipeline, execution *backends*, regularization schedules,
//!   RigL/pruning controllers, pattern selection, sparsity/FLOPs
//!   accounting, metrics.
//!
//! Execution in L3 goes through the [`backend::Backend`] trait, which has
//! two implementations:
//!
//! * [`backend::native::NativeBackend`] — the **default**: a pure-Rust,
//!   multi-threaded engine implementing the paper's methods (factorized
//!   KPD forward/backward, ℓ1-on-S proximal update, group-LASSO prox,
//!   blockwise RigL, magnitude pruning, SGD/momentum) on **one
//!   composable layer graph** (`backend::native::layers`: named linear
//!   slots with per-slot block sizes, method-dispatched
//!   forward/backward, fused apply, flat grad layouts). All native
//!   model families are thin drivers over those slot primitives —
//!   single-slot linear, the `mlp` stacks behind the Table-2 `t2_*`
//!   specs, joint multi-pattern block-size selection
//!   (`backend::native::pattern`, Eq. 7 / Figure 3), and the `t3_*`
//!   pre-LN causal transformers (`backend::native::transformer`:
//!   block-sparse q/k/v/o/FFN projection slots plus dense
//!   embedding/LayerNorm/head extras, Table 3).
//!   It is manifest-free and hermetic, so `cargo build && cargo test` and
//!   the benches run offline with no python, artifacts, or PJRT plugin.
//! * `backend::pjrt::PjrtBackend` — the AOT path (`--features pjrt`):
//!   `make artifacts` lowers the L2 graphs to `artifacts/*.hlo.txt` +
//!   `manifest.json`, and the `runtime` module executes them through
//!   PJRT with zero re-marshalling on the hot path. The `xla` dependency
//!   only enters the dependency graph when the feature is enabled.
//!
//! Training scales out through the [`train`] subsystem: the `Backend`
//! contract is split into `grad_step` (per-shard forward/backward → flat
//! gradient sums) and `apply_update` (optimizer + prox), and
//! `train::DataParallelTrainer` shards every batch across R replica
//! workers with a fixed-order pairwise tree reduction — bit-identical to
//! a single worker for any R (`--replicas`, `TrainConfig.replicas`;
//! PJRT falls back to the fused single-replica step).
//!
//! Past training, the [`infer`] subsystem closes the loop on the paper's
//! inference claim: `infer::export` packs any trained spec into a BSR
//! (block-sparse-row) model artifact (versioned, CRC-guarded,
//! atomically published on disk), `infer::bsr` runs gather-free
//! block-GEMM forward kernels whose cost scales with occupancy,
//! `infer::engine` serves them behind a **bounded** admission queue with
//! dynamic micro-batching, typed load-shed under overload and atomic
//! model hot-swap, and `infer::registry` keys engines by model name —
//! the CLI's `export` / `infer` subcommands and
//! `benches/infer_serve.rs` drive it.
//!
//! The [`blockopt`] subsystem closes the paper's *other* loop — choosing
//! the block size against real hardware. Its root holds the analytic
//! Eq. 5 solver (rank-generalized, exact branch-and-bound over the
//! divisor grid) and the §5 pattern enumeration; `blockopt::cost`
//! calibrates a per-block-shape latency model by timing the `infer::bsr`
//! kernels (serialized to a versioned `BSCM` JSON artifact);
//! `blockopt::sweep` runs one short joint `pattern_kpd` training pass,
//! prices every candidate's slot stack, and extracts the (retention ↑,
//! predicted latency ↓) Pareto front (`blockopt::pareto`) with a
//! recommendation under a latency budget. The CLI's `blockopt
//! calibrate | sweep | recommend` sub-verbs and
//! `benches/blockopt_sweep.rs` (gated in CI) drive it.
//!
//! See `rust/README.md` for the backend/feature matrix and offline
//! test/bench instructions.

pub mod backend;
pub mod bench;
pub mod blockopt;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod infer;
pub mod manifest;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sparsity;
pub mod tensor;
pub mod testutil;
pub mod train;
pub mod util;

/// Default artifact directory, overridable via `BLOCKSPARSE_ARTIFACTS`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("BLOCKSPARSE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
