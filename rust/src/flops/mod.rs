//! FLOPs & parameter accounting — the paper's Propositions 2 & 3 in code.
//!
//! Every table reports "Training Params" and "Training FLOPs" columns;
//! the paper computed them with `ptflops`, we compute them exactly from
//! the closed forms derived in Appendix A.1/A.2. Dense layers use the
//! full-matrix counts; KPD layers use the factorized counts; per-model
//! totals sum over slots (other backbone ops are identical across methods
//! within a table row, so they cancel in the comparisons — we still add
//! them for absolute numbers via `backbone_flops`).

/// KPD factorization dimensions of one layer (paper Eq. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KpdDims {
    pub m1: usize,
    pub n1: usize,
    pub m2: usize,
    pub n2: usize,
    pub r: usize,
}

impl KpdDims {
    pub fn m(&self) -> usize {
        self.m1 * self.m2
    }

    pub fn n(&self) -> usize {
        self.n1 * self.n2
    }

    /// From a weight shape and block size, clamping rank like the L2 side.
    pub fn from_block(m: usize, n: usize, m2: usize, n2: usize, r: usize) -> Self {
        assert!(m % m2 == 0 && n % n2 == 0, "block ({m2},{n2}) !| ({m},{n})");
        let (m1, n1) = (m / m2, n / n2);
        Self { m1, n1, m2, n2, r: r.min(m1 * n1).min(m2 * n2) }
    }

    /// Trainable parameters: S + r·(A + B)   (paper §4, Example 1).
    pub fn train_params(&self) -> u64 {
        let g = (self.m1 * self.n1) as u64;
        g + self.r as u64 * (g + (self.m2 * self.n2) as u64)
    }
}

// ----------------------------------------------------------------------
// Proposition 2 (linear layer, batch N) — exact counts from Appendix A.1
// ----------------------------------------------------------------------

/// Forward FLOPs of the dense linear loss  J(W; D):
/// N·m·(2n−1) + (3·N·m − 1)          (Eqs. 8–10)
pub fn dense_forward_flops(n_batch: u64, m: u64, n: u64) -> u64 {
    n_batch * m * (2 * n - 1) + 3 * n_batch * m - 1
}

/// Backward FLOPs of the dense linear loss: N·m + m·n·(2N−1)   (Eq. 13)
pub fn dense_backward_flops(n_batch: u64, m: u64, n: u64) -> u64 {
    n_batch * m + m * n * (2 * n_batch - 1)
}

/// Forward FLOPs of the factorized loss (Eq. 18, exact pre-O() form):
/// r·(N·n1·m2·(2n2−1) + m1·n1 + N·m1·m2·(2n1−1)) + (r−1)·N·m + 3·N·m − 1
pub fn kpd_forward_flops(n_batch: u64, d: KpdDims) -> u64 {
    let (m1, n1, m2, n2, r) =
        (d.m1 as u64, d.n1 as u64, d.m2 as u64, d.n2 as u64, d.r as u64);
    let m = m1 * m2;
    let per_rank = n_batch * n1 * m2 * (2 * n2 - 1)
        + m1 * n1
        + n_batch * m1 * m2 * (2 * n1 - 1);
    r * per_rank + (r - 1) * n_batch * m + 3 * n_batch * m - 1
}

/// Backward FLOPs of the factorized loss (Eq. 25, exact pre-O() form):
/// N·m + r·m1·n1·(2N·m2−1) + r·m1·n1 + (r−1)·m1·n1 + r·m1·n1
///  + r·N·m2·n1·(2m1−1) + r·m2·n2·(2N·n1−1)
pub fn kpd_backward_flops(n_batch: u64, d: KpdDims) -> u64 {
    let (m1, n1, m2, n2, r) =
        (d.m1 as u64, d.n1 as u64, d.m2 as u64, d.n2 as u64, d.r as u64);
    let m = m1 * m2;
    n_batch * m
        + r * m1 * n1 * (2 * n_batch * m2 - 1)
        + r * m1 * n1
        + (r - 1) * m1 * n1
        + r * m1 * n1
        + r * n_batch * m2 * n1 * (2 * m1 - 1)
        + r * m2 * n2 * (2 * n_batch * n1 - 1)
}

/// Parameter-update FLOPs per step (the §4 discussion after Prop. 2):
/// dense: O(m·n); KPD: O(r·(m1·n1 + m2·n2)) + S.
pub fn dense_update_flops(m: u64, n: u64) -> u64 {
    m * n
}

pub fn kpd_update_flops(d: KpdDims) -> u64 {
    d.train_params()
}

/// One full training step (fwd + bwd + update) for a dense linear slot.
pub fn dense_step_flops(n_batch: u64, m: u64, n: u64) -> u64 {
    dense_forward_flops(n_batch, m, n)
        + dense_backward_flops(n_batch, m, n)
        + dense_update_flops(m, n)
}

/// One full training step for a KPD slot.
pub fn kpd_step_flops(n_batch: u64, d: KpdDims) -> u64 {
    kpd_forward_flops(n_batch, d) + kpd_backward_flops(n_batch, d) + kpd_update_flops(d)
}

// ----------------------------------------------------------------------
// Model-level accounting
// ----------------------------------------------------------------------

/// One factorizable slot of a model, with the method-dependent counts.
#[derive(Clone, Debug)]
pub struct SlotCost {
    pub name: String,
    pub train_params: u64,
    pub step_flops: u64,
}

/// Sum training params + per-step FLOPs across a model's slots under the
/// dense parameterization (group LASSO / elastic GL / RigL / pruning all
/// train the dense W — the paper's Tables 1–3 show identical columns for
/// those baselines).
pub fn dense_model_cost(n_batch: u64, slots: &[(String, usize, usize)]) -> Vec<SlotCost> {
    slots
        .iter()
        .map(|(name, m, n)| SlotCost {
            name: name.clone(),
            train_params: (*m as u64) * (*n as u64),
            step_flops: dense_step_flops(n_batch, *m as u64, *n as u64),
        })
        .collect()
}

/// KPD parameterization cost per slot.
pub fn kpd_model_cost(n_batch: u64, slots: &[(String, KpdDims)]) -> Vec<SlotCost> {
    slots
        .iter()
        .map(|(name, d)| SlotCost {
            name: name.clone(),
            train_params: d.train_params(),
            step_flops: kpd_step_flops(n_batch, *d),
        })
        .collect()
}

pub fn total_params(costs: &[SlotCost]) -> u64 {
    costs.iter().map(|c| c.train_params).sum()
}

pub fn total_flops(costs: &[SlotCost]) -> u64 {
    costs.iter().map(|c| c.step_flops).sum()
}

// ----------------------------------------------------------------------
// Proposition 3 (two-layer network) — used by the property tests to
// cross-check the slot-summing approach against the paper's closed form.
// ----------------------------------------------------------------------

/// Dense two-layer forward: 2N·m1·m2 + 2N·m2·m3 + 2N·m3 − 1   (Eq. 29)
pub fn dense2_forward_flops(n_batch: u64, m1: u64, m2: u64, m3: u64) -> u64 {
    2 * n_batch * m1 * m2 + 2 * n_batch * m2 * m3 + 2 * n_batch * m3 - 1
}

/// Dense two-layer backward (Eq. 35):
/// 2N·m1·m2 + 4N·m2·m3 + N·m3 − m1·m2 − m2·m3
pub fn dense2_backward_flops(n_batch: u64, m1: u64, m2: u64, m3: u64) -> u64 {
    2 * n_batch * m1 * m2 + 4 * n_batch * m2 * m3 + n_batch * m3 - m1 * m2 - m2 * m3
}

/// Inference FLOPs of a block-sparse matmul with `nnz` surviving blocks —
/// the §4 claim that inference cost scales with the sparsity rate.
pub fn block_sparse_infer_flops(n_batch: u64, m2: u64, n2: u64, nnz_blocks: u64) -> u64 {
    2 * n_batch * m2 * n2 * nnz_blocks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example1_param_counts() {
        // Paper Example 1: m=2^3, n=2^8, m1=4, n1=8, m2=2, n2=32, r=1
        // → 128 trainable params (vs 2048 dense). Paper counts 2·m1·n1+m2·n2
        // (S shares A's grid); our count includes S explicitly: 32+32+64=128.
        let d = KpdDims { m1: 4, n1: 8, m2: 2, n2: 32, r: 1 };
        assert_eq!(d.train_params(), 128);
        assert_eq!(d.m() as u64 * d.n() as u64, 2048);
    }

    #[test]
    fn table1_dense_params() {
        // 10×784 linear layer = 7840 ≈ the paper's "7.84K" column
        let costs = dense_model_cost(128, &[("fc".into(), 10, 784)]);
        assert_eq!(total_params(&costs), 7840);
    }

    #[test]
    fn kpd_beats_dense_at_paper_shapes() {
        // Table 1 block (16,2) → (m2,n2)=(2,16), rank 2 on 10×784: params
        // fall below 1K (paper: 0.80K) and step FLOPs beat dense. At the
        // finest (2,2) block the factorized forward is NOT cheaper (n1=392
        // dominates) — the paper's Table 1 shows the same: (2,2) FLOPs ≈
        // dense, the win grows with block size.
        let d = KpdDims::from_block(10, 784, 2, 16, 2);
        assert!(d.train_params() < 1000, "{}", d.train_params());
        let nb = 128;
        assert!(kpd_step_flops(nb, d) < dense_step_flops(nb, 10, 784));
        // and the win is monotone in block width here
        let d8 = KpdDims::from_block(10, 784, 2, 8, 2);
        assert!(kpd_step_flops(nb, d) < kpd_step_flops(nb, d8));
    }

    #[test]
    fn forward_flops_match_big_o_scaling() {
        // doubling N should ~double both counts (leading terms linear in N)
        let d = KpdDims::from_block(120, 400, 8, 16, 5);
        let f1 = kpd_forward_flops(64, d) as f64;
        let f2 = kpd_forward_flops(128, d) as f64;
        assert!((f2 / f1 - 2.0).abs() < 0.05);
        let b1 = kpd_backward_flops(64, d) as f64;
        let b2 = kpd_backward_flops(128, d) as f64;
        assert!((b2 / b1 - 2.0).abs() < 0.05);
    }

    #[test]
    fn rank_clamped() {
        let d = KpdDims::from_block(10, 84, 2, 2, 5);
        assert_eq!(d.r, 4); // min(m1·n1=210, m2·n2=4)
    }

    #[test]
    fn prop3_consistency() {
        // slot-sum dense fwd ≈ Prop-3 closed form (within the activation
        // and loss bookkeeping terms, which are O(N·m))
        let (nb, m1, m2, m3) = (64u64, 784u64, 120u64, 10u64);
        let slots = vec![("l1".to_string(), m2 as usize, m1 as usize),
                         ("l2".to_string(), m3 as usize, m2 as usize)];
        let sum: u64 = slots
            .iter()
            .map(|(_, m, n)| dense_forward_flops(nb, *m as u64, *n as u64))
            .sum();
        let closed = dense2_forward_flops(nb, m1, m2, m3);
        let rel = (sum as f64 - closed as f64).abs() / closed as f64;
        assert!(rel < 0.02, "rel={rel}");
    }

    #[test]
    fn block_sparse_inference_scales_with_nnz() {
        let full = block_sparse_infer_flops(32, 4, 4, 100);
        let half = block_sparse_infer_flops(32, 4, 4, 50);
        assert_eq!(full, 2 * half);
    }
}
