//! Typed view over `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the build-time python layer
//! and the runtime: executable files, their exact argument order (pytree
//! flatten order), shapes/dtypes, hyper-parameter names, metric names, and
//! the per-spec method metadata (block sizes, rank, slot dimensions) that
//! drives the FLOPs accounting.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::Json;

/// The hyper-parameter vocabulary of `SpecEntry::hyper`. Both sides of the
/// `Backend` boundary — the trainer building the per-step hyper vector and
/// a backend parsing it — resolve names through this single mapping, so
/// the alias set ("lambda" ≡ "lambda1") cannot drift between them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HyperParam {
    /// Primary regularizer weight: "lambda" or "lambda1".
    Lambda1,
    /// Secondary regularizer weight (elastic ridge term): "lambda2".
    Lambda2,
    /// Learning rate: "lr".
    Lr,
}

impl HyperParam {
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "lambda" | "lambda1" => Ok(HyperParam::Lambda1),
            "lambda2" => Ok(HyperParam::Lambda2),
            "lr" => Ok(HyperParam::Lr),
            other => bail!("unknown hyper-parameter '{other}' in manifest"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSlot {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSlot {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// "param:fc.S" -> Some("fc.S")
    pub fn param_key(&self) -> Option<&str> {
        self.name.strip_prefix("param:")
    }

    pub fn opt_key(&self) -> Option<&str> {
        self.name.strip_prefix("opt:")
    }
}

#[derive(Clone, Debug)]
pub struct ExecEntry {
    pub spec: String,
    pub exec: String,
    pub file: String,
    pub inputs: Vec<IoSlot>,
    pub outputs: Vec<IoSlot>,
    pub hyper: Vec<String>,
    pub metrics: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct SlotInfo {
    pub name: String,
    pub m: usize,
    pub n: usize,
}

#[derive(Clone, Debug)]
pub struct SpecEntry {
    pub key: String,
    pub model: String,
    pub batch: usize,
    pub tags: Vec<String>,
    pub input_shape: Vec<usize>,
    pub input_dtype: DType,
    pub num_classes: usize,
    pub slots: Vec<SlotInfo>,
    pub method: String,
    pub hyper: Vec<String>,
    pub metrics: Vec<String>,
    pub params_total: usize,
    /// raw "info" blob (block sizes, rank, patterns, …)
    pub info: Json,
}

impl SpecEntry {
    /// Per-slot (m2, n2) block size, when the method defines one.
    pub fn block_of(&self, slot: &str) -> Option<(usize, usize)> {
        let blocks = self.info.get("blocks")?;
        let arr = blocks.get(slot)?.as_arr()?;
        Some((arr[0].as_usize()?, arr[1].as_usize()?))
    }

    pub fn rank(&self) -> Option<usize> {
        self.info.get("rank").and_then(Json::as_usize)
    }

    pub fn num_patterns(&self) -> Option<usize> {
        self.info.get("num_patterns").and_then(Json::as_usize)
    }

    pub fn metric_index(&self, name: &str) -> Option<usize> {
        self.metrics.iter().position(|m| m == name)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub specs: BTreeMap<String, SpecEntry>,
    pub executables: BTreeMap<(String, String), ExecEntry>,
}

fn parse_io(j: &Json) -> Result<IoSlot> {
    Ok(IoSlot {
        name: j.req_str("name")?.to_string(),
        shape: j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape")))
            .collect::<Result<_>>()?,
        dtype: DType::parse(j.req_str("dtype")?)?,
    })
}

fn parse_strs(j: Option<&Json>) -> Vec<String> {
    j.and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut specs = BTreeMap::new();
        for s in j.req_arr("specs")? {
            let entry = SpecEntry {
                key: s.req_str("key")?.to_string(),
                model: s.req_str("model")?.to_string(),
                batch: s.req_usize("batch")?,
                tags: parse_strs(s.get("tags")),
                input_shape: s
                    .req_arr("input_shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                input_dtype: DType::parse(s.req_str("input_dtype")?)?,
                num_classes: s.req_usize("num_classes")?,
                slots: s
                    .req_arr("slots")?
                    .iter()
                    .map(|v| {
                        Ok(SlotInfo {
                            name: v.req_str("name")?.to_string(),
                            m: v.req_usize("m")?,
                            n: v.req_usize("n")?,
                        })
                    })
                    .collect::<Result<_>>()?,
                method: s.req_str("method")?.to_string(),
                hyper: parse_strs(s.get("hyper")),
                metrics: parse_strs(s.get("metrics")),
                params_total: s.req_usize("params_total")?,
                info: s.get("info").cloned().unwrap_or(Json::Null),
            };
            specs.insert(entry.key.clone(), entry);
        }

        let mut executables = BTreeMap::new();
        for e in j.req_arr("executables")? {
            let entry = ExecEntry {
                spec: e.req_str("spec")?.to_string(),
                exec: e.req_str("exec")?.to_string(),
                file: e.req_str("file")?.to_string(),
                inputs: e.req_arr("inputs")?.iter().map(parse_io).collect::<Result<_>>()?,
                outputs: e.req_arr("outputs")?.iter().map(parse_io).collect::<Result<_>>()?,
                hyper: parse_strs(e.get("hyper")),
                metrics: parse_strs(e.get("metrics")),
            };
            executables.insert((entry.spec.clone(), entry.exec.clone()), entry);
        }

        Ok(Self { dir, specs, executables })
    }

    pub fn spec(&self, key: &str) -> Result<&SpecEntry> {
        self.specs
            .get(key)
            .ok_or_else(|| anyhow!("spec '{key}' not in manifest (rebuild artifacts?)"))
    }

    pub fn exec(&self, spec: &str, exec: &str) -> Result<&ExecEntry> {
        self.executables
            .get(&(spec.to_string(), exec.to_string()))
            .ok_or_else(|| anyhow!("executable '{spec}.{exec}' not in manifest"))
    }

    pub fn hlo_path(&self, entry: &ExecEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn specs_with_tag(&self, tag: &str) -> Vec<&SpecEntry> {
        self.specs.values().filter(|s| s.tags.iter().any(|t| t == tag)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> &'static str {
        r#"{
          "version": 1,
          "specs": [{
            "key": "t", "model": "linear", "batch": 4, "tags": ["x"],
            "input_shape": [8], "input_dtype": "f32", "num_classes": 2,
            "slots": [{"name": "fc", "m": 2, "n": 8}],
            "method": "kpd", "hyper": ["lambda", "lr"],
            "metrics": ["loss"], "params_total": 10,
            "info": {"rank": 2, "blocks": {"fc": [2, 4]}}
          }],
          "executables": [{
            "spec": "t", "exec": "train_step", "file": "t.train_step.hlo.txt",
            "inputs": [{"name": "param:fc.S", "shape": [1, 2], "dtype": "f32"}],
            "outputs": [{"name": "metrics", "shape": [1], "dtype": "f32"}],
            "hyper": ["lambda", "lr"], "metrics": ["loss"]
          }]
        }"#
    }

    #[test]
    fn hyper_param_aliases() {
        assert_eq!(HyperParam::parse("lambda").unwrap(), HyperParam::Lambda1);
        assert_eq!(HyperParam::parse("lambda1").unwrap(), HyperParam::Lambda1);
        assert_eq!(HyperParam::parse("lambda2").unwrap(), HyperParam::Lambda2);
        assert_eq!(HyperParam::parse("lr").unwrap(), HyperParam::Lr);
        assert!(HyperParam::parse("bogus").is_err());
    }

    #[test]
    fn parse_mini() {
        let dir = std::env::temp_dir().join("bs_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let s = m.spec("t").unwrap();
        assert_eq!(s.batch, 4);
        assert_eq!(s.block_of("fc"), Some((2, 4)));
        assert_eq!(s.rank(), Some(2));
        let e = m.exec("t", "train_step").unwrap();
        assert_eq!(e.inputs[0].param_key(), Some("fc.S"));
        assert_eq!(e.inputs[0].elements(), 2);
        assert!(m.exec("t", "nope").is_err());
        assert_eq!(m.specs_with_tag("x").len(), 1);
    }
}
