//! Shared driver for the paper-table benches (`rust/benches/*.rs`).
//!
//! Each bench regenerates one table/figure: it trains the relevant specs
//! through the coordinator with per-method hyper-parameters, prints the
//! paper-style rows next to the paper's reference values, and appends the
//! measured rows to `bench_results/results.jsonl` for EXPERIMENTS.md.
//!
//! Scale knobs (env): BS_STEPS, BS_SEEDS, BS_TRAIN_N, BS_TEST_N, plus
//! BS_REPLICAS (>1 routes every run through the data-parallel sharded
//! trainer — the CI smoke gate drives the table2 panel this way) — the
//! defaults keep a full `cargo bench` run in CPU-budget; EXPERIMENTS.md
//! records which settings produced the committed numbers.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::backend::Backend;
use crate::config::TrainConfig;
use crate::coordinator::{run_spec, SpecResult};
use crate::util::human_count;
use crate::util::json::Json;

/// Per-method regularizer defaults calibrated on the synthetic datasets
/// (see EXPERIMENTS.md §Calibration): chosen so every sparsifying method
/// lands near the paper's ~50% sparsity operating point. Pattern specs
/// get the paper's Eq. 7 values here; `BenchEnv::config` then applies the
/// native gauge calibration on top when the backend is native.
pub fn default_lambda(method: &str) -> (f64, f64) {
    match method {
        "kpd" => (0.008, 1e-4),
        // prox threshold carries a sqrt(block-size) weighting; 0.02 lands
        // ~50% block sparsity across Table-1/2 block sizes
        "group_lasso" => (0.03, 0.0),
        "elastic_gl" => (0.03, 1e-3),
        m if m.starts_with("pattern") => (0.01, 0.01), // paper's λ1 = λ2
        _ => (0.0, 0.0), // dense / rigl / prune: no regularizer input
    }
}

pub struct BenchEnv {
    pub steps: usize,
    pub seeds: Vec<u64>,
    pub train_n: usize,
    pub test_n: usize,
    pub replicas: usize,
}

impl BenchEnv {
    /// Read scale knobs, with per-table defaults.
    pub fn from_env(default_steps: usize, default_seeds: usize,
                    train_n: usize, test_n: usize) -> Self {
        let steps = std::env::var("BS_STEPS").ok().and_then(|v| v.parse().ok())
            .unwrap_or(default_steps);
        let nseeds: usize = std::env::var("BS_SEEDS").ok().and_then(|v| v.parse().ok())
            .unwrap_or(default_seeds);
        let train_n = std::env::var("BS_TRAIN_N").ok().and_then(|v| v.parse().ok())
            .unwrap_or(train_n);
        let test_n = std::env::var("BS_TEST_N").ok().and_then(|v| v.parse().ok())
            .unwrap_or(test_n);
        let replicas = std::env::var("BS_REPLICAS").ok().and_then(|v| v.parse().ok())
            .unwrap_or(1usize).max(1);
        Self { steps, seeds: (0..nseeds as u64).collect(), train_n, test_n, replicas }
    }

    pub fn config(&self, be: &dyn Backend, spec_key: &str) -> Result<TrainConfig> {
        let spec = be.spec(spec_key)?;
        let (lam, lam2) = default_lambda(&spec.method);
        let cfg = crate::config::Config::default();
        let mut tc = TrainConfig::from_config(&cfg, spec_key);
        tc.steps = self.steps;
        tc.seeds = self.seeds.clone();
        tc.train_examples = self.train_n;
        tc.test_examples = self.test_n;
        tc.lambda = lam;
        tc.lambda2 = lam2;
        tc.replicas = self.replicas;
        if spec.method.starts_with("pattern") {
            crate::backend::native::pattern::calibrate_lambda(&mut tc, &be.name());
        }
        tc.eval_every = 0; // final eval only: benches want wall-clock purity
        Ok(tc)
    }
}

/// Train one spec and return the aggregated row.
pub fn run_row(be: &dyn Backend, env: &BenchEnv, spec_key: &str) -> Result<SpecResult> {
    let cfg = env.config(be, spec_key)?;
    run_spec(be, &cfg)
}

/// Train one spec, or skip (with a printed note) when the spec is not
/// available on this backend — e.g. a LeNet/ViT spec on the native
/// backend, or any spec when HLO artifacts are absent. Benches must keep
/// printing the rows they *can* produce instead of failing.
pub fn run_row_or_skip(
    be: &dyn Backend,
    env: &BenchEnv,
    spec_key: &str,
) -> Result<Option<SpecResult>> {
    if be.spec(spec_key).is_err() {
        println!("SKIP {spec_key}: not available on backend '{}'", be.name());
        return Ok(None);
    }
    run_row(be, env, spec_key).map(Some)
}

/// Append a measured row to bench_results/results.jsonl.
pub fn record_row(table: &str, label: &str, res: &SpecResult) -> Result<()> {
    let mut obj = BTreeMap::new();
    obj.insert("table".into(), Json::Str(table.into()));
    obj.insert("label".into(), Json::Str(label.into()));
    obj.insert("spec".into(), Json::Str(res.spec.clone()));
    obj.insert("method".into(), Json::Str(res.method.clone()));
    obj.insert("acc_mean".into(), Json::Num(res.acc_mean));
    obj.insert("acc_std".into(), Json::Num(res.acc_std));
    obj.insert("sparsity_mean".into(), Json::Num(res.sparsity_mean));
    obj.insert("sparsity_std".into(), Json::Num(res.sparsity_std));
    if res.layer_sparsity.len() > 1 {
        let mut layers = BTreeMap::new();
        for (name, mean, _) in &res.layer_sparsity {
            layers.insert(name.clone(), Json::Num(*mean));
        }
        obj.insert("layer_sparsity".into(), Json::Obj(layers));
    }
    obj.insert("train_params".into(), Json::Num(res.train_params as f64));
    obj.insert("step_flops".into(), Json::Num(res.step_flops as f64));
    obj.insert("wall_secs".into(), Json::Num(res.wall_secs));
    let mut w = crate::metrics::JsonlWriter::append(std::path::Path::new(
        "bench_results/results.jsonl",
    ))?;
    w.write(&Json::Obj(obj))?;
    Ok(())
}

/// Standard cells for one table row.
pub fn cells(label: &str, method: &str, res: &SpecResult,
             paper: Option<&str>) -> Vec<String> {
    let mut row = vec![
        label.to_string(),
        method.to_string(),
        crate::bench::pm(res.acc_mean, res.acc_std),
        crate::bench::pm(res.sparsity_mean, res.sparsity_std),
        human_count(res.train_params as f64),
        human_count(res.step_flops as f64),
    ];
    row.push(paper.unwrap_or("-").to_string());
    row
}

pub const ROW_HEADERS: [&str; 7] = [
    "Block size", "Method", "Accuracy %", "Sparsity %", "Train Params",
    "Train FLOPs/step", "Paper acc (ref)",
];

/// One-line per-layer sparsity breakdown ("fc1 41.2±1.0%  fc2 ..."), or
/// None for single-slot / pattern specs — the Table-2 benches print this
/// under each multi-layer row.
pub fn layer_breakdown(res: &SpecResult) -> Option<String> {
    if res.layer_sparsity.len() < 2 {
        return None;
    }
    Some(
        res.layer_sparsity
            .iter()
            .map(|(name, m, s)| format!("{name} {m:.1}±{s:.1}%"))
            .collect::<Vec<_>>()
            .join("  "),
    )
}
