//! Micro-benchmark harness substrate (no criterion in the offline cache).
//!
//! `cargo bench` targets are `harness = false` binaries that use this
//! module: warmup, fixed-duration or fixed-iteration sampling, and a
//! summary with mean / p50 / p95 / throughput. Also hosts `TableWriter`,
//! the paper-style row printer used by the table1..table4 benches.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn report(&self) -> String {
        format!(
            "{:40} {:>8} iters  mean {:>10.3} ms  p50 {:>10.3} ms  p95 {:>10.3} ms  ({:>8.1}/s)",
            self.name,
            self.iters,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.throughput_per_sec()
        )
    }
}

/// Nearest-rank percentile over an ascending-sorted sample: the smallest
/// value with at least p·N of the sample at or below it, i.e. index
/// ⌈p·N⌉ − 1. The single definition shared by the microbench stats here
/// and the serving-latency summary (`infer::engine::latency_summary`), so
/// p50/p95/p99 stay comparable across every BENCH_*.json.
///
/// The previous `round((N−1)·p)` interpolation was *not* nearest-rank —
/// on 100 sorted samples it reported the 51st value as p50, skewing every
/// recorded tail; see `percentile_is_nearest_rank` for the pinned table.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Run `f` for `warmup` iterations, then sample until `min_iters` AND
/// `min_time` are both satisfied.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time: Duration, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(min_iters.max(16));
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= min_iters && start.elapsed() >= min_time {
            break;
        }
        if samples.len() >= 1_000_000 {
            break; // safety valve
        }
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: percentile(&sorted, 0.50),
        p95_ns: percentile(&sorted, 0.95),
        min_ns: sorted[0],
        max_ns: *sorted.last().unwrap(),
    }
}

/// Convenience wrapper with repo-standard settings.
pub fn quick_bench<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench(name, 2, 10, Duration::from_millis(300), f)
}

/// Parse `--json <path>` / `--json=<path>` from a bench binary's
/// post-`--` args: `Some(path)` when given, `Some(default)` for a bare
/// `--json`, `None` when the flag is absent. One parser for every bench
/// that emits a BENCH_*.json, so the flag's semantics cannot drift
/// between them; each bench decides what an absent flag means (perf_micro
/// skips the write, infer_serve falls back to its default path).
pub fn json_arg(args: &[String], default: &str) -> Option<String> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--json" {
            return Some(it.next().cloned().unwrap_or_else(|| default.to_string()));
        }
        if let Some(p) = a.strip_prefix("--json=") {
            return Some(p.to_string());
        }
    }
    None
}

// --------------------------------------------------------------- tables

/// Fixed-width table printer for paper-style rows.
pub struct TableWriter {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub title: String,
}

impl TableWriter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 3 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format "mean ± std" like the paper's table cells.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("spin", 1, 5, Duration::from_millis(1), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.min_ns <= s.max_ns);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        // canonical nearest-rank table on 1..=100
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.00), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0); // rank 0 clamps to the minimum
        // N = 5 (the Wikipedia nearest-rank example shape):
        // ceil(0.30·5) = 2 → 2nd value; ceil(0.40·5) = 2 as well
        let v5 = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile(&v5, 0.30), 20.0);
        assert_eq!(percentile(&v5, 0.40), 20.0);
        assert_eq!(percentile(&v5, 0.50), 35.0);
        assert_eq!(percentile(&v5, 1.00), 50.0);
        // single sample: every percentile is that sample
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        // empty sample: NaN sentinel (serialized as null by num_or_null)
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn json_arg_forms() {
        let sv = |xs: &[&str]| xs.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(json_arg(&sv(&["--json", "out.json"]), "d.json"),
                   Some("out.json".to_string()));
        assert_eq!(json_arg(&sv(&["--json=inline.json"]), "d.json"),
                   Some("inline.json".to_string()));
        assert_eq!(json_arg(&sv(&["--json"]), "d.json"), Some("d.json".to_string()));
        assert_eq!(json_arg(&sv(&["linear", "--bench"]), "d.json"), None);
    }

    #[test]
    fn table_render() {
        let mut t = TableWriter::new("T", &["a", "bb"]);
        t.row(vec!["x".into(), "y".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("a"));
        assert!(r.contains("x"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = TableWriter::new("T", &["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }
}
pub mod driver;
