//! `blocksparse` CLI — the L3 launcher.
//!
//! Subcommands:
//!   list                              show every spec the backend can run
//!   train    --spec KEY [...]         multi-seed training run + summary row
//!   pattern  --spec KEY [...]         pattern-selection run (Figure 3):
//!                                     prints the per-pattern ‖S‖₁ series
//!   export   --spec KEY --out F.bsm   train (or --ckpt restore) + pack the
//!                                     model into a BSR inference artifact
//!   infer    --model F.bsm [...]      serve the artifact through the
//!                                     batched engine; latency percentiles
//!   flops    --spec KEY | --m --n..   Prop. 2/3 accounting
//!   blockopt --m M --n N [--rank R]   Eq. 5 optimal block size
//!   blockopt calibrate [...]          time the BSR kernels across block
//!                                     shapes × occupancies, fit + save
//!                                     the hardware cost model artifact
//!   blockopt sweep [--spec KEY ..]    hardware-in-the-loop search: short
//!                                     joint training run + cost model →
//!                                     Pareto front, pick under --budget-ms
//!   blockopt recommend --cost-model F design-space recommendation for
//!                                     --m/--n from a saved cost model
//!   bench-step --spec KEY             one-step latency microbench
//!
//! Backend selection: `--backend native|pjrt`, default auto (PJRT when the
//! build has `--features pjrt` and artifacts exist, else the pure-Rust
//! native backend).
//!
//! Examples:
//!   blocksparse train --spec t1_kpd_b2x2 --steps 600 --seeds 0,1,2
//!   blocksparse train --spec qs_kpd --steps 300 --lambda 0.01
//!   blocksparse train --spec t2_kpd_16x8_8x4_4x2 --steps 500
//!       (multi-layer specs print a per-layer sparsity breakdown)
//!   blocksparse pattern --spec f3a_pattern --steps 1200   # Figure 3a
//!       (native runs default to the gauge calibration λ=0.002 +0.0005/ramp;
//!       override with --lambda / --lambda-ramp)
//!   blocksparse export --spec t2_kpd_16x8_8x4_4x2 --steps 300 --out t2.bsm
//!   blocksparse export --spec t2_kpd_16x8_8x4_4x2 --quant int8 --out t2_q8.bsm
//!   blocksparse infer --model t2.bsm --batch 16 --requests 512 --clients 8
//!   blocksparse infer --model t2_q8.bsm --mmap --async --window 64
//!   blocksparse blockopt --m 8 --n 256
//!   blocksparse blockopt calibrate --out cost_model.json
//!   blocksparse blockopt sweep --spec f3a_pattern --budget-ms 0.5
//!   blocksparse blockopt recommend --cost-model cost_model.json --m 10 --n 784

use anyhow::{anyhow, bail, Result};

use blocksparse::backend::Backend;
use blocksparse::cli::{render_usage, ArgSpec, Args};
use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, probe, run_spec};
use blocksparse::util::human_count;
use blocksparse::{bench, flops, info};

fn arg_spec() -> ArgSpec {
    ArgSpec {
        options: vec![
            ("spec", true, "spec key (see `blocksparse list`)"),
            ("backend", true, "execution backend: native | pjrt (default: auto)"),
            ("config", true, "TOML config file"),
            ("set", true, "comma-separated key=value config overrides"),
            ("steps", true, "training steps"),
            ("seeds", true, "comma-separated seeds (default 0,1,2)"),
            ("lr", true, "learning rate"),
            ("lambda", true, "l1/group regularizer weight"),
            ("lambda2", true, "secondary regularizer weight"),
            ("lambda-ramp", true, "staircase λ increment per ramp period (pattern specs)"),
            ("ramp-every", true, "ramp period in steps (0: every 5 epochs)"),
            ("train-examples", true, "training set size"),
            ("test-examples", true, "held-out set size"),
            ("eval-every", true, "eval cadence in steps"),
            ("replicas", true, "data-parallel gradient replicas (>1 shards each batch)"),
            ("artifacts", true, "artifact directory (default: artifacts)"),
            ("m", true, "matrix rows (flops/blockopt)"),
            ("n", true, "matrix cols (flops/blockopt)"),
            ("block", true, "block size m2xn2, e.g. 2x16 (comma list for blockopt calibrate)"),
            ("rank", true, "KPD rank"),
            ("batch", true, "batch size (flops accounting / infer micro-batch cap / blockopt)"),
            ("budget-ms", true, "latency budget for the blockopt front pick (default: none)"),
            ("cost-model", true, "calibrated cost model JSON (blockopt sweep/recommend)"),
            ("occupancy", true, "assumed live-block fraction (blockopt recommend, default 0.25)"),
            ("out", true, "output path for the BSR model artifact (export)"),
            ("ckpt", true, "restore training state from this checkpoint (export)"),
            ("quant", true, "export payload dtype: f32 | int8 (default f32)"),
            ("dtype", true, "kernel to calibrate: f32 | int8 (blockopt calibrate/sweep)"),
            ("model", true, "BSR model artifact to serve (infer)"),
            ("mmap", false, "zero-copy map the model payload instead of reading it (infer)"),
            ("requests", true, "total requests to issue (infer, default 256)"),
            ("clients", true, "concurrent client threads (infer, default 4)"),
            ("window", true, "in-flight handle window for --async (infer, default 32)"),
            ("queue-depth", true, "admission queue bound; full queue load-sheds (infer)"),
            ("overload", false, "sustained-overload load test: drive clients >> capacity (infer)"),
            ("async", false, "drive requests through predict_async from one thread (infer)"),
            ("csv", true, "write per-step series to this CSV file"),
            ("quiet", false, "warnings and errors only"),
            ("verbose", false, "debug logging"),
        ],
    }
}

fn build_cfg(args: &Args) -> Result<TrainConfig> {
    let spec = args
        .opt("spec")
        .ok_or_else(|| anyhow!("--spec is required (see `blocksparse list`)"))?;
    build_cfg_for(args, spec)
}

/// [`build_cfg`] with the spec key supplied by the caller — for
/// subcommands with a default spec (`blockopt sweep`).
fn build_cfg_for(args: &Args, spec: &str) -> Result<TrainConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.apply_overrides(&args.overrides())?;
    let mut tc = TrainConfig::from_config(&cfg, spec);
    if let Some(s) = args.opt("steps") {
        tc.steps = s.parse()?;
    }
    if let Some(s) = args.opt("seeds") {
        tc.seeds = s
            .split(',')
            .map(|x| x.trim().parse::<u64>())
            .collect::<Result<Vec<_>, _>>()?;
    }
    tc.lr = args.opt_f64("lr", tc.lr)?;
    tc.lambda = args.opt_f64("lambda", tc.lambda)?;
    tc.lambda2 = args.opt_f64("lambda2", tc.lambda2)?;
    tc.lambda_ramp = args.opt_f64("lambda-ramp", tc.lambda_ramp)?;
    tc.ramp_every = args.opt_usize("ramp-every", tc.ramp_every)?;
    tc.train_examples = args.opt_usize("train-examples", tc.train_examples)?;
    tc.test_examples = args.opt_usize("test-examples", tc.test_examples)?;
    tc.eval_every = args.opt_usize("eval-every", tc.eval_every)?;
    tc.replicas = args.opt_usize("replicas", tc.replicas)?.max(1);
    Ok(tc)
}

fn open_backend(args: &Args) -> Result<Box<dyn Backend>> {
    let dir = args
        .opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(blocksparse::artifact_dir);
    let be = blocksparse::backend::open(&dir, args.opt("backend"))?;
    info!("backend: {} ({} specs)", be.name(), be.specs().len());
    Ok(be)
}

fn cmd_list(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    println!("{:<28} {:<12} {:>6} {:<12} tags", "spec", "model", "batch", "method");
    for s in be.specs() {
        println!(
            "{:<28} {:<12} {:>6} {:<12} {}",
            s.key,
            s.model,
            s.batch,
            s.method,
            s.tags.join(",")
        );
    }
    Ok(())
}

/// Default pattern-method specs to the native gauge λ calibration — unless
/// the user configured λ deliberately (any λ flag, or a --config file,
/// opts out). Non-pattern specs are untouched.
fn maybe_calibrate_pattern(
    args: &Args,
    be: &dyn Backend,
    cfg: &mut TrainConfig,
) -> Result<()> {
    if be.spec(&cfg.spec)?.method.starts_with("pattern")
        && args.opt("config").is_none()
        && args.opt("set").is_none()
        && args.opt("lambda").is_none()
        && args.opt("lambda2").is_none()
        && args.opt("lambda-ramp").is_none()
    {
        blocksparse::backend::native::pattern::calibrate_lambda(cfg, &be.name());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let mut cfg = build_cfg(args)?;
    maybe_calibrate_pattern(args, be.as_ref(), &mut cfg)?;
    let res = run_spec(be.as_ref(), &cfg)?;
    println!("\nspec            : {}", res.spec);
    println!("method          : {}", res.method);
    if cfg.replicas > 1 {
        // report the mode that actually ran: backends without a separable
        // gradient path fall back to the fused single-replica step
        if be.supports_grad_step(&cfg.spec) {
            println!("replicas        : {} (sharded data-parallel)", cfg.replicas);
        } else {
            println!("replicas        : 1 (backend has no grad_step; fused fallback)");
        }
    }
    println!("accuracy        : {:.2} ± {:.2} %", res.acc_mean, res.acc_std);
    println!("sparsity rate   : {:.2} ± {:.2} %", res.sparsity_mean, res.sparsity_std);
    if res.layer_sparsity.len() > 1 {
        for (name, m, s) in &res.layer_sparsity {
            println!("  {:<13} : {:.2} ± {:.2} %", name, m, s);
        }
    }
    println!("training params : {}", human_count(res.train_params as f64));
    println!("training flops  : {}/step", human_count(res.step_flops as f64));
    println!("wall time       : {:.1}s over {} seeds", res.wall_secs, cfg.seeds.len());
    if let Some(csv) = args.opt("csv") {
        write_history_csv(csv, &res.histories[0])?;
        info!("wrote {csv}");
    }
    Ok(())
}

fn cmd_pattern(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let mut cfg = build_cfg(args)?;
    if cfg.seeds.len() > 1 {
        cfg.seeds.truncate(1); // Figure 3 is a single-run diagnostic
    }
    // the native gauge objective wants a smaller λ than the paper-scale
    // TrainConfig defaults (see backend::native::pattern); explicit λ
    // flags or a --config file opt out
    maybe_calibrate_pattern(args, be.as_ref(), &mut cfg)?;
    let spec = be.spec(&cfg.spec)?.clone();
    let k = spec
        .num_patterns()
        .ok_or_else(|| anyhow!("{} is not a pattern-selection spec", cfg.spec))?;
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, cfg.train_examples, cfg.test_examples)?;
    let trainer = coordinator::Trainer::new(be.as_ref(), &cfg);
    let outcome = trainer.run(cfg.seeds[0], &train, &test)?;
    let final_norms = probe::pattern_s_norms(&spec, &outcome.state)?;

    println!("\npattern selection for {} ({} patterns)", cfg.spec, k);
    println!("{:<8} {}", "step", (0..k).map(|p| format!("‖S^({p})‖₁")).collect::<Vec<_>>().join("  "));
    let series: Vec<Vec<(u64, f64)>> =
        (0..k).map(|p| outcome.history.series(&format!("s_l1_p{p}"))).collect();
    let stride = (cfg.steps / 20).max(1);
    for i in (0..series[0].len()).step_by(stride) {
        let step = series[0][i].0;
        let row: Vec<String> =
            series.iter().map(|s| format!("{:>9.3}", s[i].1)).collect();
        println!("{:<8} {}", step, row.join("  "));
    }
    println!("\nfinal ‖S^(k)‖₁ : {:?}", final_norms.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    println!("per-pattern acc: {:?}", outcome.pattern_accs.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
    // patterns have different S sizes: survival is max *retention*
    // (final/initial norm), the shared criterion `materialize` also uses
    let retention =
        probe::pattern_retention_measured(&spec, &outcome.state, &outcome.history)?;
    let survivor = probe::pattern_survivor(&retention);
    println!("surviving pattern (max retention): k={survivor}");
    Ok(())
}

/// Train (or `--ckpt`-restore) a spec and pack it into a BSR artifact.
fn cmd_export(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let cfg = build_cfg(args)?;
    let out = std::path::PathBuf::from(args.opt_or("out", "model.bsm"));
    let spec = be.spec(&cfg.spec)?.clone();
    let state = if let Some(ck) = args.opt("ckpt") {
        let mut state = be.init_state(&cfg.spec, cfg.seeds[0] as u32)?;
        blocksparse::checkpoint::Checkpoint::load(std::path::Path::new(ck))?
            .restore_state(&mut state)?;
        info!("restored {} training state from {ck}", cfg.spec);
        state
    } else {
        let (train, test) = coordinator::dataset_for(
            &spec,
            cfg.data_seed,
            cfg.train_examples,
            cfg.test_examples,
        )?;
        let trainer = coordinator::Trainer::new(be.as_ref(), &cfg);
        let outcome = trainer.run(cfg.seeds[0], &train, &test)?;
        info!(
            "trained {} for {} steps: test acc {:.2}%",
            cfg.spec, outcome.steps_done, outcome.test_acc
        );
        outcome.state
    };
    let model = blocksparse::infer::export(be.as_ref(), &state)?;
    let quant = args.opt_or("quant", "f32");
    match quant {
        "f32" => model.save(&out)?,
        "int8" => blocksparse::infer::quant::quantize_model(&model)?.save(&out)?,
        other => bail!("--quant wants f32 or int8, got '{other}'"),
    }
    println!("exported {} ({}, {quant}) -> {}", model.spec, model.method, out.display());
    for l in &model.layers {
        let (m1, n1) = l.grid();
        println!(
            "  {:<6} {:>4}x{:<4} block {}x{:<3} {:>6}/{:<6} blocks  occupancy {:>5.1}%",
            l.name, l.m, l.n, l.m2, l.n2, l.nnz_blocks(), m1 * n1,
            100.0 * l.occupancy()
        );
    }
    println!(
        "  params {} stored (dense {}), infer {} FLOPs/example (dense {}, {:.2}x cheaper)",
        human_count(model.nnz_params() as f64),
        human_count(spec.slots.iter().map(|s| (s.m * s.n) as f64).sum::<f64>()),
        human_count(model.infer_flops_per_example() as f64),
        human_count(model.dense_flops_per_example() as f64),
        model.dense_flops_per_example() as f64
            / (model.infer_flops_per_example() as f64).max(1.0),
    );
    Ok(())
}

/// Serve a BSR artifact (either payload dtype — peek routes the loader)
/// through the batched engine with synthetic traffic and report the
/// latency distribution + throughput. With `--mmap`, the payload is
/// zero-copy mapped instead of read (startup touches O(header) bytes).
/// With `--async`, one driver thread keeps `--window` requests in flight
/// through `predict_async` handles. With `--overload`, drive sustained
/// overload instead (clients >> engine capacity) and report the
/// load-shed behaviour: shed rate, accepted-request percentiles, peak
/// queue depth vs the admission bound.
fn cmd_infer(args: &Args) -> Result<()> {
    use blocksparse::infer::engine::{
        drive_async, drive_overload, drive_synthetic, latency_summary, Engine, EngineOpts,
    };
    let path = args
        .opt("model")
        .ok_or_else(|| anyhow!("infer needs --model <file.bsm> (see `blocksparse export`)"))?;
    let path = std::path::Path::new(path);
    let (model, map_stats) = if args.has_flag("mmap") {
        let (m, st) = blocksparse::infer::mmap::open_model_mmap(path)?;
        (m, Some(st))
    } else {
        (blocksparse::infer::load_auto(path)?, None)
    };
    let overload = args.has_flag("overload");
    // overload defaults keep the test small and the ratio honest; the
    // plain path keeps the old serve defaults
    let defaults = EngineOpts::default();
    let max_batch = args.opt_usize("batch", if overload { 4 } else { 32 })?;
    let queue_depth =
        args.opt_usize("queue-depth", if overload { 8 } else { defaults.queue_depth })?;
    let workers = if overload { 2 } else { defaults.workers };
    println!(
        "model {} ({}, {} layers, {} payload): {} -> {}, block sparsity {:.1}%, {} params, {} FLOPs/example",
        model.spec(),
        model.method(),
        model.num_layers(),
        model.dtype(),
        model.in_dim(),
        model.out_dim(),
        100.0 * model.block_sparsity(),
        human_count(model.nnz_params() as f64),
        human_count(model.infer_flops_per_example() as f64),
    );
    if let Some(st) = map_stats {
        println!(
            "mmap: {} file bytes, {} resident at startup ({})",
            st.file_bytes,
            st.resident_bytes,
            if st.zero_copy() { "zero-copy payload" } else { "read-path fallback" }
        );
    }
    let engine = Engine::new(model, EngineOpts { max_batch, workers, queue_depth })?;
    if args.has_flag("async") {
        let requests = args.opt_usize("requests", 256)?.max(1);
        let window = args.opt_usize("window", 32)?.max(1);
        let sw = blocksparse::util::Stopwatch::start();
        let rep = drive_async(&engine, requests, window, 0xA51C)?;
        let wall = sw.elapsed_secs();
        let s = latency_summary(&rep.accepted_lat_ms);
        println!(
            "async: {} requests from one driver thread, {} handles in flight, in {wall:.2}s",
            rep.offered, rep.window
        );
        println!(
            "accepted {}  shed {} ({:.1}% shed rate), {:.1} req/s",
            rep.accepted,
            rep.shed,
            100.0 * rep.shed_rate(),
            rep.accepted as f64 / wall.max(1e-9)
        );
        if s.is_empty() {
            println!("accepted latency: no samples (everything shed)");
        } else {
            println!(
                "accepted latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  mean {:.3}  max {:.3}",
                s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms, s.max_ms
            );
        }
        return Ok(());
    }
    if overload {
        // default: 4× the engine's resident capacity, zero think time
        let clients = args.opt_usize("clients", 4 * engine.capacity())?.max(1);
        let per_client = args.opt_usize("requests", 32 * engine.capacity())?.max(1) / clients.max(1);
        let sw = blocksparse::util::Stopwatch::start();
        let rep = drive_overload(&engine, per_client.max(1), clients, 0xD05)?;
        let wall = sw.elapsed_secs();
        let s = latency_summary(&rep.accepted_lat_ms);
        println!(
            "overload: {clients} clients vs capacity {} (queue {queue_depth} + {} workers x batch {max_batch}) = {:.1}x offered",
            rep.capacity,
            engine.workers(),
            rep.offered_ratio
        );
        println!(
            "offered {}  accepted {}  shed {} ({:.1}% shed rate) in {wall:.2}s",
            rep.offered,
            rep.accepted,
            rep.shed,
            100.0 * rep.shed_rate()
        );
        if s.is_empty() {
            println!("accepted latency: no samples (everything shed)");
        } else {
            println!(
                "accepted latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  mean {:.3}  max {:.3}",
                s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms, s.max_ms
            );
        }
        println!(
            "peak queue depth {} (bound {queue_depth}): backlog stayed bounded",
            rep.peak_depth
        );
        return Ok(());
    }
    let requests = args.opt_usize("requests", 256)?.max(1);
    let clients = args.opt_usize("clients", 4)?.max(1);
    let sw = blocksparse::util::Stopwatch::start();
    let lat_ms = drive_synthetic(&engine, requests, clients, 0xC11E47)?;
    let wall = sw.elapsed_secs();
    let s = latency_summary(&lat_ms);
    println!(
        "{} requests over {clients} clients (micro-batch cap {max_batch}) in {wall:.2}s",
        s.count
    );
    println!(
        "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  mean {:.3}  max {:.3}",
        s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms, s.max_ms
    );
    println!("throughput: {:.1} req/s", s.count as f64 / wall.max(1e-9));
    Ok(())
}

fn cmd_flops(args: &Args) -> Result<()> {
    if let Some(_spec_key) = args.opt("spec") {
        let be = open_backend(args)?;
        let spec = be.spec(args.opt("spec").unwrap())?;
        let (params, step) = coordinator::experiment::accounting(spec);
        println!("spec {}: train_params={} step_flops={}", spec.key,
                 human_count(params as f64), human_count(step as f64));
        for (name, d) in coordinator::experiment::kpd_dims(spec) {
            println!(
                "  slot {name}: grid {}x{} block {}x{} r={} -> params {} fwd {} bwd {}",
                d.m1, d.n1, d.m2, d.n2, d.r,
                d.train_params(),
                human_count(flops::kpd_forward_flops(spec.batch as u64, d) as f64),
                human_count(flops::kpd_backward_flops(spec.batch as u64, d) as f64),
            );
        }
        return Ok(());
    }
    let m = args.opt_usize("m", 0)?;
    let n = args.opt_usize("n", 0)?;
    if m == 0 || n == 0 {
        bail!("flops needs --spec or --m/--n");
    }
    let nb = args.opt_usize("batch", 128)? as u64;
    let rank = args.opt_usize("rank", 1)?;
    let block = args.opt_or("block", "");
    println!("dense {m}x{n} @N={nb}: params {} fwd {} bwd {}",
             human_count((m * n) as f64),
             human_count(flops::dense_forward_flops(nb, m as u64, n as u64) as f64),
             human_count(flops::dense_backward_flops(nb, m as u64, n as u64) as f64));
    if !block.is_empty() {
        let (m2, n2) = parse_block(block)?;
        let d = flops::KpdDims::from_block(m, n, m2, n2, rank);
        println!("kpd block {m2}x{n2} r={}: params {} fwd {} bwd {}",
                 d.r,
                 human_count(d.train_params() as f64),
                 human_count(flops::kpd_forward_flops(nb, d) as f64),
                 human_count(flops::kpd_backward_flops(nb, d) as f64));
    }
    Ok(())
}

fn cmd_blockopt(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        None => cmd_blockopt_eq5(args),
        Some("calibrate") => cmd_blockopt_calibrate(args),
        Some("sweep") => cmd_blockopt_sweep(args),
        Some("recommend") => cmd_blockopt_recommend(args),
        Some(other) => bail!(
            "unknown blockopt verb '{other}' (expected calibrate | sweep | recommend, \
             or no verb for the Eq.5 solver)"
        ),
    }
}

/// The analytic path: exact Eq. 5 minimizer for one weight shape.
fn cmd_blockopt_eq5(args: &Args) -> Result<()> {
    let m = args.opt_usize("m", 0)?;
    let n = args.opt_usize("n", 0)?;
    if m == 0 || n == 0 {
        bail!("blockopt needs --m and --n (or a verb: calibrate | sweep | recommend)");
    }
    let r = args.opt_usize("rank", 1)?;
    let d = blocksparse::blockopt::optimal_block(m, n, r)?;
    println!(
        "Eq.5 optimum for {m}x{n} r={r}: grid {}x{} block {}x{} -> {} params (dense {})",
        d.m1, d.n1, d.m2, d.n2,
        blocksparse::blockopt::eq5_cost_r(d.m1, d.n1, d.m2, d.n2, r),
        m * n
    );
    println!("legal blocks: {}", blocksparse::blockopt::enumerate_blocks(m, n)?.len());
    Ok(())
}

/// `--budget-ms` is tri-state: absent means unconstrained, present must
/// parse.
fn budget_arg(args: &Args) -> Result<Option<f64>> {
    match args.opt("budget-ms") {
        None => Ok(None),
        Some(_) => {
            let b = args.opt_f64("budget-ms", 0.0)?;
            if !b.is_finite() || b <= 0.0 {
                bail!("--budget-ms wants a positive latency in ms, got {b}");
            }
            Ok(Some(b))
        }
    }
}

/// Time the BSR kernels on this host, fit the per-shape cost model and
/// publish it as a JSON artifact.
fn cmd_blockopt_calibrate(args: &Args) -> Result<()> {
    use blocksparse::blockopt::cost;
    let shapes: Vec<(usize, usize)> = match args.opt("block") {
        Some(list) => list
            .split(',')
            .map(|s| parse_block(s.trim()))
            .collect::<Result<Vec<_>>>()?,
        None => cost::DEFAULT_SHAPES.to_vec(),
    };
    let nb = args.opt_usize("batch", 32)?;
    let dtype = args.opt_or("dtype", "f32");
    let out = std::path::PathBuf::from(args.opt_or("out", "cost_model.json"));
    let model = cost::calibrate_dtype(&shapes, &cost::DEFAULT_OCCUPANCIES, nb, dtype)?;
    println!(
        "calibrated {} block shapes on simd '{}' dtype '{}' (batch {nb}, {}x{} block grid):",
        model.entries.len(),
        model.simd,
        model.dtype,
        model.grid,
        model.grid
    );
    for e in model.entries.values() {
        println!("  {:>2}x{:<3} a = {:.4} ns/MAC  c = {:.0} ns", e.m2, e.n2, e.a_ns, e.c_ns);
    }
    model.save(&out)?;
    println!("wrote cost model {}", out.display());
    Ok(())
}

/// The hardware-in-the-loop search: one short joint pattern training run,
/// each candidate priced by the cost model, Pareto front + budget pick.
fn cmd_blockopt_sweep(args: &Args) -> Result<()> {
    use blocksparse::blockopt::{cost, sweep};
    let be = open_backend(args)?;
    let mut cfg = build_cfg_for(args, args.opt_or("spec", "f3a_pattern"))?;
    cfg.seeds.truncate(1); // a sweep probe, not a paper table
    maybe_calibrate_pattern(args, be.as_ref(), &mut cfg)?;
    let spec = be.spec(&cfg.spec)?.clone();
    let nb = args.opt_usize("batch", 32)?;
    let budget_ms = budget_arg(args)?;
    let model = match args.opt("cost-model") {
        Some(p) => cost::CostModel::load(std::path::Path::new(p))?,
        None => {
            let shapes = sweep::candidate_shapes(&spec)?;
            info!(
                "no --cost-model: calibrating {} candidate shapes in-process",
                shapes.len()
            );
            cost::calibrate_dtype(
                &shapes,
                &cost::DEFAULT_OCCUPANCIES,
                nb,
                args.opt_or("dtype", "f32"),
            )?
        }
    };
    let out = sweep::sweep(be.as_ref(), &cfg, &model, nb, budget_ms)?;
    let mut table = bench::TableWriter::new(
        &format!("block-size sweep: {} (batch {nb}, cost model '{}')", cfg.spec, model.simd),
        &["k", "block", "retention", "acc %", "occupancy", "pred ms", "front"],
    );
    for c in &out.candidates {
        let on_front = out.front.iter().any(|p| p.index == c.pattern);
        table.row(vec![
            c.pattern.to_string(),
            format!("{}x{}", c.m2, c.n2),
            format!("{:.3}", c.retention),
            format!("{:.2}", c.accuracy),
            format!("{:.3}", c.occupancy),
            format!("{:.4}", c.pred_latency_ms),
            if on_front { "*".into() } else { String::new() },
        ]);
    }
    table.print();
    println!("figure-3 survivor (max retention): k={}", out.survivor);
    let rets: Vec<f64> = out.candidates.iter().map(|c| c.retention).collect();
    let lats: Vec<f64> = out.candidates.iter().map(|c| c.pred_latency_ms).collect();
    let blend = probe::pattern_survivor_cost_aware(&rets, &lats, 0.5)?;
    println!("cost-aware survivor (alpha=0.5): k={}", out.candidates[blend].pattern);
    if let Some(b) = budget_ms {
        println!("latency budget: {b:.3} ms");
    }
    let rec = out
        .candidates
        .iter()
        .find(|c| c.pattern == out.recommended)
        .ok_or_else(|| anyhow!("recommended pattern {} not among candidates", out.recommended))?;
    println!(
        "recommended block size: k={} ({}x{}) predicted {:.3} ms",
        rec.pattern, rec.m2, rec.n2, rec.pred_latency_ms
    );
    Ok(())
}

/// Design-space recommendation without a training run: every legal block
/// size of an m×n slot, Eq. 5 param compression vs predicted latency.
fn cmd_blockopt_recommend(args: &Args) -> Result<()> {
    use blocksparse::blockopt::{self, cost, pareto};
    let path = args.opt("cost-model").ok_or_else(|| {
        anyhow!("recommend needs --cost-model <file.json> (see `blocksparse blockopt calibrate`)")
    })?;
    let model = cost::CostModel::load(std::path::Path::new(path))?;
    let m = args.opt_usize("m", 0)?;
    let n = args.opt_usize("n", 0)?;
    if m == 0 || n == 0 {
        bail!("recommend needs --m and --n");
    }
    let r = args.opt_usize("rank", 1)?;
    if r == 0 {
        bail!("--rank must be ≥ 1");
    }
    let nb = args.opt_usize("batch", model.batch)?;
    let occ = args.opt_f64("occupancy", 0.25)?;
    let budget_ms = budget_arg(args)?;
    let blocks = blockopt::enumerate_blocks(m, n)?;
    if blocks.is_empty() {
        bail!("{m}x{n} has no non-trivial block sizes");
    }
    let mut points = Vec::with_capacity(blocks.len());
    for (i, &(m2, n2)) in blocks.iter().enumerate() {
        // the "retention" axis of the design-space front is the Eq. 5
        // param compression ratio — higher is better, like retention
        let compression =
            (m * n) as f64 / blockopt::eq5_cost_r(m / m2, n / n2, m2, n2, r) as f64;
        let lat = model.predict_ms(m, n, m2, n2, nb, occ)?;
        points.push(pareto::Point { retention: compression, latency_ms: lat, index: i });
    }
    let front = pareto::pareto_front(&points);
    let mut table = bench::TableWriter::new(
        &format!("design-space front: {m}x{n} r={r} (batch {nb}, occupancy {occ:.2})"),
        &["block", "params", "compression", "pred ms"],
    );
    for p in &front {
        let (m2, n2) = blocks[p.index];
        table.row(vec![
            format!("{m2}x{n2}"),
            blockopt::eq5_cost_r(m / m2, n / n2, m2, n2, r).to_string(),
            format!("{:.2}x", p.retention),
            format!("{:.4}", p.latency_ms),
        ]);
    }
    table.print();
    let rec = pareto::recommend(&front, budget_ms)
        .ok_or_else(|| anyhow!("design-space front is empty — every point scored non-finite"))?;
    let (m2, n2) = blocks[rec.index];
    println!(
        "recommended block size: {m2}x{n2} — {:.2}x param compression, predicted {:.3} ms",
        rec.retention, rec.latency_ms
    );
    Ok(())
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let cfg = build_cfg(args)?;
    let spec = be.spec(&cfg.spec)?.clone();
    let (train, _test) =
        coordinator::dataset_for(&spec, cfg.data_seed, spec.batch * 4, spec.batch)?;
    let mut state = be.init_state(&cfg.spec, 0)?;
    let batch = crate::first_batch(&train, spec.batch)?;
    let hyper: Vec<f32> = spec
        .hyper
        .iter()
        .map(|h| match h.as_str() {
            "lr" => cfg.lr as f32,
            "lambda2" => cfg.lambda2 as f32,
            _ => cfg.lambda as f32,
        })
        .collect();
    let stats = bench::quick_bench(&format!("{} train_step", cfg.spec), || {
        be.train_step(&mut state, &batch.x, &batch.y, &hyper).expect("step");
    });
    println!("{}", stats.report());
    println!(
        "model flops/step {} -> {:.2} GFLOP/s effective",
        human_count(coordinator::experiment::accounting(&spec).1 as f64),
        coordinator::experiment::accounting(&spec).1 as f64 / stats.mean_ns
    );
    Ok(())
}

fn first_batch(data: &blocksparse::data::Dataset, batch: usize) -> Result<blocksparse::data::Batch> {
    let idx: Vec<usize> = (0..batch).collect();
    blocksparse::data::assemble_batch(data, &idx)
}

fn write_history_csv(path: &str, h: &blocksparse::metrics::History) -> Result<()> {
    use std::io::Write;
    let mut keys: Vec<String> = Vec::new();
    for r in &h.records {
        for k in r.values.keys() {
            if !keys.contains(k) {
                keys.push(k.clone());
            }
        }
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "step,{}", keys.join(","))?;
    for r in &h.records {
        let cells: Vec<String> = keys
            .iter()
            .map(|k| r.values.get(k).map(|v| v.to_string()).unwrap_or_default())
            .collect();
        writeln!(f, "{},{}", r.step, cells.join(","))?;
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = arg_spec();
    let args = match Args::parse(&argv, &spec, true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", render_usage("blocksparse", "<list|train|pattern|export|infer|flops|blockopt|bench-step>", &spec));
            std::process::exit(2);
        }
    };
    if args.has_flag("quiet") {
        blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    } else if args.has_flag("verbose") {
        blocksparse::util::log::set_level(blocksparse::util::log::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("list") => cmd_list(&args),
        Some("train") => cmd_train(&args),
        Some("pattern") => cmd_pattern(&args),
        Some("export") => cmd_export(&args),
        Some("infer") => cmd_infer(&args),
        Some("flops") => cmd_flops(&args),
        Some("blockopt") => cmd_blockopt(&args),
        Some("bench-step") => cmd_bench_step(&args),
        other => {
            eprintln!("unknown subcommand {other:?}");
            eprintln!("{}", render_usage("blocksparse", "<list|train|pattern|export|infer|flops|blockopt|bench-step>", &spec));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_block(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once('x')
        .ok_or_else(|| anyhow!("block must be m2xn2, e.g. 2x16"))?;
    Ok((a.parse()?, b.parse()?))
}
