//! BSR forward kernels: Z = X·Wᵀ over the stored blocks only.
//!
//! Each stored (m2×n2) block is packed contiguously, so the inner loop is
//! a straight dot product between a block row and the matching contiguous
//! n2-segment of the input row — no gather, no mask test per element (the
//! skip decision was paid once at export). Batch rows are split across
//! scoped workers via the same `par_rows`/`threads_for` substrate as the
//! training kernels in `backend::native::linalg`, with the thread decision
//! made on the *occupied* work so sparse layers are not taxed with spawn
//! overhead; cost therefore scales with occupancy, not the dense shape.

use anyhow::{bail, Context, Result};

use crate::backend::native::linalg::{par_rows, threads_for};
use crate::backend::native::simd::{self, SimdKind};

use super::{BsrLayer, BsrModel};

/// Z(N, m) = X(N, n) · Wᵀ over the occupied blocks of `layer`.
pub fn bsr_forward(x: &[f32], nb: usize, layer: &BsrLayer) -> Result<Vec<f32>> {
    forward_impl(simd::active(), x, nb, layer, false)
}

/// Fused variant: Z = max(X·Wᵀ, 0) — the hidden layers of a served stack,
/// saving one full pass over the activations.
pub fn bsr_forward_relu(x: &[f32], nb: usize, layer: &BsrLayer) -> Result<Vec<f32>> {
    forward_impl(simd::active(), x, nb, layer, true)
}

/// [`bsr_forward`] / [`bsr_forward_relu`] with an explicit SIMD kind —
/// the scalar-vs-dispatched bench variants and parity tests go through
/// here.
pub fn bsr_forward_with(
    kind: SimdKind,
    x: &[f32],
    nb: usize,
    layer: &BsrLayer,
    relu: bool,
) -> Result<Vec<f32>> {
    forward_impl(kind, x, nb, layer, relu)
}

fn forward_impl(kind: SimdKind, x: &[f32], nb: usize, l: &BsrLayer, relu: bool) -> Result<Vec<f32>> {
    let (m, n, m2, n2) = (l.m, l.n, l.m2, l.n2);
    // Real validation, not debug asserts: `from_dense` builds consistent
    // layers, but deserialized or hand-built ones must not mis-bin the
    // mask or run `row_ptr`/`col_idx` out of bounds in release builds.
    if m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
        bail!("layer '{}': block ({m2},{n2}) does not tile ({m},{n})", l.name);
    }
    let (m1, n1) = (m / m2, n / n2);
    if x.len() != nb * n {
        bail!("layer '{}': batch wants {nb}·{n} values, got {}", l.name, x.len());
    }
    if l.row_ptr.len() != m1 + 1 {
        bail!("layer '{}': row_ptr has {} entries, want {}", l.name, l.row_ptr.len(), m1 + 1);
    }
    if !l.row_ptr.windows(2).all(|w| w[0] <= w[1]) || l.row_ptr[0] != 0 {
        bail!("layer '{}': row_ptr is not monotonically increasing from 0", l.name);
    }
    // row_ptr is the authoritative block count the kernel walks — the
    // index/payload buffers must cover it exactly
    let nnz = l.row_ptr[m1] as usize;
    if l.col_idx.len() != nnz || l.blocks.len() != nnz * m2 * n2 {
        bail!(
            "layer '{}': {} col_idx / {} block values for {nnz} stored blocks",
            l.name,
            l.col_idx.len(),
            l.blocks.len()
        );
    }
    if l.col_idx.iter().any(|&j| j as usize >= n1) {
        bail!("layer '{}': col_idx out of range [0, {n1})", l.name);
    }
    let mut out = vec![0.0f32; nb * m];
    let work = nb * nnz * m2 * n2;
    par_rows(&mut out, nb, m, threads_for(work), |b, row| {
        let xrow = &x[b * n..(b + 1) * n];
        for i1 in 0..m1 {
            let orow = &mut row[i1 * m2..(i1 + 1) * m2];
            let (lo, hi) = (l.row_ptr[i1] as usize, l.row_ptr[i1 + 1] as usize);
            for k in lo..hi {
                let j1 = l.col_idx[k] as usize;
                let xseg = &xrow[j1 * n2..(j1 + 1) * n2];
                let blk = &l.blocks[k * m2 * n2..(k + 1) * m2 * n2];
                for (i2, o) in orow.iter_mut().enumerate() {
                    *o += simd::dot(kind, &blk[i2 * n2..(i2 + 1) * n2], xseg);
                }
            }
            if relu {
                for o in orow.iter_mut() {
                    if *o < 0.0 {
                        *o = 0.0;
                    }
                }
            }
        }
    });
    Ok(out)
}

/// Time one layer's forward on a fixed batch with the repo-standard
/// microbench settings — the single-layer timing hook the `blockopt` cost
/// model calibrates from. The layer is validated (and the result shape
/// exercised) once up front so a malformed layer fails loudly here
/// instead of panicking mid-sample; the timed closure then runs the same
/// `forward_impl` the serving path dispatches to, under the SIMD kind
/// active at call time.
pub fn time_layer(x: &[f32], nb: usize, layer: &BsrLayer) -> Result<crate::bench::BenchStats> {
    let kind = simd::active();
    forward_impl(kind, x, nb, layer, false)
        .with_context(|| format!("timing layer '{}'", layer.name))?;
    let name = format!("bsr.{}x{}_b{}x{}", layer.m, layer.n, layer.m2, layer.n2);
    Ok(crate::bench::quick_bench(&name, || {
        std::hint::black_box(
            forward_impl(kind, std::hint::black_box(x), nb, layer, false).unwrap(),
        );
    }))
}

/// Logits of the full stack on a flat batch (N × in_dim): ReLU fused into
/// every hidden layer, none after the logits — the serving mirror of
/// `backend::native::layers::forward_logits`.
pub fn model_forward(model: &BsrModel, x: &[f32], nb: usize) -> Result<Vec<f32>> {
    if model.layers.is_empty() {
        bail!("BSR model '{}' has no layers", model.spec);
    }
    if nb == 0 || x.len() != nb * model.in_dim {
        bail!(
            "model '{}' wants a flat batch of {}·{} values, got {}",
            model.spec, nb, model.in_dim, x.len()
        );
    }
    // the first layer reads straight from the caller's batch — no copy on
    // the serving hot path
    // the kind is resolved once for the whole stack
    let kind = simd::active();
    let last = model.layers.len() - 1;
    // each layer error is wrapped with the model/layer coordinates: the
    // serving engine forwards this chain verbatim to every waiter of a
    // failed micro-batch, so the client log alone locates the bad slot
    let at = |i: usize| format!("model '{}' layer {i} ('{}')", model.spec, model.layers[i].name);
    let mut cur = forward_impl(kind, x, nb, &model.layers[0], last != 0).with_context(|| at(0))?;
    for (i, l) in model.layers.iter().enumerate().skip(1) {
        cur = forward_impl(kind, &cur, nb, l, i < last).with_context(|| at(i))?;
    }
    Ok(cur)
}

/// Row-wise argmax over (nb × classes) logits — ties resolve to the first
/// maximum, matching `linalg::softmax_ce`'s accuracy convention.
pub fn argmax_rows(z: &[f32], nb: usize, classes: usize) -> Vec<usize> {
    debug_assert_eq!(z.len(), nb * classes);
    (0..nb)
        .map(|b| {
            let row = &z[b * classes..(b + 1) * classes];
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::linalg;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Random dense W with a deterministic set of zeroed blocks.
    fn holey_weights(
        rng: &mut Rng,
        m: usize,
        n: usize,
        m2: usize,
        n2: usize,
        keep_every: usize,
    ) -> Vec<f32> {
        let n1 = n / n2;
        let mut w = rand_vec(rng, m * n);
        for i in 0..m {
            for j in 0..n {
                let blk = (i / m2) * n1 + j / n2;
                if blk % keep_every != 0 {
                    w[i * n + j] = 0.0;
                }
            }
        }
        w
    }

    #[test]
    fn bsr_forward_matches_dense_matmul() {
        let mut rng = Rng::new(31);
        for &(nb, m, n, m2, n2, keep) in
            &[(5usize, 6usize, 8usize, 2usize, 4usize, 2usize), (3, 12, 10, 3, 5, 3), (4, 4, 4, 1, 1, 2)]
        {
            let x = rand_vec(&mut rng, nb * n);
            let w = holey_weights(&mut rng, m, n, m2, n2, keep);
            let l = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
            let got = bsr_forward(&x, nb, &l).unwrap();
            let want = linalg::matmul_nt(&x, &w, nb, n, m);
            let diff = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-5, "({nb},{m},{n},{m2},{n2}): max diff {diff}");
        }
    }

    #[test]
    fn bsr_forward_threaded_path_matches_dense() {
        // large enough that threads_for spawns workers (nb·nnz·m2·n2 > 2^21)
        let mut rng = Rng::new(32);
        let (nb, m, n, m2, n2) = (80usize, 128usize, 512usize, 8usize, 16usize);
        let x = rand_vec(&mut rng, nb * n);
        let w = holey_weights(&mut rng, m, n, m2, n2, 2);
        let l = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
        assert!(nb * l.nnz_blocks() * m2 * n2 > 1 << 21, "test must cross the threshold");
        let got = bsr_forward(&x, nb, &l).unwrap();
        let want = linalg::matmul_nt(&x, &w, nb, n, m);
        let diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "max diff {diff}");
    }

    #[test]
    fn relu_fusion_matches_separate_relu() {
        let mut rng = Rng::new(33);
        let (nb, m, n, m2, n2) = (4usize, 6usize, 9usize, 3usize, 3usize);
        let x = rand_vec(&mut rng, nb * n);
        let w = holey_weights(&mut rng, m, n, m2, n2, 2);
        let l = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
        let mut want = bsr_forward(&x, nb, &l).unwrap();
        linalg::relu_inplace(&mut want);
        assert_eq!(bsr_forward_relu(&x, nb, &l).unwrap(), want);
    }

    #[test]
    fn empty_block_rows_emit_zero() {
        // one fully-zero output block-row: its logits must be exactly 0
        let (m, n, m2, n2) = (4usize, 4usize, 2usize, 2usize);
        let mut w = vec![1.0f32; m * n];
        for i in 0..2 {
            for j in 0..n {
                w[i * n + j] = 0.0;
            }
        }
        let l = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
        assert_eq!(l.row_ptr[0], l.row_ptr[1], "first block-row must be empty");
        let x = vec![1.0f32; n];
        let z = bsr_forward(&x, 1, &l).unwrap();
        assert_eq!(&z[..2], &[0.0, 0.0]);
        assert_eq!(&z[2..], &[4.0, 4.0]);
    }

    /// The shape checks are real validation now: a hand-built (or
    /// corrupted-on-disk) layer with a non-dividing block shape, a wrong
    /// batch length, or inconsistent row_ptr/col_idx must error instead
    /// of mis-binning or indexing out of bounds in release builds.
    #[test]
    fn forward_rejects_inconsistent_layers() {
        let mut rng = Rng::new(35);
        let (m, n, m2, n2) = (6usize, 8usize, 2usize, 4usize);
        let w = rand_vec(&mut rng, m * n);
        let good = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
        let x = vec![0.0f32; 2 * n];
        assert!(bsr_forward(&x, 2, &good).is_ok());

        // wrong batch length
        assert!(bsr_forward(&x[..15], 2, &good).is_err());

        // non-dividing block shape
        let mut bad = good.clone();
        bad.m2 = 4; // 6 % 4 != 0
        assert!(bsr_forward(&x, 2, &bad).is_err());
        let mut bad = good.clone();
        bad.n2 = 3; // 8 % 3 != 0
        assert!(bsr_forward(&x, 2, &bad).is_err());

        // truncated row_ptr would read past the end
        let mut bad = good.clone();
        bad.row_ptr.pop();
        assert!(bsr_forward(&x, 2, &bad).is_err());

        // col_idx pointing past the last block column
        let mut bad = good.clone();
        bad.col_idx[0] = (n / n2) as u32;
        assert!(bsr_forward(&x, 2, &bad).is_err());

        // block payload length out of sync with the index
        let mut bad = good.clone();
        let cut = bad.blocks.len() - 1;
        bad.blocks.to_mut().truncate(cut);
        assert!(bsr_forward(&x, 2, &bad).is_err());
    }

    #[test]
    fn model_forward_chains_with_relu_and_validates_input() {
        let mut rng = Rng::new(34);
        let w1 = rand_vec(&mut rng, 6 * 8);
        let w2 = rand_vec(&mut rng, 4 * 6);
        let model = BsrModel {
            spec: "tiny".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![
                BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap(),
                BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap(),
            ],
        };
        let nb = 3;
        let x = rand_vec(&mut rng, nb * 8);
        let z = model_forward(&model, &x, nb).unwrap();
        // reference: dense matmul chain with an explicit ReLU between
        let mut h = linalg::matmul_nt(&x, &w1, nb, 8, 6);
        linalg::relu_inplace(&mut h);
        let want = linalg::matmul_nt(&h, &w2, nb, 6, 4);
        let diff = z
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-5, "max diff {diff}");
        // wrong input length is rejected
        assert!(model_forward(&model, &x[..7], 1).is_err());
        assert!(model_forward(&model, &x, 0).is_err());
    }

    #[test]
    fn time_layer_samples_and_validates() {
        let mut rng = Rng::new(36);
        let (nb, m, n, m2, n2) = (4usize, 8usize, 16usize, 2usize, 4usize);
        let x = rand_vec(&mut rng, nb * n);
        let w = holey_weights(&mut rng, m, n, m2, n2, 2);
        let l = BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap();
        let stats = time_layer(&x, nb, &l).unwrap();
        assert!(stats.iters >= 10, "{stats:?}");
        assert!(stats.p50_ns > 0.0 && stats.p50_ns <= stats.p95_ns, "{stats:?}");
        assert_eq!(stats.name, "bsr.8x16_b2x4");
        // a malformed layer errors up front, never panics mid-sample
        let mut bad = l.clone();
        bad.n2 = 3;
        assert!(time_layer(&x, nb, &bad).is_err());
        assert!(time_layer(&x[..7], nb, &l).is_err());
    }

    #[test]
    fn argmax_rows_first_max_wins() {
        let z = vec![0.0, 2.0, 2.0, /* row 2 */ -1.0, -3.0, -2.0];
        assert_eq!(argmax_rows(&z, 2, 3), vec![1, 0]);
    }
}
