//! Batched serving engine v2: bounded admission, dynamic micro-batching,
//! typed load-shed, and atomic hot-swap.
//!
//! Clients call [`Engine::predict`] (blocking). Admission is **bounded**:
//! the request queue holds at most `queue_depth` requests, and a predict
//! arriving at a full queue fails fast with the typed
//! [`EngineError::Overloaded`] instead of queueing forever — under
//! sustained overload the backlog (and client-visible latency) is capped
//! by configuration, and the excess is shed at the door where the client
//! can retry elsewhere. A dispatcher thread drains admitted requests into
//! micro-batches — whatever is waiting, capped at `max_batch`, with no
//! artificial fill delay — and submits each batch to a
//! `util::pool::ThreadPool`, keeping at most one batch in flight per pool
//! worker. Under light load a request rides alone (lowest latency); under
//! sustained load the in-flight bound makes the backlog accumulate while
//! workers are busy, so later batches genuinely fill toward `max_batch`
//! (highest throughput).
//!
//! Failures propagate: a micro-batch whose forward errors sends the
//! root-cause message to **every** waiter as
//! [`EngineError::BatchFailed`] — no dropped senders, no fabricated
//! guess at the cause.
//!
//! Models hot-swap atomically ([`Engine::swap_model`]): the replacement
//! is installed with a single `Arc` pointer swap, new micro-batches route
//! to it immediately, and batches already formed finish on the model they
//! started with — one request never mixes logits from two models. Each
//! [`Prediction`] carries the `generation` that served it. The on-disk
//! half of the same discipline is `BsrModel::save`'s write-then-rename
//! publish (uv-style), so a reader never observes a torn artifact.
//!
//! Every response carries per-request latency (enqueue → logits ready)
//! and the micro-batch size it rode in; [`Engine::stats`] exposes the
//! accepted/shed/completed/failed counters and the peak queue depth the
//! overload bench gates on.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::pool::ThreadPool;

use super::{bsr, BsrModel};

/// Typed serving errors — [`Engine::predict`]'s error type. Implements
/// `std::error::Error`, so `?` converts it into `anyhow::Error` at call
/// sites that just propagate, while load-shedding callers (and tests)
/// match on the variant directly (the vendored anyhow has no downcast).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The admission queue is at its configured depth: the request was
    /// load-shed without queueing. Fail-fast by design — retry against
    /// another replica or back off.
    Overloaded {
        /// the configured admission bound that was hit
        depth: usize,
    },
    /// The engine has shut down (or tore down while the request waited).
    ShutDown,
    /// The request itself is malformed (feature-count mismatch).
    BadRequest(String),
    /// The micro-batch carrying this request failed; the message is the
    /// actual forward error, chain included.
    BatchFailed(String),
    /// [`Engine::swap_model`] refused the replacement model.
    SwapRejected(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Overloaded { depth } => write!(
                f,
                "engine overloaded: admission queue at its bound of {depth} requests (load shed)"
            ),
            EngineError::ShutDown => write!(f, "engine is shut down"),
            EngineError::BadRequest(m) => write!(f, "bad request: {m}"),
            EngineError::BatchFailed(m) => write!(f, "micro-batch failed: {m}"),
            EngineError::SwapRejected(m) => write!(f, "hot-swap rejected: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// One served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// raw logits (out_dim values)
    pub logits: Vec<f32>,
    /// argmax class id (first maximum on ties)
    pub class: usize,
    /// request enqueue → response ready (queueing + compute)
    pub latency: Duration,
    /// size of the micro-batch this request rode in
    pub batch_size: usize,
    /// which deployed model served it: 0 for the construction model,
    /// bumped by every [`Engine::swap_model`]
    pub generation: u64,
}

struct Pending {
    x: Vec<f32>,
    enqueued: Instant,
    tx: mpsc::Sender<Result<Prediction, EngineError>>,
}

/// The model a micro-batch is pinned to: swapped as one `Arc`, so a batch
/// either sees (old model, old generation) or (new, new) — never a mix.
struct Deployed {
    model: Arc<BsrModel>,
    generation: u64,
}

struct QueueState {
    q: VecDeque<Pending>,
    /// micro-batches currently executing on the pool — the dispatcher only
    /// forms a new batch while this is below the worker count, so under
    /// sustained load requests accumulate and batches actually fill toward
    /// `max_batch` instead of racing through one-by-one
    in_flight: usize,
    shutdown: bool,
    /// dispatch hold: admitted requests stay queued (maintenance drains,
    /// deterministic tests). Admission — and therefore shedding at the
    /// bound — continues while paused.
    paused: bool,
    accepted: u64,
    shed: u64,
    completed: u64,
    failed: u64,
    peak_depth: usize,
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// Counter snapshot from [`Engine::stats`].
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// requests admitted into the queue since construction
    pub accepted: u64,
    /// requests load-shed at the admission bound
    pub shed: u64,
    /// requests answered with logits
    pub completed: u64,
    /// requests answered with a batch failure
    pub failed: u64,
    /// maximum queue depth ever observed (≤ the configured bound)
    pub peak_depth: usize,
    /// current queue depth
    pub depth: usize,
    /// generation of the currently deployed model
    pub generation: u64,
}

/// Engine sizing.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// micro-batch cap: the dispatcher never packs more rows than this
    pub max_batch: usize,
    /// pool workers executing micro-batches concurrently
    pub workers: usize,
    /// admission bound: a predict arriving with this many requests queued
    /// is load-shed with [`EngineError::Overloaded`]
    pub queue_depth: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineOpts {
            max_batch: 32,
            // shared crate-wide clamp (1..=util::MAX_WORKERS) — the old
            // 1..=8 here silently disagreed with the kernels' 1..=16
            workers: crate::util::env_workers("BS_SERVE_WORKERS", cores.saturating_sub(1)),
            queue_depth: 256,
        }
    }
}

/// A running inference engine over a hot-swappable [`BsrModel`].
pub struct Engine {
    current: Arc<Mutex<Arc<Deployed>>>,
    queue: Arc<Queue>,
    in_dim: usize,
    out_dim: usize,
    opts: EngineOpts,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn new(model: BsrModel, opts: EngineOpts) -> Result<Engine> {
        model.validate()?;
        let (in_dim, out_dim) = (model.in_dim, model.out_dim);
        let opts = EngineOpts {
            max_batch: opts.max_batch.max(1),
            workers: crate::util::clamp_workers(opts.workers),
            queue_depth: opts.queue_depth.max(1),
        };
        let current = Arc::new(Mutex::new(Arc::new(Deployed {
            model: Arc::new(model),
            generation: 0,
        })));
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                paused: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                peak_depth: 0,
            }),
            cv: Condvar::new(),
        });
        let pool = ThreadPool::new(opts.workers);
        let (qc, cc) = (queue.clone(), current.clone());
        let (max_batch, workers) = (opts.max_batch, opts.workers);
        let dispatcher = std::thread::Builder::new()
            .name("bsr-dispatch".to_string())
            .spawn(move || dispatch_loop(qc, cc, pool, max_batch, workers))
            .map_err(|e| anyhow!("spawning engine dispatcher: {e}"))?;
        Ok(Engine { current, queue, in_dim, out_dim, opts, dispatcher: Some(dispatcher) })
    }

    /// The currently deployed model (the next micro-batch's model; an
    /// in-flight batch may still be on the previous one).
    pub fn model(&self) -> Arc<BsrModel> {
        self.current.lock().unwrap().model.clone()
    }

    /// Generation of the currently deployed model (0 at construction,
    /// +1 per [`Engine::swap_model`]).
    pub fn generation(&self) -> u64 {
        self.current.lock().unwrap().generation
    }

    pub fn max_batch(&self) -> usize {
        self.opts.max_batch
    }

    pub fn workers(&self) -> usize {
        self.opts.workers
    }

    /// The configured admission bound.
    pub fn queue_depth(&self) -> usize {
        self.opts.queue_depth
    }

    /// Resident-request capacity: queued (`queue_depth`) plus executing
    /// (`workers · max_batch`). Offered concurrency beyond this sheds.
    pub fn capacity(&self) -> usize {
        self.opts.queue_depth + self.opts.workers * self.opts.max_batch
    }

    /// Counter snapshot (monotonic since construction, except `depth`).
    pub fn stats(&self) -> EngineStats {
        let (accepted, shed, completed, failed, peak_depth, depth) = {
            let st = self.queue.state.lock().unwrap();
            (st.accepted, st.shed, st.completed, st.failed, st.peak_depth, st.q.len())
        };
        // generation is read after the queue lock is released — the two
        // locks are never held together anywhere in the engine
        EngineStats { accepted, shed, completed, failed, peak_depth, depth, generation: self.generation() }
    }

    /// Hold dispatch: admitted requests stay queued until [`Engine::resume`].
    /// Admission (and shedding at the bound) continues. Maintenance /
    /// deterministic-test hook; dropping the engine drains regardless.
    pub fn pause(&self) {
        self.queue.state.lock().unwrap().paused = true;
    }

    /// Resume dispatch after [`Engine::pause`].
    pub fn resume(&self) {
        self.queue.state.lock().unwrap().paused = false;
        self.queue.cv.notify_all();
    }

    /// Atomically deploy `model`: one `Arc` swap in memory. New
    /// micro-batches route to it immediately; batches already formed
    /// finish on the model they started with, so a request never mixes
    /// generations. The replacement must validate and match the engine's
    /// (in_dim, out_dim) — queued requests were admitted against those
    /// shapes. Returns the new generation. O(1) beyond validation: no
    /// engine teardown, no thread respawn, no queue disturbance.
    pub fn swap_model(&self, model: BsrModel) -> Result<u64, EngineError> {
        if let Err(e) = model.validate() {
            return Err(EngineError::SwapRejected(format!("{e:#}")));
        }
        if model.in_dim != self.in_dim || model.out_dim != self.out_dim {
            return Err(EngineError::SwapRejected(format!(
                "model '{}' is {}->{}, engine serves {}->{}",
                model.spec, model.in_dim, model.out_dim, self.in_dim, self.out_dim
            )));
        }
        let mut cur = self.current.lock().unwrap();
        let generation = cur.generation + 1;
        *cur = Arc::new(Deployed { model: Arc::new(model), generation });
        Ok(generation)
    }

    /// Blocking single-request predict: enqueue, wait for the micro-batch
    /// carrying this request to finish, return logits + argmax + latency.
    /// Safe to call from many client threads at once — that is what fills
    /// the micro-batches. Fails fast with [`EngineError::Overloaded`]
    /// when the admission queue is at its bound.
    pub fn predict(&self, x: &[f32]) -> Result<Prediction, EngineError> {
        if x.len() != self.in_dim {
            return Err(EngineError::BadRequest(format!(
                "request has {} features, engine wants {}",
                x.len(),
                self.in_dim
            )));
        }
        let (tx, rx) = mpsc::channel();
        // the payload copy is per-request-private: build it before taking
        // the shared lock so concurrent clients don't serialize on it
        let pending = Pending { x: x.to_vec(), enqueued: Instant::now(), tx };
        {
            let mut st = self.queue.state.lock().unwrap();
            if st.shutdown {
                return Err(EngineError::ShutDown);
            }
            if st.q.len() >= self.opts.queue_depth {
                // bounded admission: shed at the door, O(1), queue unread
                st.shed += 1;
                return Err(EngineError::Overloaded { depth: self.opts.queue_depth });
            }
            st.q.push_back(pending);
            st.accepted += 1;
            if st.q.len() > st.peak_depth {
                st.peak_depth = st.q.len();
            }
        }
        self.queue.cv.notify_one();
        match rx.recv() {
            Ok(res) => res,
            // the sender was dropped without a response: only engine
            // teardown does that (run_batch always answers)
            Err(_) => Err(EngineError::ShutDown),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        {
            let mut st = self.queue.state.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cv.notify_all();
        // the dispatcher drains what is still queued (shutdown overrides
        // pause), then its pool drop joins the in-flight micro-batches —
        // no admitted request is abandoned
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    queue: Arc<Queue>,
    current: Arc<Mutex<Arc<Deployed>>>,
    pool: ThreadPool,
    max_batch: usize,
    workers: usize,
) {
    loop {
        let batch: Vec<Pending> = {
            let mut st = queue.state.lock().unwrap();
            loop {
                // bounded in-flight: only form a batch when a pool worker
                // can take it, so a sustained backlog fills later batches
                // toward max_batch instead of flooding the pool queue with
                // size-1 batches. A pause holds dispatch (not admission)
                // until resume — or shutdown, which always drains.
                let dispatchable =
                    !st.q.is_empty() && st.in_flight < workers && (!st.paused || st.shutdown);
                if dispatchable {
                    let take = st.q.len().min(max_batch);
                    st.in_flight += 1;
                    break st.q.drain(..take).collect();
                }
                if st.shutdown && st.q.is_empty() {
                    return; // pool drops here: joins outstanding batches
                }
                st = queue.cv.wait(st).unwrap();
            }
        };
        // the model is pinned per micro-batch *after* the batch is formed
        // and *outside* the queue lock: a swap between batches routes the
        // later batch to the new model; a swap during a batch leaves that
        // batch on the model it started with — one request never mixes
        // generations
        let deployed: Arc<Deployed> = current.lock().unwrap().clone();
        let q = queue.clone();
        pool.submit(move || {
            // the pool catch_unwind's jobs and keeps its workers alive, so
            // the slot release must survive a panicking batch too — a drop
            // guard runs on unwind, where a trailing statement would not
            // (a leaked slot would eventually wedge the dispatcher for
            // good once every slot leaked)
            struct SlotGuard(Arc<Queue>);
            impl Drop for SlotGuard {
                fn drop(&mut self) {
                    let mut st = self.0.state.lock().unwrap();
                    st.in_flight -= 1;
                    drop(st);
                    // wake the dispatcher: a worker slot is free again
                    self.0.cv.notify_all();
                }
            }
            let _slot = SlotGuard(q.clone());
            run_batch(&deployed, &q, batch);
        });
    }
}

fn run_batch(deployed: &Deployed, queue: &Queue, batch: Vec<Pending>) {
    let model = &deployed.model;
    let nb = batch.len();
    let mut xs = Vec::with_capacity(nb * model.in_dim);
    for p in &batch {
        xs.extend_from_slice(&p.x);
    }
    // counters bump BEFORE the responses go out: once a client's predict
    // has returned, `stats()` is guaranteed to already count that request
    match bsr::model_forward(model, &xs, nb) {
        Ok(z) => {
            queue.state.lock().unwrap().completed += nb as u64;
            let classes = model.out_dim;
            let preds = bsr::argmax_rows(&z, nb, classes);
            for (i, p) in batch.into_iter().enumerate() {
                let resp = Prediction {
                    logits: z[i * classes..(i + 1) * classes].to_vec(),
                    class: preds[i],
                    latency: p.enqueued.elapsed(),
                    batch_size: nb,
                    generation: deployed.generation,
                };
                // a client that gave up (dropped rx) is not an engine error
                let _ = p.tx.send(Ok(resp));
            }
        }
        Err(e) => {
            queue.state.lock().unwrap().failed += nb as u64;
            // every waiter gets the actual forward error — the senders
            // are answered, not dropped, so clients see the root cause
            // instead of a fabricated "batch failed?" guess
            let msg = format!("{e:#}");
            crate::warn_!("micro-batch of {nb} failed: {msg}");
            for p in batch {
                let _ = p.tx.send(Err(EngineError::BatchFailed(msg.clone())));
            }
        }
    }
}

/// Drive an engine with synthetic random-normal traffic: `clients`
/// concurrent threads issue `requests` predicts in total (quota split
/// evenly, remainder to the first threads), each with its own
/// seed-derived RNG. Returns every request's latency in milliseconds —
/// feed to [`latency_summary`]. Closed-loop: each client has one request
/// outstanding, so with `queue_depth ≥ clients` nothing sheds. Shared by
/// the `infer` CLI subcommand and `benches/infer_serve.rs` so the
/// measured traffic shape cannot diverge between them; the overload
/// variant is [`drive_overload`].
pub fn drive_synthetic(
    engine: &Engine,
    requests: usize,
    clients: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let requests = requests.max(1);
    let clients = clients.max(1);
    let in_dim = engine.model().in_dim;
    let per_client: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let quota = requests / clients + usize::from(c < requests % clients);
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut lat = Vec::with_capacity(quota);
                    for _ in 0..quota {
                        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
                        lat.push(engine.predict(&x)?.latency.as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(requests);
    for r in per_client {
        out.extend(r?);
    }
    Ok(out)
}

/// What [`drive_overload`] measured.
#[derive(Clone, Debug)]
pub struct OverloadReport {
    /// total requests issued (accepted + shed)
    pub offered: usize,
    /// requests that got logits
    pub accepted: usize,
    /// requests load-shed with [`EngineError::Overloaded`]
    pub shed: usize,
    /// per-accepted-request latency in milliseconds
    pub accepted_lat_ms: Vec<f64>,
    /// maximum queue depth the engine ever observed
    pub peak_depth: usize,
    /// the configured admission bound
    pub queue_depth: usize,
    /// resident capacity: queue_depth + workers·max_batch
    pub capacity: usize,
    /// offered concurrency (clients) over resident capacity
    pub offered_ratio: f64,
}

impl OverloadReport {
    /// shed / offered ∈ [0, 1].
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }
}

/// Sustained-overload load test: `clients` threads each issue
/// `per_client` predicts back-to-back with zero think time. Sized with
/// `clients` well above [`Engine::capacity`] (the bench drives ≥ 4×),
/// the admission queue saturates and the excess load-sheds: shed
/// requests fail fast with the typed [`EngineError::Overloaded`] and are
/// counted (the client yields and moves to its next request); accepted
/// ones contribute latency samples. Any other error aborts the drive.
/// Use a fresh engine per drive — `peak_depth` reads engine-lifetime
/// stats.
pub fn drive_overload(
    engine: &Engine,
    per_client: usize,
    clients: usize,
    seed: u64,
) -> Result<OverloadReport> {
    let per_client = per_client.max(1);
    let clients = clients.max(1);
    let in_dim = engine.model().in_dim;
    let per: Vec<Result<(Vec<f64>, usize)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || -> Result<(Vec<f64>, usize)> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut lat = Vec::new();
                    let mut shed = 0usize;
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
                        match engine.predict(&x) {
                            Ok(p) => lat.push(p.latency.as_secs_f64() * 1e3),
                            Err(EngineError::Overloaded { .. }) => {
                                shed += 1;
                                // an aggressive client retries immediately
                                // with its next request; the yield keeps
                                // the shed path from starving admitted
                                // work of a core
                                std::thread::yield_now();
                            }
                            Err(e) => return Err(e.into()),
                        }
                    }
                    Ok((lat, shed))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("overload client panicked"))
            .collect()
    });
    let mut accepted_lat_ms = Vec::new();
    let mut shed = 0usize;
    for r in per {
        let (l, s) = r?;
        accepted_lat_ms.extend(l);
        shed += s;
    }
    let stats = engine.stats();
    Ok(OverloadReport {
        offered: per_client * clients,
        accepted: accepted_lat_ms.len(),
        shed,
        accepted_lat_ms,
        peak_depth: stats.peak_depth,
        queue_depth: engine.queue_depth(),
        capacity: engine.capacity(),
        offered_ratio: clients as f64 / engine.capacity() as f64,
    })
}

// ----------------------------------------------------------- aggregation

/// Latency distribution summary (milliseconds) — shared by the `infer`
/// CLI subcommand and `benches/infer_serve.rs`.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Nearest-rank percentiles over per-request latencies in milliseconds
/// (via the shared [`crate::bench::percentile`], so serving numbers stay
/// comparable with the kernel benches).
pub fn latency_summary(lat_ms: &[f64]) -> LatencySummary {
    if lat_ms.is_empty() {
        return LatencySummary {
            count: 0,
            mean_ms: f64::NAN,
            p50_ms: f64::NAN,
            p95_ms: f64::NAN,
            p99_ms: f64::NAN,
            max_ms: f64::NAN,
        };
    }
    let mut sorted = lat_ms.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    LatencySummary {
        count: sorted.len(),
        mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
        p50_ms: crate::bench::percentile(&sorted, 0.50),
        p95_ms: crate::bench::percentile(&sorted, 0.95),
        p99_ms: crate::bench::percentile(&sorted, 0.99),
        max_ms: *sorted.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::BsrLayer;
    use crate::util::rng::Rng;

    fn tiny_model(seed: u64) -> (BsrModel, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w1: Vec<f32> = (0..6 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..4 * 6).map(|_| rng.normal()).collect();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![
                BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap(),
                BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap(),
            ],
        };
        (model, w1, w2)
    }

    fn opts(max_batch: usize, workers: usize, queue_depth: usize) -> EngineOpts {
        EngineOpts { max_batch, workers, queue_depth }
    }

    #[test]
    fn predict_matches_direct_forward() {
        let (model, _, _) = tiny_model(41);
        let reference = model.clone();
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let p = engine.predict(&x).unwrap();
            let want = bsr::model_forward(&reference, &x, 1).unwrap();
            assert_eq!(p.logits, want);
            assert_eq!(p.class, bsr::argmax_rows(&want, 1, 4)[0]);
            assert!(p.batch_size >= 1 && p.batch_size <= 4);
            assert_eq!(p.generation, 0);
        }
        let st = engine.stats();
        assert_eq!(st.accepted, 10);
        assert_eq!(st.completed, 10);
        assert_eq!((st.shed, st.failed), (0, 0));
    }

    #[test]
    fn concurrent_clients_all_get_their_own_answer() {
        let (model, _, _) = tiny_model(43);
        let reference = model.clone();
        let engine = Engine::new(model, opts(8, 3, 64)).unwrap();
        let results: Vec<(Vec<f32>, Prediction)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16)
                .map(|c| {
                    let engine = &engine;
                    s.spawn(move || {
                        let mut rng = Rng::new(100 + c as u64);
                        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                        let p = engine.predict(&x).unwrap();
                        (x, p)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 16);
        for (x, p) in &results {
            let want = bsr::model_forward(&reference, x, 1).unwrap();
            assert_eq!(&p.logits, &want, "a client got another client's logits");
        }
    }

    #[test]
    fn predict_rejects_wrong_feature_count() {
        let (model, _, _) = tiny_model(44);
        let engine = Engine::new(model, EngineOpts::default()).unwrap();
        assert!(matches!(engine.predict(&[0.0; 7]), Err(EngineError::BadRequest(_))));
        assert!(engine.predict(&[0.0; 8]).is_ok());
    }

    #[test]
    fn drop_with_idle_engine_does_not_hang() {
        let (model, _, _) = tiny_model(45);
        let engine = Engine::new(model, opts(2, 1, 8)).unwrap();
        drop(engine);
    }

    #[test]
    fn drive_synthetic_collects_every_request() {
        let (model, _, _) = tiny_model(46);
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        // 10 requests over 3 clients: quotas 4/3/3, all latencies returned
        let lat = drive_synthetic(&engine, 10, 3, 7).unwrap();
        assert_eq!(lat.len(), 10);
        assert!(lat.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    /// Deterministic shed: with dispatch paused the queue cannot drain,
    /// so filling it to the bound makes the next predict fail fast with
    /// the typed Overloaded error — and the engine recovers on resume.
    #[test]
    fn full_queue_sheds_with_typed_overload_error() {
        let (model, _, _) = tiny_model(47);
        let engine = Engine::new(model, opts(4, 1, 2)).unwrap();
        engine.pause();
        let blocked: Vec<Result<Prediction, EngineError>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let engine = &engine;
                    s.spawn(move || engine.predict(&[0.5; 8]))
                })
                .collect();
            // wait until both requests are actually queued
            while engine.stats().depth < 2 {
                std::thread::yield_now();
            }
            // the queue is at its bound: the next predict sheds, O(1),
            // without blocking
            match engine.predict(&[0.5; 8]) {
                Err(EngineError::Overloaded { depth }) => assert_eq!(depth, 2),
                other => panic!("wanted Overloaded, got {other:?}"),
            }
            engine.resume();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in blocked {
            r.expect("queued requests complete after resume");
        }
        let st = engine.stats();
        assert_eq!(st.shed, 1);
        assert_eq!(st.accepted, 2);
        assert_eq!(st.completed, 2);
        assert!(st.peak_depth <= 2, "queue depth {} exceeded the bound", st.peak_depth);
    }

    /// A failing forward must answer every waiter with the root-cause
    /// error — the old code dropped the senders and clients saw a
    /// fabricated "batch failed?" recv error.
    #[test]
    fn run_batch_sends_root_cause_to_every_waiter() {
        let (model, _, _) = tiny_model(48);
        let mut broken = model;
        // passes Engine-level shape checks at build time but the kernel's
        // own validation rejects it: payload out of sync with the index
        broken.layers[0].blocks.pop();
        let deployed = Deployed { model: Arc::new(broken), generation: 3 };
        let queue = Queue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                paused: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                peak_depth: 0,
            }),
            cv: Condvar::new(),
        };
        let mut rxs = Vec::new();
        let batch: Vec<Pending> = (0..3)
            .map(|_| {
                let (tx, rx) = mpsc::channel();
                rxs.push(rx);
                Pending { x: vec![0.0; 8], enqueued: Instant::now(), tx }
            })
            .collect();
        run_batch(&deployed, &queue, batch);
        for rx in rxs {
            match rx.recv().expect("waiter must be answered, not dropped") {
                Err(EngineError::BatchFailed(msg)) => {
                    assert!(
                        msg.contains("block values") && msg.contains("fc1"),
                        "root cause lost: {msg}"
                    );
                }
                other => panic!("wanted BatchFailed, got {other:?}"),
            }
        }
        assert_eq!(queue.state.lock().unwrap().failed, 3);
    }

    /// A client that gave up (dropped its receiver) must not take down
    /// the batch — the other waiters still get their answers.
    #[test]
    fn run_batch_survives_dropped_waiter() {
        let (model, _, _) = tiny_model(49);
        let deployed = Deployed { model: Arc::new(model), generation: 0 };
        let queue = Queue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                paused: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                peak_depth: 0,
            }),
            cv: Condvar::new(),
        };
        let (tx_gone, rx_gone) = mpsc::channel();
        drop(rx_gone); // this client raced away (timeout / disconnect)
        let (tx_live, rx_live) = mpsc::channel();
        let batch = vec![
            Pending { x: vec![0.1; 8], enqueued: Instant::now(), tx: tx_gone },
            Pending { x: vec![0.2; 8], enqueued: Instant::now(), tx: tx_live },
        ];
        run_batch(&deployed, &queue, batch);
        let got = rx_live.recv().unwrap().unwrap();
        assert_eq!(got.batch_size, 2);
        assert_eq!(queue.state.lock().unwrap().completed, 2);
    }

    #[test]
    fn hot_swap_routes_new_requests_and_tags_generations() {
        let (a, _, _) = tiny_model(50);
        let (b, _, _) = tiny_model(51);
        let (ref_a, ref_b) = (a.clone(), b.clone());
        let engine = Engine::new(a, opts(4, 2, 64)).unwrap();
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.25 - 1.0).collect();
        let p0 = engine.predict(&x).unwrap();
        assert_eq!(p0.generation, 0);
        assert_eq!(p0.logits, bsr::model_forward(&ref_a, &x, 1).unwrap());
        let generation = engine.swap_model(b).unwrap();
        assert_eq!(generation, 1);
        assert_eq!(engine.generation(), 1);
        let p1 = engine.predict(&x).unwrap();
        assert_eq!(p1.generation, 1);
        assert_eq!(p1.logits, bsr::model_forward(&ref_b, &x, 1).unwrap());
        // a mismatched replacement is rejected: queued requests were
        // admitted against the engine's shapes
        let mut rng = Rng::new(52);
        let w: Vec<f32> = (0..4 * 6).map(|_| rng.normal()).collect();
        let mismatched = BsrModel {
            spec: "other".into(),
            method: "dense".into(),
            in_dim: 6,
            out_dim: 4,
            layers: vec![BsrLayer::from_dense("fc", &w, 4, 6, 2, 2).unwrap()],
        };
        assert!(matches!(engine.swap_model(mismatched), Err(EngineError::SwapRejected(_))));
        // an invalid replacement is rejected before the swap
        let (mut corrupt, _, _) = tiny_model(53);
        corrupt.layers[1].col_idx[0] = 99;
        assert!(matches!(engine.swap_model(corrupt), Err(EngineError::SwapRejected(_))));
        assert_eq!(engine.generation(), 1, "rejected swaps must not bump the generation");
    }

    #[test]
    fn drive_overload_accounts_every_request() {
        let (model, _, _) = tiny_model(54);
        let engine = Engine::new(model, opts(2, 1, 2)).unwrap();
        assert_eq!(engine.capacity(), 2 + 2);
        let rep = drive_overload(&engine, 8, 8, 11).unwrap();
        assert_eq!(rep.offered, 64);
        assert_eq!(rep.accepted + rep.shed, rep.offered);
        assert_eq!(rep.accepted_lat_ms.len(), rep.accepted);
        assert!(rep.accepted >= 1, "a drive must accept something");
        assert!(rep.peak_depth <= rep.queue_depth, "the bound was breached");
        assert!((rep.offered_ratio - 2.0).abs() < 1e-12);
        assert!(rep.shed_rate() >= 0.0 && rep.shed_rate() <= 1.0);
        // engine counters agree with the report
        let st = engine.stats();
        assert_eq!(st.shed, rep.shed as u64);
        assert_eq!(st.accepted, rep.accepted as u64);
    }

    #[test]
    fn latency_summary_percentiles() {
        // nearest-rank (ceil(p·N)−1) pinned exactly on 1..=100: p50 is the
        // 50th sorted value, p95 the 95th, p99 the 99th, and p100 ≡ max
        let lat: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = latency_summary(&lat);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        // the empty summary is all-NaN (count 0) — the JSON writers must
        // map those to nulls, pinned in util::json
        let empty = latency_summary(&[]);
        assert_eq!(empty.count, 0);
        assert!(empty.mean_ms.is_nan() && empty.p99_ms.is_nan() && empty.max_ms.is_nan());
    }
}
