//! Batched serving engine: a request queue with dynamic micro-batching.
//!
//! Clients call [`Engine::predict`] (blocking). A dispatcher thread drains
//! the queue into micro-batches — whatever is waiting, capped at
//! `max_batch`, with no artificial fill delay — and submits each batch to
//! a `util::pool::ThreadPool`, keeping at most one batch in flight per
//! pool worker. Under light load a request rides alone (lowest latency);
//! under sustained load the in-flight bound makes the backlog accumulate
//! while workers are busy, so later batches genuinely fill toward
//! `max_batch` (highest throughput) — the classic dynamic-batching trade
//! handled without tuning knobs beyond `max_batch` and the worker count.
//!
//! Every response carries per-request latency (enqueue → logits ready) and
//! the micro-batch size it rode in, which is exactly what the serving
//! bench aggregates into p50/p95/p99.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::util::pool::ThreadPool;

use super::{bsr, BsrModel};

/// One served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// raw logits (out_dim values)
    pub logits: Vec<f32>,
    /// argmax class id (first maximum on ties)
    pub class: usize,
    /// request enqueue → response ready (queueing + compute)
    pub latency: Duration,
    /// size of the micro-batch this request rode in
    pub batch_size: usize,
}

struct Pending {
    x: Vec<f32>,
    enqueued: Instant,
    tx: mpsc::Sender<Prediction>,
}

struct QueueState {
    q: VecDeque<Pending>,
    /// micro-batches currently executing on the pool — the dispatcher only
    /// forms a new batch while this is below the worker count, so under
    /// sustained load requests accumulate and batches actually fill toward
    /// `max_batch` instead of racing through one-by-one
    in_flight: usize,
    shutdown: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// Engine sizing.
pub struct EngineOpts {
    /// micro-batch cap: the dispatcher never packs more rows than this
    pub max_batch: usize,
    /// pool workers executing micro-batches concurrently
    pub workers: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineOpts { max_batch: 32, workers: cores.saturating_sub(1).clamp(1, 8) }
    }
}

/// A running inference engine over one [`BsrModel`].
pub struct Engine {
    model: Arc<BsrModel>,
    queue: Arc<Queue>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn new(model: BsrModel, opts: EngineOpts) -> Result<Engine> {
        model.validate()?;
        let model = Arc::new(model);
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let max_batch = opts.max_batch.max(1);
        let workers = opts.workers.max(1);
        let pool = ThreadPool::new(workers);
        let (qc, mc) = (queue.clone(), model.clone());
        let dispatcher = std::thread::Builder::new()
            .name("bsr-dispatch".to_string())
            .spawn(move || dispatch_loop(qc, mc, pool, max_batch, workers))
            .map_err(|e| anyhow!("spawning engine dispatcher: {e}"))?;
        Ok(Engine { model, queue, dispatcher: Some(dispatcher) })
    }

    pub fn model(&self) -> &BsrModel {
        &self.model
    }

    /// Blocking single-request predict: enqueue, wait for the micro-batch
    /// carrying this request to finish, return logits + argmax + latency.
    /// Safe to call from many client threads at once — that is what fills
    /// the micro-batches.
    pub fn predict(&self, x: &[f32]) -> Result<Prediction> {
        if x.len() != self.model.in_dim {
            bail!(
                "request has {} features, model '{}' wants {}",
                x.len(), self.model.spec, self.model.in_dim
            );
        }
        let (tx, rx) = mpsc::channel();
        // the payload copy is per-request-private: build it before taking
        // the shared lock so concurrent clients don't serialize on it
        let pending = Pending { x: x.to_vec(), enqueued: Instant::now(), tx };
        {
            let mut st = self.queue.state.lock().unwrap();
            if st.shutdown {
                bail!("engine is shut down");
            }
            st.q.push_back(pending);
        }
        self.queue.cv.notify_one();
        rx.recv().map_err(|_| anyhow!("engine dropped the request (batch failed?)"))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        {
            let mut st = self.queue.state.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cv.notify_all();
        // the dispatcher drains what is still queued, then its pool drop
        // joins the in-flight micro-batches — no request is abandoned
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    queue: Arc<Queue>,
    model: Arc<BsrModel>,
    pool: ThreadPool,
    max_batch: usize,
    workers: usize,
) {
    loop {
        let batch: Vec<Pending> = {
            let mut st = queue.state.lock().unwrap();
            loop {
                // bounded in-flight: only form a batch when a pool worker
                // can take it, so a sustained backlog fills later batches
                // toward max_batch instead of flooding the pool queue with
                // size-1 batches
                if !st.q.is_empty() && st.in_flight < workers {
                    let take = st.q.len().min(max_batch);
                    st.in_flight += 1;
                    break st.q.drain(..take).collect();
                }
                if st.shutdown && st.q.is_empty() {
                    return; // pool drops here: joins outstanding batches
                }
                st = queue.cv.wait(st).unwrap();
            }
        };
        let (m, q) = (model.clone(), queue.clone());
        pool.submit(move || {
            // the pool catch_unwind's jobs and keeps its workers alive, so
            // the slot release must survive a panicking batch too — a drop
            // guard runs on unwind, where a trailing statement would not
            // (a leaked slot would eventually wedge the dispatcher for
            // good once every slot leaked)
            struct SlotGuard(Arc<Queue>);
            impl Drop for SlotGuard {
                fn drop(&mut self) {
                    let mut st = self.0.state.lock().unwrap();
                    st.in_flight -= 1;
                    drop(st);
                    // wake the dispatcher: a worker slot is free again
                    self.0.cv.notify_all();
                }
            }
            let _slot = SlotGuard(q);
            run_batch(&m, batch);
        });
    }
}

fn run_batch(model: &BsrModel, batch: Vec<Pending>) {
    let nb = batch.len();
    let mut xs = Vec::with_capacity(nb * model.in_dim);
    for p in &batch {
        xs.extend_from_slice(&p.x);
    }
    match bsr::model_forward(model, &xs, nb) {
        Ok(z) => {
            let classes = model.out_dim;
            let preds = bsr::argmax_rows(&z, nb, classes);
            for (i, p) in batch.into_iter().enumerate() {
                let resp = Prediction {
                    logits: z[i * classes..(i + 1) * classes].to_vec(),
                    class: preds[i],
                    latency: p.enqueued.elapsed(),
                    batch_size: nb,
                };
                // a client that gave up (dropped rx) is not an engine error
                let _ = p.tx.send(resp);
            }
        }
        Err(e) => {
            // dropping the senders wakes every waiter with a recv error
            crate::warn_!("micro-batch of {nb} failed: {e:#}");
        }
    }
}

/// Drive an engine with synthetic random-normal traffic: `clients`
/// concurrent threads issue `requests` predicts in total (quota split
/// evenly, remainder to the first threads), each with its own
/// seed-derived RNG. Returns every request's latency in milliseconds —
/// feed to [`latency_summary`]. Shared by the `infer` CLI subcommand and
/// `benches/infer_serve.rs` so the measured traffic shape cannot diverge
/// between them.
pub fn drive_synthetic(
    engine: &Engine,
    requests: usize,
    clients: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let requests = requests.max(1);
    let clients = clients.max(1);
    let in_dim = engine.model().in_dim;
    let per_client: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let quota = requests / clients + usize::from(c < requests % clients);
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut lat = Vec::with_capacity(quota);
                    for _ in 0..quota {
                        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
                        lat.push(engine.predict(&x)?.latency.as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(requests);
    for r in per_client {
        out.extend(r?);
    }
    Ok(out)
}

// ----------------------------------------------------------- aggregation

/// Latency distribution summary (milliseconds) — shared by the `infer`
/// CLI subcommand and `benches/infer_serve.rs`.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Nearest-rank percentiles over per-request latencies in milliseconds
/// (via the shared [`crate::bench::percentile`], so serving numbers stay
/// comparable with the kernel benches).
pub fn latency_summary(lat_ms: &[f64]) -> LatencySummary {
    if lat_ms.is_empty() {
        return LatencySummary {
            count: 0,
            mean_ms: f64::NAN,
            p50_ms: f64::NAN,
            p95_ms: f64::NAN,
            p99_ms: f64::NAN,
            max_ms: f64::NAN,
        };
    }
    let mut sorted = lat_ms.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    LatencySummary {
        count: sorted.len(),
        mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
        p50_ms: crate::bench::percentile(&sorted, 0.50),
        p95_ms: crate::bench::percentile(&sorted, 0.95),
        p99_ms: crate::bench::percentile(&sorted, 0.99),
        max_ms: *sorted.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::BsrLayer;
    use crate::util::rng::Rng;

    fn tiny_model(seed: u64) -> (BsrModel, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w1: Vec<f32> = (0..6 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..4 * 6).map(|_| rng.normal()).collect();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![
                BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap(),
                BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap(),
            ],
        };
        (model, w1, w2)
    }

    #[test]
    fn predict_matches_direct_forward() {
        let (model, _, _) = tiny_model(41);
        let reference = model.clone();
        let engine =
            Engine::new(model, EngineOpts { max_batch: 4, workers: 2 }).unwrap();
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let p = engine.predict(&x).unwrap();
            let want = bsr::model_forward(&reference, &x, 1).unwrap();
            assert_eq!(p.logits, want);
            assert_eq!(p.class, bsr::argmax_rows(&want, 1, 4)[0]);
            assert!(p.batch_size >= 1 && p.batch_size <= 4);
        }
    }

    #[test]
    fn concurrent_clients_all_get_their_own_answer() {
        let (model, _, _) = tiny_model(43);
        let reference = model.clone();
        let engine =
            Engine::new(model, EngineOpts { max_batch: 8, workers: 3 }).unwrap();
        let results: Vec<(Vec<f32>, Prediction)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16)
                .map(|c| {
                    let engine = &engine;
                    s.spawn(move || {
                        let mut rng = Rng::new(100 + c as u64);
                        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                        let p = engine.predict(&x).unwrap();
                        (x, p)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 16);
        for (x, p) in &results {
            let want = bsr::model_forward(&reference, x, 1).unwrap();
            assert_eq!(&p.logits, &want, "a client got another client's logits");
        }
    }

    #[test]
    fn predict_rejects_wrong_feature_count() {
        let (model, _, _) = tiny_model(44);
        let engine = Engine::new(model, EngineOpts::default()).unwrap();
        assert!(engine.predict(&[0.0; 7]).is_err());
        assert!(engine.predict(&[0.0; 8]).is_ok());
    }

    #[test]
    fn drop_with_idle_engine_does_not_hang() {
        let (model, _, _) = tiny_model(45);
        let engine = Engine::new(model, EngineOpts { max_batch: 2, workers: 1 }).unwrap();
        drop(engine);
    }

    #[test]
    fn drive_synthetic_collects_every_request() {
        let (model, _, _) = tiny_model(46);
        let engine =
            Engine::new(model, EngineOpts { max_batch: 4, workers: 2 }).unwrap();
        // 10 requests over 3 clients: quotas 4/3/3, all latencies returned
        let lat = drive_synthetic(&engine, 10, 3, 7).unwrap();
        assert_eq!(lat.len(), 10);
        assert!(lat.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn latency_summary_percentiles() {
        // nearest-rank (ceil(p·N)−1) pinned exactly on 1..=100: p50 is the
        // 50th sorted value, p95 the 95th, p99 the 99th, and p100 ≡ max
        let lat: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = latency_summary(&lat);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        // the empty summary is all-NaN (count 0) — the JSON writers must
        // map those to nulls, pinned in util::json
        let empty = latency_summary(&[]);
        assert_eq!(empty.count, 0);
        assert!(empty.mean_ms.is_nan() && empty.p99_ms.is_nan() && empty.max_ms.is_nan());
    }
}
