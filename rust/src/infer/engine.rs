//! Batched serving engine v3: bounded admission, dynamic micro-batching,
//! typed load-shed, atomic hot-swap — and a completion-slot async request
//! path.
//!
//! Clients call [`Engine::predict_async`], which admits the request and
//! returns a [`PredictionHandle`] immediately: a completion-based future
//! backed by a slot the executing micro-batch fills. The handle polls
//! without a condvar ([`PredictionHandle::is_ready`] /
//! [`PredictionHandle::try_take`] are one atomic load) and
//! [`PredictionHandle::wait`] parks the calling thread only if the result
//! is not in yet (batch completion unparks it) — so **N in-flight
//! requests cost N queue slots, not N parked OS threads**: one driver
//! thread can keep hundreds of requests in flight while the process runs
//! `workers + constant` threads total. The blocking [`Engine::predict`]
//! is a thin `predict_async(x)?.wait()` wrapper.
//!
//! Admission is **bounded**: the request queue holds at most
//! `queue_depth` requests, and a request arriving at a full queue fails
//! fast with the typed [`EngineError::Overloaded`] instead of queueing
//! forever — under sustained overload the backlog (and client-visible
//! latency) is capped by configuration, and the excess is shed at the
//! door where the client can retry elsewhere. A dispatcher thread drains
//! admitted requests into micro-batches — whatever is waiting, capped at
//! `max_batch`, with no artificial fill delay — and submits each batch to
//! a `util::pool::ThreadPool`, keeping at most one batch in flight per
//! pool worker. Under light load a request rides alone (lowest latency);
//! under sustained load the in-flight bound makes the backlog accumulate
//! while workers are busy, so later batches genuinely fill toward
//! `max_batch` (highest throughput).
//!
//! Failures propagate: a micro-batch whose forward errors completes
//! **every** waiter's slot with the root-cause message as
//! [`EngineError::BatchFailed`] — no abandoned slots, no fabricated guess
//! at the cause. Every admitted slot is completed exactly once: by its
//! batch, or by the shutdown drain.
//!
//! Models hot-swap atomically ([`Engine::swap_model`]): the replacement —
//! any [`ServedModel`], f32 or int8, the engine is dtype-agnostic — is
//! installed with a single `Arc` pointer swap, new micro-batches route to
//! it immediately, and batches already formed finish on the model they
//! started with — one request never mixes logits from two models. Each
//! [`Prediction`] carries the `generation` that served it. The on-disk
//! half of the same discipline is `BsrModel::save`'s write-then-rename
//! publish (uv-style), so a reader never observes a torn artifact.
//!
//! Every response carries per-request latency (enqueue → logits ready)
//! and the micro-batch size it rode in; [`Engine::stats`] exposes the
//! accepted/shed/completed/failed counters and the peak queue depth the
//! overload bench gates on.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::pool::ThreadPool;

use super::{bsr, ServedModel};

/// Typed serving errors — [`Engine::predict`]'s error type. Implements
/// `std::error::Error`, so `?` converts it into `anyhow::Error` at call
/// sites that just propagate, while load-shedding callers (and tests)
/// match on the variant directly (the vendored anyhow has no downcast).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The admission queue is at its configured depth: the request was
    /// load-shed without queueing. Fail-fast by design — retry against
    /// another replica or back off.
    Overloaded {
        /// the configured admission bound that was hit
        depth: usize,
    },
    /// The engine has shut down (or tore down while the request waited).
    ShutDown,
    /// The request itself is malformed (feature-count mismatch).
    BadRequest(String),
    /// The micro-batch carrying this request failed; the message is the
    /// actual forward error, chain included.
    BatchFailed(String),
    /// [`Engine::swap_model`] refused the replacement model.
    SwapRejected(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Overloaded { depth } => write!(
                f,
                "engine overloaded: admission queue at its bound of {depth} requests (load shed)"
            ),
            EngineError::ShutDown => write!(f, "engine is shut down"),
            EngineError::BadRequest(m) => write!(f, "bad request: {m}"),
            EngineError::BatchFailed(m) => write!(f, "micro-batch failed: {m}"),
            EngineError::SwapRejected(m) => write!(f, "hot-swap rejected: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// One served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// raw logits (out_dim values)
    pub logits: Vec<f32>,
    /// argmax class id (first maximum on ties)
    pub class: usize,
    /// request enqueue → response ready (queueing + compute)
    pub latency: Duration,
    /// size of the micro-batch this request rode in
    pub batch_size: usize,
    /// which deployed model served it: 0 for the construction model,
    /// bumped by every [`Engine::swap_model`]
    pub generation: u64,
}

// ------------------------------------------------------- completion slots

/// Who to wake when a slot completes. `Thread` is a parked
/// [`PredictionHandle::wait`] caller; `None` means the owner is polling
/// (or has not started waiting yet) — completion just publishes the
/// result.
enum Waiter {
    None,
    Thread(std::thread::Thread),
}

struct SlotState {
    result: Option<Result<Prediction, EngineError>>,
    waiter: Waiter,
}

/// One request's completion slot. The executing micro-batch (or the
/// shutdown drain) fills it exactly once; the [`PredictionHandle`] side
/// polls `ready` lock-free and only touches the mutex to take the result
/// or to register itself for a wakeup.
struct Slot {
    /// Acquire/Release flag mirroring `result.is_some()`: set *after* the
    /// result is stored, so a handle that observes `ready == true` is
    /// guaranteed to find the result under the lock.
    ready: AtomicBool,
    inner: Mutex<SlotState>,
}

impl Slot {
    fn new() -> Arc<Slot> {
        Arc::new(Slot {
            ready: AtomicBool::new(false),
            inner: Mutex::new(SlotState { result: None, waiter: Waiter::None }),
        })
    }

    /// Publish the result and wake the waiter, if one is parked. Called
    /// exactly once per slot.
    fn complete(&self, res: Result<Prediction, EngineError>) {
        let waiter = {
            let mut st = self.inner.lock().unwrap();
            debug_assert!(st.result.is_none(), "slot completed twice");
            st.result = Some(res);
            std::mem::replace(&mut st.waiter, Waiter::None)
        };
        // ready flips only after the result is in place (Release pairs
        // with the Acquire load in is_ready/try_take)
        self.ready.store(true, Ordering::Release);
        if let Waiter::Thread(t) = waiter {
            t.unpark();
        }
    }
}

/// A completion-based future for one admitted request — what
/// [`Engine::predict_async`] returns. Holding a handle costs one queue
/// slot and **zero threads**: poll it ([`PredictionHandle::is_ready`] /
/// [`PredictionHandle::try_take`]) from any loop, or park this thread on
/// it ([`PredictionHandle::wait`]). Dropping the handle abandons the
/// response (the request still executes and is counted; nothing leaks
/// and the batch never notices).
pub struct PredictionHandle {
    slot: Arc<Slot>,
}

impl PredictionHandle {
    /// Whether the result is in — one atomic load, no lock, no syscall.
    pub fn is_ready(&self) -> bool {
        self.slot.ready.load(Ordering::Acquire)
    }

    /// Take the result if it is in (`None` = still in flight). After the
    /// first `Some`, subsequent calls return `None` — the result moves
    /// out exactly once.
    pub fn try_take(&mut self) -> Option<Result<Prediction, EngineError>> {
        if !self.is_ready() {
            return None;
        }
        self.slot.inner.lock().unwrap().result.take()
    }

    /// Block until the result is in: park this thread, let the completing
    /// micro-batch unpark it. Consumes the handle — the blocking
    /// [`Engine::predict`] is exactly `predict_async(x)?.wait()`.
    pub fn wait(mut self) -> Result<Prediction, EngineError> {
        loop {
            if let Some(res) = self.try_take() {
                return res;
            }
            {
                // register for a wakeup, then re-check under the same
                // lock — a completion racing ahead of the registration
                // would otherwise be a lost wakeup
                let mut st = self.slot.inner.lock().unwrap();
                if let Some(res) = st.result.take() {
                    return res;
                }
                st.waiter = Waiter::Thread(std::thread::current());
            }
            // park() may return spuriously; the loop re-checks. An
            // unpark() that raced in before this park() makes it return
            // immediately (the park token).
            std::thread::park();
        }
    }
}

struct Pending {
    x: Vec<f32>,
    enqueued: Instant,
    slot: Arc<Slot>,
}

/// The model a micro-batch is pinned to: swapped as one `Arc`, so a batch
/// either sees (old model, old generation) or (new, new) — never a mix.
struct Deployed {
    model: Arc<ServedModel>,
    generation: u64,
}

struct QueueState {
    q: VecDeque<Pending>,
    /// micro-batches currently executing on the pool — the dispatcher only
    /// forms a new batch while this is below the worker count, so under
    /// sustained load requests accumulate and batches actually fill toward
    /// `max_batch` instead of racing through one-by-one
    in_flight: usize,
    shutdown: bool,
    /// dispatch hold: admitted requests stay queued (maintenance drains,
    /// deterministic tests). Admission — and therefore shedding at the
    /// bound — continues while paused.
    paused: bool,
    accepted: u64,
    shed: u64,
    completed: u64,
    failed: u64,
    peak_depth: usize,
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Queue {
    fn new() -> Queue {
        Queue {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
                paused: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                peak_depth: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Counter snapshot from [`Engine::stats`].
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// requests admitted into the queue since construction
    pub accepted: u64,
    /// requests load-shed at the admission bound
    pub shed: u64,
    /// requests answered with logits
    pub completed: u64,
    /// requests answered with a batch failure
    pub failed: u64,
    /// maximum queue depth ever observed (≤ the configured bound)
    pub peak_depth: usize,
    /// current queue depth
    pub depth: usize,
    /// generation of the currently deployed model
    pub generation: u64,
}

/// Engine sizing.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// micro-batch cap: the dispatcher never packs more rows than this
    pub max_batch: usize,
    /// pool workers executing micro-batches concurrently
    pub workers: usize,
    /// admission bound: a predict arriving with this many requests queued
    /// is load-shed with [`EngineError::Overloaded`]
    pub queue_depth: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineOpts {
            max_batch: 32,
            // shared crate-wide clamp (1..=util::MAX_WORKERS) — the old
            // 1..=8 here silently disagreed with the kernels' 1..=16
            workers: crate::util::env_workers("BS_SERVE_WORKERS", cores.saturating_sub(1)),
            queue_depth: 256,
        }
    }
}

/// A running inference engine over a hot-swappable [`ServedModel`]
/// (f32 or int8 — the request path is dtype-agnostic).
pub struct Engine {
    current: Arc<Mutex<Arc<Deployed>>>,
    queue: Arc<Queue>,
    in_dim: usize,
    out_dim: usize,
    opts: EngineOpts,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn new(model: impl Into<ServedModel>, opts: EngineOpts) -> Result<Engine> {
        let model: ServedModel = model.into();
        model.validate()?;
        let (in_dim, out_dim) = (model.in_dim(), model.out_dim());
        let opts = EngineOpts {
            max_batch: opts.max_batch.max(1),
            workers: crate::util::clamp_workers(opts.workers),
            queue_depth: opts.queue_depth.max(1),
        };
        let current = Arc::new(Mutex::new(Arc::new(Deployed {
            model: Arc::new(model),
            generation: 0,
        })));
        let queue = Arc::new(Queue::new());
        let pool = ThreadPool::new(opts.workers);
        let (qc, cc) = (queue.clone(), current.clone());
        let (max_batch, workers) = (opts.max_batch, opts.workers);
        let dispatcher = std::thread::Builder::new()
            .name("bsr-dispatch".to_string())
            .spawn(move || dispatch_loop(qc, cc, pool, max_batch, workers))
            .map_err(|e| anyhow!("spawning engine dispatcher: {e}"))?;
        Ok(Engine { current, queue, in_dim, out_dim, opts, dispatcher: Some(dispatcher) })
    }

    /// The currently deployed model (the next micro-batch's model; an
    /// in-flight batch may still be on the previous one).
    pub fn model(&self) -> Arc<ServedModel> {
        self.current.lock().unwrap().model.clone()
    }

    /// Generation of the currently deployed model (0 at construction,
    /// +1 per [`Engine::swap_model`]).
    pub fn generation(&self) -> u64 {
        self.current.lock().unwrap().generation
    }

    /// Feature count every request must carry (fixed at construction —
    /// swaps must match it).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Logit count every response carries.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn max_batch(&self) -> usize {
        self.opts.max_batch
    }

    pub fn workers(&self) -> usize {
        self.opts.workers
    }

    /// The configured admission bound.
    pub fn queue_depth(&self) -> usize {
        self.opts.queue_depth
    }

    /// Resident-request capacity: queued (`queue_depth`) plus executing
    /// (`workers · max_batch`). Offered concurrency beyond this sheds.
    pub fn capacity(&self) -> usize {
        self.opts.queue_depth + self.opts.workers * self.opts.max_batch
    }

    /// Counter snapshot (monotonic since construction, except `depth`).
    pub fn stats(&self) -> EngineStats {
        let (accepted, shed, completed, failed, peak_depth, depth) = {
            let st = self.queue.state.lock().unwrap();
            (st.accepted, st.shed, st.completed, st.failed, st.peak_depth, st.q.len())
        };
        // generation is read after the queue lock is released — the two
        // locks are never held together anywhere in the engine
        EngineStats { accepted, shed, completed, failed, peak_depth, depth, generation: self.generation() }
    }

    /// Hold dispatch: admitted requests stay queued until [`Engine::resume`].
    /// Admission (and shedding at the bound) continues. Maintenance /
    /// deterministic-test hook; dropping the engine drains regardless.
    pub fn pause(&self) {
        self.queue.state.lock().unwrap().paused = true;
    }

    /// Resume dispatch after [`Engine::pause`].
    pub fn resume(&self) {
        self.queue.state.lock().unwrap().paused = false;
        self.queue.cv.notify_all();
    }

    /// Stop admission now: every subsequent predict fails fast with
    /// [`EngineError::ShutDown`], while requests already admitted drain
    /// normally (shutdown overrides pause) and their handles complete.
    /// Idempotent, callable from any thread — racing it against live
    /// traffic is safe and is exactly what the stress tests do. Dropping
    /// the engine calls this and then joins the dispatcher.
    pub fn shutdown(&self) {
        self.queue.state.lock().unwrap().shutdown = true;
        self.queue.cv.notify_all();
    }

    /// Atomically deploy `model`: one `Arc` swap in memory. New
    /// micro-batches route to it immediately; batches already formed
    /// finish on the model they started with, so a request never mixes
    /// generations. The replacement must validate and match the engine's
    /// (in_dim, out_dim) — queued requests were admitted against those
    /// shapes; its dtype may differ (f32 → int8 swaps are how quantized
    /// artifacts roll out). Returns the new generation. O(1) beyond
    /// validation: no engine teardown, no thread respawn, no queue
    /// disturbance.
    pub fn swap_model(&self, model: impl Into<ServedModel>) -> Result<u64, EngineError> {
        let model: ServedModel = model.into();
        if let Err(e) = model.validate() {
            return Err(EngineError::SwapRejected(format!("{e:#}")));
        }
        if model.in_dim() != self.in_dim || model.out_dim() != self.out_dim {
            return Err(EngineError::SwapRejected(format!(
                "model '{}' is {}->{}, engine serves {}->{}",
                model.spec(), model.in_dim(), model.out_dim(), self.in_dim, self.out_dim
            )));
        }
        let mut cur = self.current.lock().unwrap();
        let generation = cur.generation + 1;
        *cur = Arc::new(Deployed { model: Arc::new(model), generation });
        Ok(generation)
    }

    /// Admit one request and return a [`PredictionHandle`] immediately —
    /// the completion-based request path. The handle costs one queue slot
    /// and no thread; poll it or `wait()` on it. Fails fast with
    /// [`EngineError::Overloaded`] at the admission bound and
    /// [`EngineError::ShutDown`] after [`Engine::shutdown`].
    pub fn predict_async(&self, x: &[f32]) -> Result<PredictionHandle, EngineError> {
        if x.len() != self.in_dim {
            return Err(EngineError::BadRequest(format!(
                "request has {} features, engine wants {}",
                x.len(),
                self.in_dim
            )));
        }
        let slot = Slot::new();
        // the payload copy is per-request-private: build it before taking
        // the shared lock so concurrent clients don't serialize on it
        let pending = Pending { x: x.to_vec(), enqueued: Instant::now(), slot: slot.clone() };
        {
            let mut st = self.queue.state.lock().unwrap();
            if st.shutdown {
                return Err(EngineError::ShutDown);
            }
            if st.q.len() >= self.opts.queue_depth {
                // bounded admission: shed at the door, O(1), queue unread
                st.shed += 1;
                return Err(EngineError::Overloaded { depth: self.opts.queue_depth });
            }
            st.q.push_back(pending);
            st.accepted += 1;
            if st.q.len() > st.peak_depth {
                st.peak_depth = st.q.len();
            }
        }
        self.queue.cv.notify_one();
        Ok(PredictionHandle { slot })
    }

    /// Blocking single-request predict — a thin wrapper:
    /// `predict_async(x)?.wait()`. Safe to call from many client threads
    /// at once — that is what fills the micro-batches.
    pub fn predict(&self, x: &[f32]) -> Result<Prediction, EngineError> {
        self.predict_async(x)?.wait()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
        // the dispatcher drains what is still queued (shutdown overrides
        // pause), then its pool drop joins the in-flight micro-batches —
        // no admitted request is abandoned, every outstanding handle
        // completes before the join returns
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    queue: Arc<Queue>,
    current: Arc<Mutex<Arc<Deployed>>>,
    pool: ThreadPool,
    max_batch: usize,
    workers: usize,
) {
    loop {
        let batch: Vec<Pending> = {
            let mut st = queue.state.lock().unwrap();
            loop {
                // bounded in-flight: only form a batch when a pool worker
                // can take it, so a sustained backlog fills later batches
                // toward max_batch instead of flooding the pool queue with
                // size-1 batches. A pause holds dispatch (not admission)
                // until resume — or shutdown, which always drains.
                let dispatchable =
                    !st.q.is_empty() && st.in_flight < workers && (!st.paused || st.shutdown);
                if dispatchable {
                    let take = st.q.len().min(max_batch);
                    st.in_flight += 1;
                    break st.q.drain(..take).collect();
                }
                if st.shutdown && st.q.is_empty() {
                    return; // pool drops here: joins outstanding batches
                }
                st = queue.cv.wait(st).unwrap();
            }
        };
        // the model is pinned per micro-batch *after* the batch is formed
        // and *outside* the queue lock: a swap between batches routes the
        // later batch to the new model; a swap during a batch leaves that
        // batch on the model it started with — one request never mixes
        // generations
        let deployed: Arc<Deployed> = current.lock().unwrap().clone();
        let q = queue.clone();
        pool.submit(move || {
            // the pool catch_unwind's jobs and keeps its workers alive, so
            // the slot release must survive a panicking batch too — a drop
            // guard runs on unwind, where a trailing statement would not
            // (a leaked slot would eventually wedge the dispatcher for
            // good once every slot leaked)
            struct SlotGuard(Arc<Queue>);
            impl Drop for SlotGuard {
                fn drop(&mut self) {
                    let mut st = self.0.state.lock().unwrap();
                    st.in_flight -= 1;
                    drop(st);
                    // wake the dispatcher: a worker slot is free again
                    self.0.cv.notify_all();
                }
            }
            let _slot = SlotGuard(q.clone());
            run_batch(&deployed, &q, batch);
        });
    }
}

fn run_batch(deployed: &Deployed, queue: &Queue, batch: Vec<Pending>) {
    let model = &deployed.model;
    let nb = batch.len();
    let mut xs = Vec::with_capacity(nb * model.in_dim());
    for p in &batch {
        xs.extend_from_slice(&p.x);
    }
    // counters bump BEFORE the slots complete: once a client's handle has
    // resolved, `stats()` is guaranteed to already count that request
    match model.forward(&xs, nb) {
        Ok(z) => {
            queue.state.lock().unwrap().completed += nb as u64;
            let classes = model.out_dim();
            let preds = bsr::argmax_rows(&z, nb, classes);
            for (i, p) in batch.into_iter().enumerate() {
                let resp = Prediction {
                    logits: z[i * classes..(i + 1) * classes].to_vec(),
                    class: preds[i],
                    latency: p.enqueued.elapsed(),
                    batch_size: nb,
                    generation: deployed.generation,
                };
                // a client that dropped its handle is not an engine
                // error — the slot just holds an unread result
                p.slot.complete(Ok(resp));
            }
        }
        Err(e) => {
            queue.state.lock().unwrap().failed += nb as u64;
            // every waiter's slot completes with the actual forward
            // error — never abandoned, so clients see the root cause
            // instead of a fabricated "batch failed?" guess
            let msg = format!("{e:#}");
            crate::warn_!("micro-batch of {nb} failed: {msg}");
            for p in batch {
                p.slot.complete(Err(EngineError::BatchFailed(msg.clone())));
            }
        }
    }
}

// ----------------------------------------------------------------- drivers

/// Drive an engine with synthetic random-normal traffic: `clients`
/// concurrent threads issue `requests` predicts in total (quota split
/// evenly, remainder to the first threads), each with its own
/// seed-derived RNG. Returns every request's latency in milliseconds —
/// feed to [`latency_summary`]. Closed-loop: each client has one request
/// outstanding, so with `queue_depth ≥ clients` nothing sheds. Shared by
/// the `infer` CLI subcommand and `benches/infer_serve.rs` so the
/// measured traffic shape cannot diverge between them; the overload
/// variant is [`drive_overload`], the thread-free open-loop variant is
/// [`drive_async`].
pub fn drive_synthetic(
    engine: &Engine,
    requests: usize,
    clients: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let requests = requests.max(1);
    let clients = clients.max(1);
    let in_dim = engine.in_dim();
    let per_client: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let quota = requests / clients + usize::from(c < requests % clients);
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut lat = Vec::with_capacity(quota);
                    for _ in 0..quota {
                        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
                        lat.push(engine.predict(&x)?.latency.as_secs_f64() * 1e3);
                    }
                    Ok(lat)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(requests);
    for r in per_client {
        out.extend(r?);
    }
    Ok(out)
}

/// What [`drive_async`] measured.
#[derive(Clone, Debug)]
pub struct AsyncDriveReport {
    /// total requests issued (accepted + shed)
    pub offered: usize,
    /// requests that got logits
    pub accepted: usize,
    /// requests load-shed with [`EngineError::Overloaded`]
    pub shed: usize,
    /// the in-flight handle window the driver held
    pub window: usize,
    /// per-accepted-request latency in milliseconds
    pub accepted_lat_ms: Vec<f64>,
}

impl AsyncDriveReport {
    /// shed / offered ∈ [0, 1].
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }
}

/// Open-loop driver over [`Engine::predict_async`]: ONE thread keeps up
/// to `window` requests in flight as [`PredictionHandle`]s — the
/// many-clients load shape of [`drive_overload`] without its
/// thread-per-client cost, which is the tentpole claim (N in-flight
/// requests cost N queue slots, and the process thread count stays at
/// `workers + constant` regardless of `window` — pinned by the stress
/// suite's `/proc` accounting test). With `window` above
/// [`Engine::capacity`], admission saturates and the excess sheds typed,
/// exactly like the blocking path; [`EngineError::BatchFailed`] (or any
/// non-overload error) aborts the drive. Use a fresh engine per drive
/// when comparing reports against engine-lifetime stats.
pub fn drive_async(
    engine: &Engine,
    requests: usize,
    window: usize,
    seed: u64,
) -> Result<AsyncDriveReport> {
    let requests = requests.max(1);
    let window = window.max(1);
    let in_dim = engine.in_dim();
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut inflight: VecDeque<PredictionHandle> = VecDeque::with_capacity(window);
    let mut accepted_lat_ms = Vec::new();
    let mut shed = 0usize;
    let mut reap = |h: PredictionHandle, lat: &mut Vec<f64>| -> Result<()> {
        let p = h.wait()?;
        lat.push(p.latency.as_secs_f64() * 1e3);
        Ok(())
    };
    for _ in 0..requests {
        // keep the window bounded *before* admitting more: the driver
        // holds at most `window` outstanding handles
        while inflight.len() >= window {
            let h = inflight.pop_front().unwrap();
            reap(h, &mut accepted_lat_ms)?;
        }
        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
        match engine.predict_async(&x) {
            Ok(h) => inflight.push_back(h),
            Err(EngineError::Overloaded { .. }) => {
                shed += 1;
                // same back-off shape as drive_overload's aggressive
                // clients: yield, then offer the next request
                std::thread::yield_now();
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in inflight {
        reap(h, &mut accepted_lat_ms)?;
    }
    Ok(AsyncDriveReport {
        offered: requests,
        accepted: accepted_lat_ms.len(),
        shed,
        window,
        accepted_lat_ms,
    })
}

/// What [`drive_overload`] measured.
#[derive(Clone, Debug)]
pub struct OverloadReport {
    /// total requests issued (accepted + shed)
    pub offered: usize,
    /// requests that got logits
    pub accepted: usize,
    /// requests load-shed with [`EngineError::Overloaded`]
    pub shed: usize,
    /// per-accepted-request latency in milliseconds
    pub accepted_lat_ms: Vec<f64>,
    /// maximum queue depth the engine ever observed
    pub peak_depth: usize,
    /// the configured admission bound
    pub queue_depth: usize,
    /// resident capacity: queue_depth + workers·max_batch
    pub capacity: usize,
    /// offered concurrency (clients) over resident capacity
    pub offered_ratio: f64,
}

impl OverloadReport {
    /// shed / offered ∈ [0, 1].
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }
}

/// Sustained-overload load test: `clients` threads each issue
/// `per_client` predicts back-to-back with zero think time. Sized with
/// `clients` well above [`Engine::capacity`] (the bench drives ≥ 4×),
/// the admission queue saturates and the excess load-sheds: shed
/// requests fail fast with the typed [`EngineError::Overloaded`] and are
/// counted (the client yields and moves to its next request); accepted
/// ones contribute latency samples. Any other error aborts the drive.
/// Use a fresh engine per drive — `peak_depth` reads engine-lifetime
/// stats.
pub fn drive_overload(
    engine: &Engine,
    per_client: usize,
    clients: usize,
    seed: u64,
) -> Result<OverloadReport> {
    let per_client = per_client.max(1);
    let clients = clients.max(1);
    let in_dim = engine.in_dim();
    let per: Vec<Result<(Vec<f64>, usize)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || -> Result<(Vec<f64>, usize)> {
                    let mut rng = crate::util::rng::Rng::new(
                        seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut lat = Vec::new();
                    let mut shed = 0usize;
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
                        match engine.predict(&x) {
                            Ok(p) => lat.push(p.latency.as_secs_f64() * 1e3),
                            Err(EngineError::Overloaded { .. }) => {
                                shed += 1;
                                // an aggressive client retries immediately
                                // with its next request; the yield keeps
                                // the shed path from starving admitted
                                // work of a core
                                std::thread::yield_now();
                            }
                            Err(e) => return Err(e.into()),
                        }
                    }
                    Ok((lat, shed))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("overload client panicked"))
            .collect()
    });
    let mut accepted_lat_ms = Vec::new();
    let mut shed = 0usize;
    for r in per {
        let (l, s) = r?;
        accepted_lat_ms.extend(l);
        shed += s;
    }
    let stats = engine.stats();
    Ok(OverloadReport {
        offered: per_client * clients,
        accepted: accepted_lat_ms.len(),
        shed,
        accepted_lat_ms,
        peak_depth: stats.peak_depth,
        queue_depth: engine.queue_depth(),
        capacity: engine.capacity(),
        offered_ratio: clients as f64 / engine.capacity() as f64,
    })
}

// ----------------------------------------------------------- aggregation

/// Latency distribution summary (milliseconds) — shared by the `infer`
/// CLI subcommand and `benches/infer_serve.rs`. An empty sample set is a
/// first-class value ([`LatencySummary::empty`], `count == 0`, every
/// statistic NaN): overload runs that shed 100% produce it, callers
/// branch on [`LatencySummary::is_empty`] instead of sniffing NaNs, and
/// the JSON writers map the NaNs to nulls (pinned in `util::json`).
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// The typed zero-sample summary — what [`latency_summary`] returns
    /// for an empty slice.
    pub fn empty() -> LatencySummary {
        LatencySummary {
            count: 0,
            mean_ms: f64::NAN,
            p50_ms: f64::NAN,
            p95_ms: f64::NAN,
            p99_ms: f64::NAN,
            max_ms: f64::NAN,
        }
    }

    /// No samples — every statistic is NaN (null in JSON) by contract.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// Nearest-rank percentiles over per-request latencies in milliseconds
/// (via the shared [`crate::bench::percentile`], so serving numbers stay
/// comparable with the kernel benches).
pub fn latency_summary(lat_ms: &[f64]) -> LatencySummary {
    if lat_ms.is_empty() {
        return LatencySummary::empty();
    }
    let mut sorted = lat_ms.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    LatencySummary {
        count: sorted.len(),
        mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
        p50_ms: crate::bench::percentile(&sorted, 0.50),
        p95_ms: crate::bench::percentile(&sorted, 0.95),
        p99_ms: crate::bench::percentile(&sorted, 0.99),
        max_ms: *sorted.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{BsrLayer, BsrModel};
    use crate::util::rng::Rng;

    fn tiny_model(seed: u64) -> (BsrModel, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w1: Vec<f32> = (0..6 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..4 * 6).map(|_| rng.normal()).collect();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![
                BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap(),
                BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap(),
            ],
        };
        (model, w1, w2)
    }

    fn opts(max_batch: usize, workers: usize, queue_depth: usize) -> EngineOpts {
        EngineOpts { max_batch, workers, queue_depth }
    }

    #[test]
    fn predict_matches_direct_forward() {
        let (model, _, _) = tiny_model(41);
        let reference = model.clone();
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        assert_eq!((engine.in_dim(), engine.out_dim()), (8, 4));
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let p = engine.predict(&x).unwrap();
            let want = bsr::model_forward(&reference, &x, 1).unwrap();
            assert_eq!(p.logits, want);
            assert_eq!(p.class, bsr::argmax_rows(&want, 1, 4)[0]);
            assert!(p.batch_size >= 1 && p.batch_size <= 4);
            assert_eq!(p.generation, 0);
        }
        let st = engine.stats();
        assert_eq!(st.accepted, 10);
        assert_eq!(st.completed, 10);
        assert_eq!((st.shed, st.failed), (0, 0));
    }

    #[test]
    fn predict_async_polls_and_resolves_without_extra_threads() {
        let (model, _, _) = tiny_model(60);
        let reference = model.clone();
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 2.0).collect();
        let mut h = engine.predict_async(&x).unwrap();
        // poll to completion on this thread — no helper thread anywhere
        let res = loop {
            if let Some(r) = h.try_take() {
                break r;
            }
            std::thread::yield_now();
        };
        let p = res.unwrap();
        assert_eq!(p.logits, bsr::model_forward(&reference, &x, 1).unwrap());
        // a handle that was polled dry stays dry
        assert!(h.is_ready());
        assert!(h.try_take().is_none(), "the result moves out exactly once");
    }

    #[test]
    fn predict_async_wait_after_completion_returns_immediately() {
        let (model, _, _) = tiny_model(61);
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        let h = engine.predict_async(&[0.25; 8]).unwrap();
        // let the batch complete first, then wait() must not park forever
        while !h.is_ready() {
            std::thread::yield_now();
        }
        let p = h.wait().unwrap();
        assert_eq!(p.generation, 0);
        assert!(p.batch_size >= 1);
    }

    #[test]
    fn dropped_handles_do_not_leak_or_wedge_the_engine() {
        let (model, _, _) = tiny_model(62);
        let engine = Engine::new(model, opts(4, 1, 64)).unwrap();
        for _ in 0..8 {
            // admit and immediately abandon: the batch still runs and the
            // engine must keep serving
            drop(engine.predict_async(&[0.1; 8]).unwrap());
        }
        let p = engine.predict(&[0.3; 8]).unwrap();
        assert_eq!(p.logits.len(), 4);
        // every admitted request is counted even if its handle was dropped
        let st = engine.stats();
        assert_eq!(st.accepted, 9);
        assert_eq!(st.completed, 9);
    }

    #[test]
    fn shutdown_rejects_new_requests_but_drains_admitted_ones() {
        let (model, _, _) = tiny_model(63);
        let engine = Engine::new(model, opts(4, 1, 64)).unwrap();
        engine.pause();
        let h = engine.predict_async(&[0.5; 8]).unwrap();
        engine.shutdown(); // overrides pause: the queued request drains
        assert!(matches!(engine.predict_async(&[0.5; 8]), Err(EngineError::ShutDown)));
        assert!(matches!(engine.predict(&[0.5; 8]), Err(EngineError::ShutDown)));
        let p = h.wait().expect("admitted before shutdown ⇒ must complete");
        assert_eq!(p.logits.len(), 4);
        // shutdown is idempotent
        engine.shutdown();
    }

    #[test]
    fn concurrent_clients_all_get_their_own_answer() {
        let (model, _, _) = tiny_model(43);
        let reference = model.clone();
        let engine = Engine::new(model, opts(8, 3, 64)).unwrap();
        let results: Vec<(Vec<f32>, Prediction)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16)
                .map(|c| {
                    let engine = &engine;
                    s.spawn(move || {
                        let mut rng = Rng::new(100 + c as u64);
                        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                        let p = engine.predict(&x).unwrap();
                        (x, p)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 16);
        for (x, p) in &results {
            let want = bsr::model_forward(&reference, x, 1).unwrap();
            assert_eq!(&p.logits, &want, "a client got another client's logits");
        }
    }

    #[test]
    fn predict_rejects_wrong_feature_count() {
        let (model, _, _) = tiny_model(44);
        let engine = Engine::new(model, EngineOpts::default()).unwrap();
        assert!(matches!(engine.predict(&[0.0; 7]), Err(EngineError::BadRequest(_))));
        assert!(matches!(engine.predict_async(&[0.0; 9]), Err(EngineError::BadRequest(_))));
        assert!(engine.predict(&[0.0; 8]).is_ok());
    }

    #[test]
    fn drop_with_idle_engine_does_not_hang() {
        let (model, _, _) = tiny_model(45);
        let engine = Engine::new(model, opts(2, 1, 8)).unwrap();
        drop(engine);
    }

    #[test]
    fn drive_synthetic_collects_every_request() {
        let (model, _, _) = tiny_model(46);
        let engine = Engine::new(model, opts(4, 2, 64)).unwrap();
        // 10 requests over 3 clients: quotas 4/3/3, all latencies returned
        let lat = drive_synthetic(&engine, 10, 3, 7).unwrap();
        assert_eq!(lat.len(), 10);
        assert!(lat.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn drive_async_accounts_every_request() {
        let (model, _, _) = tiny_model(55);
        let engine = Engine::new(model, opts(2, 1, 4)).unwrap();
        // window well above capacity (4 + 2·1 = 6): some offers shed
        let rep = drive_async(&engine, 200, 32, 13).unwrap();
        assert_eq!(rep.offered, 200);
        assert_eq!(rep.accepted + rep.shed, rep.offered);
        assert_eq!(rep.accepted_lat_ms.len(), rep.accepted);
        assert!(rep.accepted >= 1, "a drive must accept something");
        assert_eq!(rep.window, 32);
        // engine counters agree with the report
        let st = engine.stats();
        assert_eq!(st.accepted, rep.accepted as u64);
        assert_eq!(st.shed, rep.shed as u64);
        assert_eq!(st.completed + st.failed, st.accepted);
        // the admission bound held under the async path too
        assert!(st.peak_depth <= engine.queue_depth());
    }

    /// Deterministic shed: with dispatch paused the queue cannot drain,
    /// so filling it to the bound makes the next predict fail fast with
    /// the typed Overloaded error — and the engine recovers on resume.
    /// The waiting requests hold completion slots, not worker threads, so
    /// the fill side uses handles and only two of them.
    #[test]
    fn full_queue_sheds_with_typed_overload_error() {
        let (model, _, _) = tiny_model(47);
        let engine = Engine::new(model, opts(4, 1, 2)).unwrap();
        engine.pause();
        let h0 = engine.predict_async(&[0.5; 8]).unwrap();
        let h1 = engine.predict_async(&[0.5; 8]).unwrap();
        assert_eq!(engine.stats().depth, 2);
        // the queue is at its bound: the next predict sheds, O(1),
        // without blocking — on both request paths
        match engine.predict(&[0.5; 8]) {
            Err(EngineError::Overloaded { depth }) => assert_eq!(depth, 2),
            other => panic!("wanted Overloaded, got {other:?}"),
        }
        match engine.predict_async(&[0.5; 8]) {
            Err(EngineError::Overloaded { depth }) => assert_eq!(depth, 2),
            other => panic!("wanted Overloaded, got {other:?}"),
        }
        assert!(!h0.is_ready() && !h1.is_ready(), "paused queue must not dispatch");
        engine.resume();
        h0.wait().expect("queued requests complete after resume");
        h1.wait().expect("queued requests complete after resume");
        let st = engine.stats();
        assert_eq!(st.shed, 2);
        assert_eq!(st.accepted, 2);
        assert_eq!(st.completed, 2);
        assert!(st.peak_depth <= 2, "queue depth {} exceeded the bound", st.peak_depth);
    }

    /// A failing forward must complete every waiter's slot with the
    /// root-cause error — never abandon a slot (the v2 engine pinned the
    /// same contract for its channel senders).
    #[test]
    fn run_batch_completes_every_slot_with_the_root_cause() {
        let (model, _, _) = tiny_model(48);
        let mut broken = model;
        // passes Engine-level shape checks at build time but the kernel's
        // own validation rejects it: payload out of sync with the index
        broken.layers[0].blocks.to_mut().pop();
        let deployed = Deployed { model: Arc::new(broken.into()), generation: 3 };
        let queue = Queue::new();
        let mut handles = Vec::new();
        let batch: Vec<Pending> = (0..3)
            .map(|_| {
                let slot = Slot::new();
                handles.push(PredictionHandle { slot: slot.clone() });
                Pending { x: vec![0.0; 8], enqueued: Instant::now(), slot }
            })
            .collect();
        run_batch(&deployed, &queue, batch);
        for h in handles {
            assert!(h.is_ready(), "slot abandoned");
            match h.wait() {
                Err(EngineError::BatchFailed(msg)) => {
                    assert!(
                        msg.contains("block values") && msg.contains("fc1"),
                        "root cause lost: {msg}"
                    );
                }
                other => panic!("wanted BatchFailed, got {other:?}"),
            }
        }
        assert_eq!(queue.state.lock().unwrap().failed, 3);
    }

    /// A client that gave up (dropped its handle) must not take down the
    /// batch — the other waiters still get their answers.
    #[test]
    fn run_batch_survives_dropped_waiter() {
        let (model, _, _) = tiny_model(49);
        let deployed = Deployed { model: Arc::new(model.into()), generation: 0 };
        let queue = Queue::new();
        let gone = Slot::new(); // its handle raced away (timeout / disconnect)
        let live = Slot::new();
        let live_handle = PredictionHandle { slot: live.clone() };
        let batch = vec![
            Pending { x: vec![0.1; 8], enqueued: Instant::now(), slot: gone },
            Pending { x: vec![0.2; 8], enqueued: Instant::now(), slot: live },
        ];
        run_batch(&deployed, &queue, batch);
        let got = live_handle.wait().unwrap();
        assert_eq!(got.batch_size, 2);
        assert_eq!(queue.state.lock().unwrap().completed, 2);
    }

    #[test]
    fn hot_swap_routes_new_requests_and_tags_generations() {
        let (a, _, _) = tiny_model(50);
        let (b, _, _) = tiny_model(51);
        let (ref_a, ref_b) = (a.clone(), b.clone());
        let engine = Engine::new(a, opts(4, 2, 64)).unwrap();
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.25 - 1.0).collect();
        let p0 = engine.predict(&x).unwrap();
        assert_eq!(p0.generation, 0);
        assert_eq!(p0.logits, bsr::model_forward(&ref_a, &x, 1).unwrap());
        let generation = engine.swap_model(b).unwrap();
        assert_eq!(generation, 1);
        assert_eq!(engine.generation(), 1);
        let p1 = engine.predict(&x).unwrap();
        assert_eq!(p1.generation, 1);
        assert_eq!(p1.logits, bsr::model_forward(&ref_b, &x, 1).unwrap());
        // a mismatched replacement is rejected: queued requests were
        // admitted against the engine's shapes
        let mut rng = Rng::new(52);
        let w: Vec<f32> = (0..4 * 6).map(|_| rng.normal()).collect();
        let mismatched = BsrModel {
            spec: "other".into(),
            method: "dense".into(),
            in_dim: 6,
            out_dim: 4,
            layers: vec![BsrLayer::from_dense("fc", &w, 4, 6, 2, 2).unwrap()],
        };
        assert!(matches!(engine.swap_model(mismatched), Err(EngineError::SwapRejected(_))));
        // an invalid replacement is rejected before the swap
        let (mut corrupt, _, _) = tiny_model(53);
        corrupt.layers[1].col_idx[0] = 99;
        assert!(matches!(engine.swap_model(corrupt), Err(EngineError::SwapRejected(_))));
        assert_eq!(engine.generation(), 1, "rejected swaps must not bump the generation");
    }

    /// Swapping a quantized model into an f32 engine serves int8 logits
    /// tagged with the new generation — how quantized artifacts roll out.
    #[test]
    fn hot_swap_crosses_dtypes() {
        let (a, _, _) = tiny_model(56);
        let q = crate::infer::quant::quantize_model(&a).unwrap();
        let q_ref = q.clone();
        let engine = Engine::new(a, opts(4, 2, 64)).unwrap();
        assert_eq!(engine.model().dtype(), "f32");
        let generation = engine.swap_model(q).unwrap();
        assert_eq!(generation, 1);
        assert_eq!(engine.model().dtype(), "int8");
        let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
        let p = engine.predict(&x).unwrap();
        assert_eq!(p.generation, 1);
        let want = crate::infer::quant::model_forward_q8(&q_ref, &x, 1).unwrap();
        assert_eq!(p.logits, want);
    }

    #[test]
    fn drive_overload_accounts_every_request() {
        let (model, _, _) = tiny_model(54);
        let engine = Engine::new(model, opts(2, 1, 2)).unwrap();
        assert_eq!(engine.capacity(), 2 + 2);
        let rep = drive_overload(&engine, 8, 8, 11).unwrap();
        assert_eq!(rep.offered, 64);
        assert_eq!(rep.accepted + rep.shed, rep.offered);
        assert_eq!(rep.accepted_lat_ms.len(), rep.accepted);
        assert!(rep.accepted >= 1, "a drive must accept something");
        assert!(rep.peak_depth <= rep.queue_depth, "the bound was breached");
        assert!((rep.offered_ratio - 2.0).abs() < 1e-12);
        assert!(rep.shed_rate() >= 0.0 && rep.shed_rate() <= 1.0);
        // engine counters agree with the report
        let st = engine.stats();
        assert_eq!(st.shed, rep.shed as u64);
        assert_eq!(st.accepted, rep.accepted as u64);
    }

    #[test]
    fn latency_summary_percentiles() {
        // nearest-rank (ceil(p·N)−1) pinned exactly on 1..=100: p50 is the
        // 50th sorted value, p95 the 95th, p99 the 99th, and p100 ≡ max
        let lat: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = latency_summary(&lat);
        assert!(!s.is_empty());
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    /// Regression (PR-10 satellite): an all-shed overload run produces
    /// zero samples — the summary must be the typed empty value, not a
    /// panic or a caller-side NaN sniff.
    #[test]
    fn latency_summary_empty_is_typed() {
        let empty = latency_summary(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.count, 0);
        assert!(empty.mean_ms.is_nan() && empty.p50_ms.is_nan());
        assert!(empty.p95_ms.is_nan() && empty.p99_ms.is_nan() && empty.max_ms.is_nan());
        let direct = LatencySummary::empty();
        assert!(direct.is_empty());
        assert_eq!(direct.count, empty.count);
    }
}
