//! Int8-quantized BSR: per-block-row symmetric quantization of the packed
//! block payload, with f32 accumulation in the kernels (W8A32).
//!
//! Block-sparse inference at serving batch sizes is memory-bandwidth
//! bound — the kernel streams every stored block once per batch row while
//! the activations stay cache-hot. Storing blocks as i8 moves 4× less
//! payload per block than f32, which is where the BENCH_infer int8 panel's
//! ≥1.5× throughput gate comes from.
//!
//! Quantization granularity is one scale per **row of each stored
//! block** (`scales[k·m2 + i2]`, f32): the inner kernel loop is a dot
//! product between one block row and an n2-segment of the input, so a
//! per-row scale folds into a single multiply *after* the integer dot —
//! no per-element rescale on the hot path, and the error bound stays
//! local: `|w − dq(q(w))| ≤ scale/2` with `scale = max|row|/127`
//! (all-zero rows get scale 0 and round-trip exactly). Accumulation is
//! f32 throughout ([`crate::backend::native::simd::dot_q8`] widens i8 →
//! f32 and FMAs against the activations), so the only error source is the
//! weight rounding itself.
//!
//! On disk a [`QuantModel`] is an ordinary version-2 `"BSRM"` container
//! with `dtype = int8`: same header plus one extra payload offset per
//! layer (`scales_off`), same 8-aligned payload rules, same atomic
//! publish. [`super::load_auto`] routes on the dtype field;
//! [`super::mmap::open_quant_mmap`] serves both qblocks and scales
//! zero-copy.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::native::linalg::{par_rows, threads_for};
use crate::backend::native::simd::{self, SimdKind};
use crate::checkpoint::wire;
use crate::flops::block_sparse_infer_flops;

use super::mmap::MmapRegion;
use super::{BlockStore, BsrLayer, BsrModel, DTYPE_F32, DTYPE_INT8, MAGIC};

// ------------------------------------------------------------ QBlockStore

/// Where a layer's quantized block payload lives — the i8 twin of
/// [`BlockStore`], with the same contract: owned after a read/quantize,
/// a window into a shared mapping after `open_quant_mmap`, copy-on-write
/// via [`QBlockStore::to_mut`].
#[derive(Clone)]
pub enum QBlockStore {
    Owned(Vec<i8>),
    Mapped {
        region: Arc<MmapRegion>,
        off: usize,
        len: usize,
    },
}

impl QBlockStore {
    pub fn as_slice(&self) -> &[i8] {
        match self {
            QBlockStore::Owned(v) => v,
            QBlockStore::Mapped { region, off, len } => region.i8s(*off, *len),
        }
    }

    pub fn is_mapped(&self) -> bool {
        matches!(self, QBlockStore::Mapped { .. })
    }

    /// Mutable access, converting a mapped store to an owned copy first.
    pub fn to_mut(&mut self) -> &mut Vec<i8> {
        if let QBlockStore::Mapped { .. } = self {
            *self = QBlockStore::Owned(self.as_slice().to_vec());
        }
        match self {
            QBlockStore::Owned(v) => v,
            QBlockStore::Mapped { .. } => unreachable!("converted to Owned above"),
        }
    }
}

impl std::ops::Deref for QBlockStore {
    type Target = [i8];
    fn deref(&self) -> &[i8] {
        self.as_slice()
    }
}

impl From<Vec<i8>> for QBlockStore {
    fn from(v: Vec<i8>) -> Self {
        QBlockStore::Owned(v)
    }
}

impl PartialEq for QBlockStore {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for QBlockStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "owned" };
        write!(f, "QBlockStore<{kind}, {} i8>", self.len())
    }
}

// -------------------------------------------------------------- QuantLayer

/// One int8 BSR slot: the same CSR index as [`BsrLayer`], i8 block
/// payload, and one f32 scale per stored block row.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub m2: usize,
    pub n2: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    /// nnz · m2 · n2 quantized values, blocks contiguous in storage order
    pub qblocks: QBlockStore,
    /// nnz · m2 dequantization scales, `scales[k·m2 + i2]` for block k row i2
    pub scales: BlockStore,
}

impl QuantLayer {
    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.m2, self.n / self.n2)
    }

    pub fn nnz_blocks(&self) -> usize {
        self.col_idx.len()
    }

    pub fn occupancy(&self) -> f64 {
        let (m1, n1) = self.grid();
        self.nnz_blocks() as f64 / (m1 * n1) as f64
    }

    pub fn block_sparsity(&self) -> f64 {
        1.0 - self.occupancy()
    }

    /// Stored parameter count (quantized values; scales excluded — they
    /// are metadata, 1/n2 of the payload).
    pub fn nnz_params(&self) -> u64 {
        self.qblocks.len() as u64
    }

    /// Same FLOP convention as the f32 path: the int8 kernel does the
    /// same multiply-adds, just against narrower storage.
    pub fn infer_flops(&self) -> u64 {
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, self.nnz_blocks() as u64)
    }

    pub fn dense_flops(&self) -> u64 {
        let (m1, n1) = self.grid();
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, (m1 * n1) as u64)
    }

    pub fn validate(&self) -> Result<()> {
        if self.m == 0 || self.n == 0 || self.m2 == 0 || self.n2 == 0 {
            bail!("slot '{}': zero dimension", self.name);
        }
        if self.m % self.m2 != 0 || self.n % self.n2 != 0 {
            bail!(
                "slot '{}': block ({},{}) does not tile ({},{})",
                self.name, self.m2, self.n2, self.m, self.n
            );
        }
        let (m1, n1) = self.grid();
        if self.row_ptr.len() != m1 + 1 {
            bail!("slot '{}': row_ptr has {} entries, want {}", self.name, self.row_ptr.len(), m1 + 1);
        }
        if !self.row_ptr.windows(2).all(|w| w[0] <= w[1]) || self.row_ptr[0] != 0 {
            bail!("slot '{}': row_ptr is not monotonically increasing from 0", self.name);
        }
        let nnz = self.row_ptr[m1] as usize;
        if self.col_idx.len() != nnz {
            bail!("slot '{}': {} col_idx for {nnz} stored blocks", self.name, self.col_idx.len());
        }
        if self.col_idx.iter().any(|&j| j as usize >= n1) {
            bail!("slot '{}': col_idx out of range [0, {n1})", self.name);
        }
        if self.qblocks.len() != nnz * self.m2 * self.n2 {
            bail!(
                "slot '{}': {} quantized values for {nnz} stored blocks",
                self.name,
                self.qblocks.len()
            );
        }
        if self.scales.len() != nnz * self.m2 {
            bail!(
                "slot '{}': {} scales for {nnz} stored blocks of {} rows",
                self.name,
                self.scales.len(),
                self.m2
            );
        }
        if self.scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
            bail!("slot '{}': scales must be finite and non-negative", self.name);
        }
        Ok(())
    }
}

// -------------------------------------------------------------- QuantModel

/// A full int8-quantized BSR stack — the serving artifact behind
/// `export --quant int8`, deployed through [`super::ServedModel::Int8`].
#[derive(Clone, Debug, PartialEq)]
pub struct QuantModel {
    pub spec: String,
    pub method: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub layers: Vec<QuantLayer>,
}

impl QuantModel {
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("quantized BSR model '{}' has no layers", self.spec);
        }
        let mut prev = self.in_dim;
        for l in &self.layers {
            l.validate()?;
            if l.n != prev {
                bail!(
                    "quantized model '{}': layer '{}' wants {} inputs, previous layer emits {prev}",
                    self.spec, l.name, l.n
                );
            }
            prev = l.m;
        }
        if prev != self.out_dim {
            bail!(
                "quantized model '{}': last layer emits {prev}, model declares {} outputs",
                self.spec, self.out_dim
            );
        }
        Ok(())
    }

    pub fn nnz_params(&self) -> u64 {
        self.layers.iter().map(QuantLayer::nnz_params).sum()
    }

    pub fn block_sparsity(&self) -> f64 {
        crate::sparsity::aggregate(
            &self
                .layers
                .iter()
                .map(|l| (l.block_sparsity(), l.m * l.n))
                .collect::<Vec<_>>(),
        )
    }

    pub fn infer_flops_per_example(&self) -> u64 {
        self.layers.iter().map(QuantLayer::infer_flops).sum()
    }

    pub fn dense_flops_per_example(&self) -> u64 {
        self.layers.iter().map(QuantLayer::dense_flops).sum()
    }

    /// Serialize as a version-2 container with `dtype = int8`: identical
    /// header layout to the f32 path plus one `scales_off` per layer.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        let mut pw = super::PayloadWriter::new();
        let mut header = Vec::new();
        wire::put_str(&mut header, &self.spec);
        wire::put_str(&mut header, &self.method);
        wire::put_u32(&mut header, self.in_dim as u32);
        wire::put_u32(&mut header, self.out_dim as u32);
        wire::put_u32(&mut header, self.layers.len() as u32);
        for l in &self.layers {
            wire::put_str(&mut header, &l.name);
            wire::put_u32(&mut header, l.m as u32);
            wire::put_u32(&mut header, l.n as u32);
            wire::put_u32(&mut header, l.m2 as u32);
            wire::put_u32(&mut header, l.n2 as u32);
            wire::put_u32(&mut header, l.col_idx.len() as u32);
            wire::put_u64(&mut header, pw.put_u32s(&l.row_ptr));
            wire::put_u64(&mut header, pw.put_u32s(&l.col_idx));
            wire::put_u64(&mut header, pw.put_i8s(&l.qblocks));
            wire::put_u64(&mut header, pw.put_f32s(&l.scales));
        }
        super::write_container(path, DTYPE_INT8, &header, &pw.finish())
    }

    /// Load from disk with full payload CRC verification. Version-1
    /// containers never carry int8 payloads, so only version 2 is
    /// accepted; an f32 artifact is redirected to the right loader.
    pub fn load(path: &Path) -> Result<Self> {
        let all = std::fs::read(path).with_context(|| format!("reading BSR model {path:?}"))?;
        if all.len() < 12 || &all[..4] != MAGIC {
            bail!("{path:?} is not a BSRM artifact");
        }
        let version = u32::from_le_bytes(all[4..8].try_into().unwrap());
        if version == super::VERSION_V1 {
            bail!("version 1 containers store f32 blocks only — use `BsrModel::load`");
        }
        let c = super::open_v2_bytes(&all, true)?;
        if c.prologue.dtype == DTYPE_F32 {
            bail!("artifact stores f32 blocks — open it with `load_auto` or `BsrModel::load`");
        }
        let mut layers = Vec::new();
        for lh in &c.header.layers {
            let m1 = lh.m / lh.m2;
            let row_ptr = super::take_u32s(
                c.payload, lh.row_ptr_off, (m1 + 1) as u64,
                &format!("{}.row_ptr", lh.name),
            )?;
            let col_idx = super::take_u32s(
                c.payload, lh.col_idx_off, lh.nnz as u64,
                &format!("{}.col_idx", lh.name),
            )?;
            let qblocks = super::take_i8s(
                c.payload, lh.blocks_off, lh.block_values()?,
                &format!("{}.qblocks", lh.name),
            )?;
            let scales = super::take_f32s(
                c.payload, lh.scales_off, (lh.nnz as u64) * (lh.m2 as u64),
                &format!("{}.scales", lh.name),
            )?;
            layers.push(QuantLayer {
                name: lh.name.clone(),
                m: lh.m,
                n: lh.n,
                m2: lh.m2,
                n2: lh.n2,
                row_ptr,
                col_idx,
                qblocks: qblocks.into(),
                scales: scales.into(),
            });
        }
        let model = QuantModel {
            spec: c.header.spec.clone(),
            method: c.header.method.clone(),
            in_dim: c.header.in_dim,
            out_dim: c.header.out_dim,
            layers,
        };
        model.validate().with_context(|| format!("validating quantized model from {path:?}"))?;
        Ok(model)
    }

    /// Zero-copy open — see [`super::mmap::open_quant_mmap`].
    pub fn open_mmap(path: &Path) -> Result<(Self, super::mmap::MapStats)> {
        super::mmap::open_quant_mmap(path)
    }
}

// ------------------------------------------------------------ quantization

/// Quantize one f32 BSR layer: per stored block row,
/// `scale = max|row| / 127`, `q = clamp(round(w / scale), −127, 127)`.
/// All-zero rows get scale 0 (and round-trip exactly); the symmetric
/// range never uses −128, so negation stays lossless.
pub fn quantize_layer(l: &BsrLayer) -> QuantLayer {
    let (m2, n2) = (l.m2, l.n2);
    let nnz = l.nnz_blocks();
    let mut qblocks = vec![0i8; nnz * m2 * n2];
    let mut scales = vec![0.0f32; nnz * m2];
    for k in 0..nnz {
        let blk = &l.blocks[k * m2 * n2..(k + 1) * m2 * n2];
        for i2 in 0..m2 {
            let row = &blk[i2 * n2..(i2 + 1) * n2];
            let maxabs = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            if maxabs == 0.0 {
                continue; // scale 0, all-zero q row
            }
            let scale = maxabs / 127.0;
            scales[k * m2 + i2] = scale;
            let qrow = &mut qblocks[(k * m2 + i2) * n2..(k * m2 + i2 + 1) * n2];
            for (q, &w) in qrow.iter_mut().zip(row) {
                *q = (w / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
    QuantLayer {
        name: l.name.clone(),
        m: l.m,
        n: l.n,
        m2,
        n2,
        row_ptr: l.row_ptr.clone(),
        col_idx: l.col_idx.clone(),
        qblocks: qblocks.into(),
        scales: scales.into(),
    }
}

/// Quantize a whole f32 stack — the `export --quant int8` entry point.
pub fn quantize_model(m: &BsrModel) -> Result<QuantModel> {
    m.validate()?;
    Ok(QuantModel {
        spec: m.spec.clone(),
        method: m.method.clone(),
        in_dim: m.in_dim,
        out_dim: m.out_dim,
        layers: m.layers.iter().map(quantize_layer).collect(),
    })
}

/// Reconstruct the f32 layer a [`QuantLayer`] encodes: `w = scale · q`.
/// Each value is within `scale/2` of the original (exact for all-zero
/// rows and 1×1 blocks) — the property tests pin this bound.
pub fn dequantize_layer(l: &QuantLayer) -> BsrLayer {
    let (m2, n2) = (l.m2, l.n2);
    let blocks: Vec<f32> = l
        .qblocks
        .iter()
        .enumerate()
        .map(|(i, &q)| l.scales[i / n2] * q as f32)
        .collect();
    BsrLayer {
        name: l.name.clone(),
        m: l.m,
        n: l.n,
        m2,
        n2,
        row_ptr: l.row_ptr.clone(),
        col_idx: l.col_idx.clone(),
        blocks: blocks.into(),
    }
}

// ----------------------------------------------------------------- kernels

/// Z(N, m) = X(N, n) · dq(W)ᵀ over the occupied blocks of `l` — the int8
/// mirror of `bsr::forward_impl`: same validation, same `par_rows` split,
/// but the inner dot runs over i8 block rows
/// ([`simd::dot_q8`], f32 accumulate) with the per-row scale folded into
/// one multiply after the dot.
fn forward_impl_q8(
    kind: SimdKind,
    x: &[f32],
    nb: usize,
    l: &QuantLayer,
    relu: bool,
) -> Result<Vec<f32>> {
    let (m, n, m2, n2) = (l.m, l.n, l.m2, l.n2);
    l.validate()?;
    let (m1, _) = l.grid();
    if x.len() != nb * n {
        bail!("layer '{}': batch wants {nb}·{n} values, got {}", l.name, x.len());
    }
    let nnz = l.row_ptr[m1] as usize;
    let qblocks = l.qblocks.as_slice();
    let scales = l.scales.as_slice();
    let mut out = vec![0.0f32; nb * m];
    let work = nb * nnz * m2 * n2;
    par_rows(&mut out, nb, m, threads_for(work), |b, row| {
        let xrow = &x[b * n..(b + 1) * n];
        for i1 in 0..m1 {
            let orow = &mut row[i1 * m2..(i1 + 1) * m2];
            let (lo, hi) = (l.row_ptr[i1] as usize, l.row_ptr[i1 + 1] as usize);
            for k in lo..hi {
                let j1 = l.col_idx[k] as usize;
                let xseg = &xrow[j1 * n2..(j1 + 1) * n2];
                let blk = &qblocks[k * m2 * n2..(k + 1) * m2 * n2];
                let srow = &scales[k * m2..(k + 1) * m2];
                for (i2, o) in orow.iter_mut().enumerate() {
                    *o += srow[i2] * simd::dot_q8(kind, &blk[i2 * n2..(i2 + 1) * n2], xseg);
                }
            }
            if relu {
                for o in orow.iter_mut() {
                    if *o < 0.0 {
                        *o = 0.0;
                    }
                }
            }
        }
    });
    Ok(out)
}

/// Single-layer int8 forward (no activation) under the dispatched SIMD
/// kind — bench and test entry point.
pub fn q8_forward(x: &[f32], nb: usize, l: &QuantLayer) -> Result<Vec<f32>> {
    forward_impl_q8(simd::active(), x, nb, l, false)
}

/// [`q8_forward`] with an explicit SIMD kind — scalar-vs-dispatched
/// parity tests and bench variants go through here.
pub fn q8_forward_with(
    kind: SimdKind,
    x: &[f32],
    nb: usize,
    l: &QuantLayer,
    relu: bool,
) -> Result<Vec<f32>> {
    forward_impl_q8(kind, x, nb, l, relu)
}

/// Logits of a full int8 stack on a flat batch — ReLU fused into every
/// hidden layer, none after the logits; the int8 mirror of
/// [`super::bsr::model_forward`] with identical error coordinates.
pub fn model_forward_q8(model: &QuantModel, x: &[f32], nb: usize) -> Result<Vec<f32>> {
    if model.layers.is_empty() {
        bail!("quantized BSR model '{}' has no layers", model.spec);
    }
    if nb == 0 || x.len() != nb * model.in_dim {
        bail!(
            "model '{}' wants a flat batch of {}·{} values, got {}",
            model.spec, nb, model.in_dim, x.len()
        );
    }
    let kind = simd::active();
    let last = model.layers.len() - 1;
    let at = |i: usize| format!("model '{}' layer {i} ('{}')", model.spec, model.layers[i].name);
    let mut cur =
        forward_impl_q8(kind, x, nb, &model.layers[0], last != 0).with_context(|| at(0))?;
    for (i, l) in model.layers.iter().enumerate().skip(1) {
        cur = forward_impl_q8(kind, &cur, nb, l, i < last).with_context(|| at(i))?;
    }
    Ok(cur)
}

/// Time one int8 layer's forward — the quantized twin of
/// [`super::bsr::time_layer`], feeding `blockopt`'s dtype-aware cost
/// calibration. Bench name: `bsrq8.{m}x{n}_b{m2}x{n2}`.
pub fn time_layer_q8(x: &[f32], nb: usize, layer: &QuantLayer) -> Result<crate::bench::BenchStats> {
    let kind = simd::active();
    forward_impl_q8(kind, x, nb, layer, false)
        .with_context(|| format!("timing quantized layer '{}'", layer.name))?;
    let name = format!("bsrq8.{}x{}_b{}x{}", layer.m, layer.n, layer.m2, layer.n2);
    Ok(crate::bench::quick_bench(&name, || {
        std::hint::black_box(
            forward_impl_q8(kind, std::hint::black_box(x), nb, layer, false).unwrap(),
        );
    }))
}

#[cfg(test)]
mod tests {
    use super::super::synth_block_sparse_weights;
    use super::*;
    use crate::util::rng::Rng;

    fn layer(seed: u64, m: usize, n: usize, m2: usize, n2: usize, density: f64) -> BsrLayer {
        let mut rng = Rng::new(seed);
        let (w, _) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, density);
        BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap()
    }

    #[test]
    fn round_trip_error_is_within_half_scale_per_row() {
        let l = layer(3, 12, 16, 3, 4, 0.6);
        let q = quantize_layer(&l);
        q.validate().unwrap();
        let back = dequantize_layer(&q);
        let (m2, n2) = (l.m2, l.n2);
        for k in 0..l.nnz_blocks() {
            for i2 in 0..m2 {
                let scale = q.scales[k * m2 + i2];
                for j2 in 0..n2 {
                    let w = l.blocks[(k * m2 + i2) * n2 + j2];
                    let dq = back.blocks[(k * m2 + i2) * n2 + j2];
                    assert!(
                        (w - dq).abs() <= scale / 2.0 + 1e-7,
                        "block {k} row {i2} col {j2}: |{w} - {dq}| > {scale}/2"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rows_and_single_element_blocks_round_trip_exactly() {
        // all-zero stored block: scale 0, q 0, dequant exactly 0
        let mut l = layer(4, 8, 8, 2, 2, 0.5);
        let span = l.m2 * l.n2;
        l.blocks.to_mut()[..span].fill(0.0);
        let q = quantize_layer(&l);
        assert!(q.scales[..l.m2].iter().all(|&s| s == 0.0));
        assert!(dequantize_layer(&q).blocks[..span].iter().all(|&v| v == 0.0));
        // 1×1 blocks: every row is its own max → |q| = 127 or 0, exact
        let l1 = layer(5, 6, 6, 1, 1, 0.7);
        let back = dequantize_layer(&quantize_layer(&l1));
        for (a, b) in l1.blocks.iter().zip(back.blocks.iter()) {
            assert!((a - b).abs() <= a.abs() * 1e-6, "1x1 must be ~exact: {a} vs {b}");
        }
    }

    #[test]
    fn q8_forward_tracks_f32_forward() {
        let l = layer(6, 24, 32, 4, 8, 0.5);
        let q = quantize_layer(&l);
        let nb = 5;
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..nb * l.n).map(|_| rng.normal()).collect();
        let zf = super::super::bsr::bsr_forward(&x, nb, &l).unwrap();
        let zq = q8_forward(&x, nb, &q).unwrap();
        // int8 weights ⇒ relative error ~1/254 per term; loose abs bound
        // scaled by the logit magnitude
        let rms = (zf.iter().map(|v| (v * v) as f64).sum::<f64>() / zf.len() as f64).sqrt();
        let mae = zf.iter().zip(&zq).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
            / zf.len() as f64;
        assert!(mae <= 0.02 * rms + 1e-4, "mae {mae} vs rms {rms}");
    }

    #[test]
    fn q8_forward_validates_like_the_f32_kernel() {
        let q = quantize_layer(&layer(8, 8, 8, 2, 2, 0.5));
        let x = vec![0.0f32; 2 * 8];
        assert!(q8_forward(&x, 2, &q).is_ok());
        assert!(q8_forward(&x[..15], 2, &q).is_err());
        let mut bad = q.clone();
        bad.col_idx[0] = 99;
        assert!(q8_forward(&x, 2, &bad).is_err());
        let mut bad = q.clone();
        let cut = bad.scales.len() - 1;
        bad.scales.to_mut().truncate(cut);
        assert!(q8_forward(&x, 2, &bad).is_err());
        let mut bad = q.clone();
        bad.scales.to_mut()[0] = f32::NAN;
        assert!(q8_forward(&x, 2, &bad).is_err());
    }

    #[test]
    fn save_load_round_trip_int8() {
        let model = QuantModel {
            spec: "q8-rt".into(),
            method: "kpd".into(),
            in_dim: 16,
            out_dim: 12,
            layers: vec![quantize_layer(&layer(9, 12, 16, 3, 4, 0.6))],
        };
        model.validate().unwrap();
        let dir = std::env::temp_dir().join("bs_quant_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let back = QuantModel::load(&path).unwrap();
        assert_eq!(back, model);
        // peek reports the dtype without reading the payload
        let meta = BsrModel::peek(&path).unwrap();
        assert_eq!(meta.dtype, "int8");
        assert_eq!(meta.version, 2);
        // the typed loaders refuse to cross dtypes
        let err = BsrModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("int8"), "{err}");
        // and load_auto routes to the right one
        match super::super::load_auto(&path).unwrap() {
            super::super::ServedModel::Int8(m) => assert_eq!(m, model),
            other => panic!("load_auto picked {other:?}"),
        }
    }

    #[test]
    fn time_layer_q8_samples_and_validates() {
        let q = quantize_layer(&layer(10, 8, 16, 2, 4, 0.5));
        let x = vec![0.5f32; 4 * 16];
        let stats = time_layer_q8(&x, 4, &q).unwrap();
        assert!(stats.iters >= 10, "{stats:?}");
        assert_eq!(stats.name, "bsrq8.8x16_b2x4");
        assert!(time_layer_q8(&x[..7], 4, &q).is_err());
    }
}
