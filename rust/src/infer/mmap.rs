//! Zero-copy artifact loading: mmap a version-2 `"BSRM"` container and
//! serve its block payload straight from the page cache.
//!
//! [`open_model_mmap`] (and the typed [`open_bsr_mmap`] /
//! [`open_quant_mmap`]) map the file read-only and build a model whose
//! bulk arrays are [`BlockStore::Mapped`] windows into the mapping:
//! start-up touches the prologue, the CRC-guarded header and the small
//! CSR index arrays (which are copied out and validated eagerly — the
//! kernels index by them without checks), but **never** the packed
//! blocks. A multi-GB artifact therefore starts in O(header + index)
//! time and resident memory; block pages fault in lazily as traffic
//! actually reads them, and clean pages can be evicted under memory
//! pressure for free. [`MapStats`] reports exactly that split, and the
//! page-touch accounting test pins it: two artifacts with identical
//! grids but 1000× different payloads must report identical
//! `resident_bytes`.
//!
//! Integrity: the header CRC, padding and extent equation are verified at
//! open (a corrupt header can never mis-drive the loader), but the
//! payload CRC is **not** swept — touching every page would defeat the
//! point. `BsrModel::load` remains the integrity checker of record;
//! corruption inside a mapped block surfaces as wrong logits, not UB,
//! because every offset/length was bounds- and alignment-checked against
//! the mapping before a `BlockStore` was built.
//!
//! Portability: the mapping uses raw `mmap(2)`/`munmap(2)` (no libc
//! crate in the offline vendor set) and is gated to little-endian unix —
//! exactly the targets where reinterpreting mapped bytes as `f32`/`i8`
//! matches the container's wire format. Everywhere else (and for
//! version-1 artifacts, which interleave frame metadata with payload)
//! these functions fall back to the owned read path and report
//! `resident_bytes == file_bytes`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::quant::{QBlockStore, QuantLayer, QuantModel};
use super::{BlockStore, BsrLayer, BsrModel, ServedModel};

/// What [`open_model_mmap`] touched: total artifact size versus the bytes
/// actually read/copied at open time (prologue + header + padding + CSR
/// index arrays). For a mapped open, `resident_bytes` is O(header +
/// index) and independent of the block payload; the read-path fallback
/// reports `resident_bytes == file_bytes`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapStats {
    pub file_bytes: u64,
    pub resident_bytes: u64,
}

impl MapStats {
    /// Whether the open was zero-copy (some payload bytes stayed
    /// untouched) rather than the full-read fallback.
    pub fn zero_copy(&self) -> bool {
        self.resident_bytes < self.file_bytes
    }
}

#[cfg(all(unix, target_endian = "little"))]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A read-only, whole-file memory mapping. The region owns the mapping
/// (`munmap` on drop) and is shared behind an `Arc` by every
/// `BlockStore::Mapped` carved out of it — the file stays mapped for as
/// long as any layer (or clone of a layer, however the model was
/// hot-swapped around) still references it.
pub struct MmapRegion {
    ptr: *mut u8,
    len: usize,
}

// The mapping is read-only for its entire lifetime: shared references to
// its bytes are safe to send and share across the serving threads.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl MmapRegion {
    #[cfg(all(unix, target_endian = "little"))]
    fn map(f: &std::fs::File, len: usize) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr.is_null() || ptr as usize == usize::MAX {
            bail!("mmap of {len} bytes failed");
        }
        Ok(MmapRegion { ptr: ptr as *mut u8, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// `len` f32 values at byte offset `off`. Bounds and 4-byte alignment
    /// were checked when the store was built (8-aligned offsets over a
    /// page-aligned base); the debug asserts re-state the invariant.
    pub(crate) fn f32s(&self, off: usize, len: usize) -> &[f32] {
        debug_assert!(off + len * 4 <= self.len);
        debug_assert_eq!((self.ptr as usize + off) % std::mem::align_of::<f32>(), 0);
        unsafe { std::slice::from_raw_parts(self.ptr.add(off) as *const f32, len) }
    }

    /// `len` i8 values at byte offset `off`.
    pub(crate) fn i8s(&self, off: usize, len: usize) -> &[i8] {
        debug_assert!(off + len <= self.len);
        unsafe { std::slice::from_raw_parts(self.ptr.add(off) as *const i8, len) }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(all(unix, target_endian = "little"))]
        unsafe {
            sys::munmap(self.ptr as *mut core::ffi::c_void, self.len);
        }
    }
}

/// Map `path` if (and only if) it is a version-2 container on a platform
/// with mmap support. `Ok(None)` means "use the read path" — version-1
/// artifact, too-short file, or foreign magic (the read path then raises
/// its own typed error).
#[cfg(all(unix, target_endian = "little"))]
fn map_v2(path: &Path) -> Result<Option<Arc<MmapRegion>>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening BSR model {path:?}"))?;
    let len = f.metadata()?.len();
    if len < super::PROLOGUE_LEN as u64 {
        return Ok(None);
    }
    let mut head = [0u8; 8];
    f.read_exact(&mut head)?;
    if &head[..4] != super::MAGIC {
        return Ok(None);
    }
    if u32::from_le_bytes(head[4..8].try_into().unwrap()) != 2 {
        return Ok(None);
    }
    let len = usize::try_from(len).context("artifact larger than the address space")?;
    Ok(Some(Arc::new(MmapRegion::map(&f, len)?)))
}

/// Zero-copy open of an f32 artifact. v1 / unsupported-platform fallback:
/// [`BsrModel::load`] with `resident_bytes == file_bytes`.
pub fn open_bsr_mmap(path: &Path) -> Result<(BsrModel, MapStats)> {
    #[cfg(all(unix, target_endian = "little"))]
    if let Some(region) = map_v2(path)? {
        return mapped_bsr(&region);
    }
    let model = BsrModel::load(path)?;
    let file_bytes = std::fs::metadata(path)?.len();
    Ok((model, MapStats { file_bytes, resident_bytes: file_bytes }))
}

/// Zero-copy open of an int8 artifact (blocks **and** scales stay
/// mapped). Same fallback contract as [`open_bsr_mmap`].
pub fn open_quant_mmap(path: &Path) -> Result<(QuantModel, MapStats)> {
    #[cfg(all(unix, target_endian = "little"))]
    if let Some(region) = map_v2(path)? {
        return mapped_quant(&region);
    }
    let model = QuantModel::load(path)?;
    let file_bytes = std::fs::metadata(path)?.len();
    Ok((model, MapStats { file_bytes, resident_bytes: file_bytes }))
}

/// Zero-copy open of an artifact of either dtype: one O(header) peek
/// routes to the matching typed open. This is what the CLI's `--mmap`
/// arm and a registry cold-start scan call.
pub fn open_model_mmap(path: &Path) -> Result<(ServedModel, MapStats)> {
    let meta = BsrModel::peek(path)?;
    if meta.dtype == "int8" {
        let (m, s) = open_quant_mmap(path)?;
        Ok((m.into(), s))
    } else {
        let (m, s) = open_bsr_mmap(path)?;
        Ok((m.into(), s))
    }
}

#[cfg(all(unix, target_endian = "little"))]
fn mapped_bsr(region: &Arc<MmapRegion>) -> Result<(BsrModel, MapStats)> {
    let c = super::open_v2_bytes(region.bytes(), false)?;
    if c.prologue.dtype != super::DTYPE_F32 {
        bail!(
            "artifact stores {} blocks — open it with `open_model_mmap`",
            super::dtype_label(c.prologue.dtype)
        );
    }
    let payload_base = c.prologue.payload_off as usize;
    // every byte before the payload was read during open_v2_bytes
    let mut resident = c.prologue.payload_off;
    let mut layers = Vec::new();
    for lh in &c.header.layers {
        let m1 = lh.m / lh.m2;
        let row_ptr = super::take_u32s(
            c.payload, lh.row_ptr_off, (m1 + 1) as u64,
            &format!("{}.row_ptr", lh.name),
        )?;
        let col_idx = super::take_u32s(
            c.payload, lh.col_idx_off, lh.nnz as u64,
            &format!("{}.col_idx", lh.name),
        )?;
        resident += (row_ptr.len() as u64 + col_idx.len() as u64) * 4;
        let nvals = lh.block_values()?;
        // bounds/alignment check only — the block pages stay untouched
        let (off, _) = super::span(
            c.payload.len(), lh.blocks_off, 4, nvals,
            &format!("{}.blocks", lh.name),
        )?;
        layers.push(BsrLayer {
            name: lh.name.clone(),
            m: lh.m,
            n: lh.n,
            m2: lh.m2,
            n2: lh.n2,
            row_ptr,
            col_idx,
            blocks: BlockStore::Mapped {
                region: region.clone(),
                off: payload_base + off,
                len: nvals as usize,
            },
        });
    }
    let model = BsrModel {
        spec: c.header.spec.clone(),
        method: c.header.method.clone(),
        in_dim: c.header.in_dim,
        out_dim: c.header.out_dim,
        layers,
    };
    // validate reads the copied index arrays and the stores' lengths —
    // no block page is faulted in
    model.validate()?;
    let stats = MapStats { file_bytes: region.len() as u64, resident_bytes: resident };
    Ok((model, stats))
}

#[cfg(all(unix, target_endian = "little"))]
fn mapped_quant(region: &Arc<MmapRegion>) -> Result<(QuantModel, MapStats)> {
    let c = super::open_v2_bytes(region.bytes(), false)?;
    if c.prologue.dtype != super::DTYPE_INT8 {
        bail!(
            "artifact stores {} blocks — open it with `open_model_mmap`",
            super::dtype_label(c.prologue.dtype)
        );
    }
    let payload_base = c.prologue.payload_off as usize;
    let mut resident = c.prologue.payload_off;
    let mut layers = Vec::new();
    for lh in &c.header.layers {
        let m1 = lh.m / lh.m2;
        let row_ptr = super::take_u32s(
            c.payload, lh.row_ptr_off, (m1 + 1) as u64,
            &format!("{}.row_ptr", lh.name),
        )?;
        let col_idx = super::take_u32s(
            c.payload, lh.col_idx_off, lh.nnz as u64,
            &format!("{}.col_idx", lh.name),
        )?;
        resident += (row_ptr.len() as u64 + col_idx.len() as u64) * 4;
        let nvals = lh.block_values()?;
        let nscales = (lh.nnz as u64) * (lh.m2 as u64);
        let (qoff, _) = super::span(
            c.payload.len(), lh.blocks_off, 1, nvals,
            &format!("{}.qblocks", lh.name),
        )?;
        let (soff, _) = super::span(
            c.payload.len(), lh.scales_off, 4, nscales,
            &format!("{}.scales", lh.name),
        )?;
        layers.push(QuantLayer {
            name: lh.name.clone(),
            m: lh.m,
            n: lh.n,
            m2: lh.m2,
            n2: lh.n2,
            row_ptr,
            col_idx,
            qblocks: QBlockStore::Mapped {
                region: region.clone(),
                off: payload_base + qoff,
                len: nvals as usize,
            },
            scales: BlockStore::Mapped {
                region: region.clone(),
                off: payload_base + soff,
                len: nscales as usize,
            },
        });
    }
    let model = QuantModel {
        spec: c.header.spec.clone(),
        method: c.header.method.clone(),
        in_dim: c.header.in_dim,
        out_dim: c.header.out_dim,
        layers,
    };
    model.validate()?;
    let stats = MapStats { file_bytes: region.len() as u64, resident_bytes: resident };
    Ok((model, stats))
}

#[cfg(test)]
mod tests {
    use super::super::{synth_block_sparse_weights, BsrLayer, BsrModel};
    use super::*;
    use crate::util::rng::Rng;

    /// Two single-layer models with the *same* block grid and nnz but
    /// wildly different block sizes — same header/index footprint,
    /// ~1000× different payload.
    fn graded_models() -> (BsrModel, BsrModel) {
        let mk = |m2: usize, n2: usize, seed: u64| {
            let (m1, n1) = (8usize, 8usize);
            let (m, n) = (m1 * m2, n1 * n2);
            let mut rng = Rng::new(seed);
            let (w, _) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, 0.5);
            BsrModel {
                spec: "page-touch".into(),
                method: "kpd".into(),
                in_dim: n,
                out_dim: m,
                layers: vec![BsrLayer::from_dense("fc", &w, m, n, m2, n2).unwrap()],
            }
        };
        (mk(2, 2, 9), mk(64, 64, 9))
    }

    #[test]
    fn mmap_open_matches_read_open_bit_for_bit() {
        let mut rng = Rng::new(77);
        let (w, _) = synth_block_sparse_weights(&mut rng, 24, 32, 4, 8, 0.4);
        let model = BsrModel {
            spec: "mmap-parity".into(),
            method: "kpd".into(),
            in_dim: 32,
            out_dim: 24,
            layers: vec![BsrLayer::from_dense("fc", &w, 24, 32, 4, 8).unwrap()],
        };
        let dir = std::env::temp_dir().join("bs_mmap_parity_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let read = BsrModel::load(&path).unwrap();
        let (mapped, stats) = BsrModel::open_mmap(&path).unwrap();
        // BlockStore::PartialEq compares values, so this is bitwise block
        // equality across the two open paths
        assert_eq!(mapped, read);
        assert_eq!(stats.file_bytes, std::fs::metadata(&path).unwrap().len());
        // and the logits agree bit for bit
        let x: Vec<f32> = (0..2 * 32).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = crate::infer::bsr::model_forward(&read, &x, 2).unwrap();
        let b = crate::infer::bsr::model_forward(&mapped, &x, 2).unwrap();
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
        #[cfg(all(unix, target_endian = "little"))]
        {
            assert!(mapped.layers[0].blocks.is_mapped());
            assert!(stats.zero_copy(), "{stats:?}");
        }
    }

    /// The page-touch accounting claim: open cost is O(header + index),
    /// not O(payload). Same grid + nnz, 1024× the block bytes → identical
    /// resident_bytes.
    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn mmap_open_resident_bytes_are_independent_of_payload_size() {
        let (small, large) = graded_models();
        let dir = std::env::temp_dir().join("bs_mmap_pages_test");
        std::fs::create_dir_all(&dir).unwrap();
        let (ps, pl) = (dir.join("small.bsm"), dir.join("large.bsm"));
        small.save(&ps).unwrap();
        large.save(&pl).unwrap();
        let (_, st_small) = BsrModel::open_mmap(&ps).unwrap();
        let (_, st_large) = BsrModel::open_mmap(&pl).unwrap();
        assert!(
            st_large.file_bytes > 500 * st_small.file_bytes,
            "payloads must differ wildly: {st_small:?} vs {st_large:?}"
        );
        assert_eq!(
            st_small.resident_bytes, st_large.resident_bytes,
            "open touched payload pages: {st_small:?} vs {st_large:?}"
        );
        assert!(st_large.zero_copy());
    }

    #[test]
    fn v1_artifacts_fall_back_to_the_read_path() {
        let (small, _) = graded_models();
        let dir = std::env::temp_dir().join("bs_mmap_v1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.bsm");
        small.save_v1(&path).unwrap();
        let (model, stats) = BsrModel::open_mmap(&path).unwrap();
        assert_eq!(model, small);
        assert_eq!(stats.resident_bytes, stats.file_bytes);
        assert!(!stats.zero_copy());
        assert!(!model.layers[0].blocks.is_mapped());
    }
}
