//! Named multi-model serving: a registry of [`Engine`]s keyed by model
//! name, with atomic hot-swap deployment.
//!
//! One process serves many models; each name owns one engine (its own
//! admission queue, worker pool and stats). [`ModelRegistry::deploy`]
//! routes a replacement model through [`Engine::swap_model`] when the
//! engine's request shapes still fit — a single `Arc` swap, O(1) beyond
//! validation, no queue disturbance, no thread respawn; in-flight
//! micro-batches finish on the model they started with. A replacement
//! with *different* shapes cannot reuse the queue (queued requests were
//! admitted against the old shapes), so deploy builds a fresh engine and
//! retires the old one — handed-out `Arc<Engine>`s keep serving until
//! their holders drop them, then the old engine drains and joins.
//!
//! [`ModelRegistry::deploy_from_path`] pairs with the artifact side of
//! the same discipline: `BsrModel::save` publishes write-then-rename, so
//! a deploy watching a path never loads a torn file, and
//! `BsrModel::peek` lets a scan route artifacts without paying a full
//! load.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::engine::{Engine, EngineError, EngineOpts};
use super::ServedModel;

/// A name → [`Engine`] map; every engine is built with the registry's
/// [`EngineOpts`]. All methods take `&self` — the registry is shared
/// behind an `Arc` between deployers and request routers.
pub struct ModelRegistry {
    opts: EngineOpts,
    engines: Mutex<BTreeMap<String, Arc<Engine>>>,
}

impl ModelRegistry {
    pub fn new(opts: EngineOpts) -> Self {
        Self { opts, engines: Mutex::new(BTreeMap::new()) }
    }

    /// Deploy `model` under `name` — any [`ServedModel`] (a `BsrModel` or
    /// `QuantModel` converts implicitly): first deploy creates an engine
    /// (generation 0); a redeploy hot-swaps in place when the shapes
    /// still fit — **dtype may change**, which is how an int8 artifact
    /// rolls out over its f32 ancestor without dropping a request — and
    /// otherwise replaces the engine (generation restarts at 0). Returns
    /// the serving generation. An invalid model is rejected before
    /// anything existing is touched.
    pub fn deploy(&self, name: &str, model: impl Into<ServedModel>) -> Result<u64> {
        let model: ServedModel = model.into();
        // try the in-place swap first, outside any new-engine work
        {
            let engines = self.engines.lock().unwrap();
            if let Some(engine) = engines.get(name) {
                match engine.swap_model(model.clone()) {
                    Ok(generation) => return Ok(generation),
                    // shape mismatch falls through to engine replacement;
                    // an *invalid* model must not replace a live engine
                    Err(EngineError::SwapRejected(msg)) if model.validate().is_err() => {
                        return Err(EngineError::SwapRejected(msg))
                            .with_context(|| format!("deploying '{name}'"));
                    }
                    Err(_) => {}
                }
            }
        }
        // build the replacement engine without holding the map lock (it
        // validates and spawns threads), then install it with one map write
        let engine = Arc::new(
            Engine::new(model, self.opts.clone())
                .with_context(|| format!("deploying '{name}'"))?,
        );
        let generation = engine.generation();
        let old = self.engines.lock().unwrap().insert(name.to_string(), engine);
        // the old engine (if any) drains outside the lock when its last
        // Arc drops — possibly right here
        drop(old);
        Ok(generation)
    }

    /// [`ModelRegistry::deploy`] from a saved artifact of either dtype:
    /// one O(header) peek routes f32 containers to `BsrModel::load` and
    /// int8 ones to `QuantModel::load` ([`super::load_auto`]). Pairs with
    /// the atomic write-then-rename save: a path being re-published
    /// concurrently always loads as one complete artifact.
    pub fn deploy_from_path(&self, name: &str, path: &Path) -> Result<u64> {
        let model = super::load_auto(path)
            .with_context(|| format!("deploying '{name}' from {path:?}"))?;
        self.deploy(name, model)
    }

    /// The engine serving `name`, if deployed. The returned `Arc` stays
    /// valid across later deploys — a router holding it keeps getting
    /// answers (from the engine it resolved, at whatever generation that
    /// engine serves) until it re-resolves.
    pub fn get(&self, name: &str) -> Option<Arc<Engine>> {
        self.engines.lock().unwrap().get(name).cloned()
    }

    /// Remove `name`. Returns whether it was deployed. The engine drains
    /// and joins when the last outstanding `Arc` drops.
    pub fn undeploy(&self, name: &str) -> bool {
        self.engines.lock().unwrap().remove(name).is_some()
    }

    /// Deployed names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.engines.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::BsrLayer;
    use crate::util::rng::Rng;

    fn model(seed: u64, in_dim: usize, out_dim: usize) -> BsrModel {
        let mut rng = Rng::new(seed);
        let hidden = 6;
        let w1: Vec<f32> = (0..hidden * in_dim).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..out_dim * hidden).map(|_| rng.normal()).collect();
        BsrModel {
            spec: format!("reg{seed}"),
            method: "dense".into(),
            in_dim,
            out_dim,
            layers: vec![
                BsrLayer::from_dense("fc1", &w1, hidden, in_dim, 2, 2).unwrap(),
                BsrLayer::from_dense("fc2", &w2, out_dim, hidden, 2, 2).unwrap(),
            ],
        }
    }

    fn opts() -> EngineOpts {
        EngineOpts { max_batch: 4, workers: 2, queue_depth: 16 }
    }

    #[test]
    fn deploy_get_undeploy_lifecycle() {
        let reg = ModelRegistry::new(opts());
        assert!(reg.get("a").is_none());
        assert_eq!(reg.deploy("a", model(1, 8, 4)).unwrap(), 0);
        assert_eq!(reg.deploy("b", model(2, 8, 4)).unwrap(), 0);
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        let engine = reg.get("a").unwrap();
        assert!(engine.predict(&[0.1; 8]).is_ok());
        assert!(reg.undeploy("a"));
        assert!(!reg.undeploy("a"));
        assert!(reg.get("a").is_none());
        // the held Arc outlives the undeploy and still serves
        assert!(engine.predict(&[0.2; 8]).is_ok());
    }

    #[test]
    fn redeploy_same_shapes_hot_swaps_in_place() {
        let reg = ModelRegistry::new(opts());
        reg.deploy("m", model(3, 8, 4)).unwrap();
        let engine_before = reg.get("m").unwrap();
        let generation = reg.deploy("m", model(4, 8, 4)).unwrap();
        assert_eq!(generation, 1);
        // same engine object: the queue and its stats survived the swap
        assert!(Arc::ptr_eq(&engine_before, &reg.get("m").unwrap()));
        assert_eq!(engine_before.generation(), 1);
        assert_eq!(engine_before.predict(&[0.3; 8]).unwrap().generation, 1);
    }

    #[test]
    fn redeploy_new_shapes_replaces_the_engine() {
        let reg = ModelRegistry::new(opts());
        reg.deploy("m", model(5, 8, 4)).unwrap();
        let old = reg.get("m").unwrap();
        // 12-feature replacement cannot reuse an 8-feature queue
        let generation = reg.deploy("m", model(6, 12, 4)).unwrap();
        assert_eq!(generation, 0);
        let new = reg.get("m").unwrap();
        assert!(!Arc::ptr_eq(&old, &new));
        assert!(new.predict(&[0.1; 12]).is_ok());
        assert!(old.predict(&[0.1; 8]).is_ok(), "retired engine serves until dropped");
    }

    #[test]
    fn deploying_an_invalid_model_rejects_and_keeps_the_old() {
        let reg = ModelRegistry::new(opts());
        reg.deploy("m", model(7, 8, 4)).unwrap();
        let mut corrupt = model(8, 8, 4);
        corrupt.layers[0].col_idx[0] = 99;
        assert!(reg.deploy("m", corrupt.clone()).is_err());
        assert_eq!(reg.get("m").unwrap().generation(), 0);
        // also rejected as a *first* deploy (Engine::new validates)
        assert!(reg.deploy("fresh", corrupt).is_err());
        assert!(reg.get("fresh").is_none());
    }

    #[test]
    fn deploy_from_path_round_trips() {
        let dir = std::env::temp_dir().join("bs_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        let m = model(9, 8, 4);
        m.save(&path).unwrap();
        let reg = ModelRegistry::new(opts());
        assert_eq!(reg.deploy_from_path("disk", &path).unwrap(), 0);
        let p = reg.get("disk").unwrap().predict(&[0.4; 8]).unwrap();
        let want = crate::infer::bsr::model_forward(&m, &[0.4; 8], 1).unwrap();
        assert_eq!(p.logits, want);
        // republish (atomic save) + redeploy = hot swap
        m.save(&path).unwrap();
        assert_eq!(reg.deploy_from_path("disk", &path).unwrap(), 1);
        assert!(reg.deploy_from_path("gone", &dir.join("missing.bsm")).is_err());
    }

    /// Quantized rollout: an int8 model hot-swaps in place over its f32
    /// ancestor (same shapes, same engine, same queue), and an int8
    /// artifact on disk deploys through the dtype-routing loader.
    #[test]
    fn quantized_artifacts_deploy_and_hot_swap_over_f32() {
        let reg = ModelRegistry::new(opts());
        let m = model(10, 8, 4);
        reg.deploy("m", m.clone()).unwrap();
        let engine = reg.get("m").unwrap();
        assert_eq!(engine.model().dtype(), "f32");
        let q = crate::infer::quant::quantize_model(&m).unwrap();
        assert_eq!(reg.deploy("m", q.clone()).unwrap(), 1);
        assert!(Arc::ptr_eq(&engine, &reg.get("m").unwrap()), "dtype swap must reuse the engine");
        assert_eq!(engine.model().dtype(), "int8");
        let p = engine.predict(&[0.4; 8]).unwrap();
        let want = crate::infer::quant::model_forward_q8(&q, &[0.4; 8], 1).unwrap();
        assert_eq!(p.logits, want);
        // an int8 artifact from disk routes through the peek-based loader
        let dir = std::env::temp_dir().join("bs_registry_q8_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("q.bsm");
        q.save(&path).unwrap();
        assert_eq!(reg.deploy_from_path("m", &path).unwrap(), 2);
        assert_eq!(engine.model().dtype(), "int8");
    }
}
