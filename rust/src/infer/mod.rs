//! Block-sparse inference: the train→export→serve half of the paper's
//! story. Training (PRs 1–3) produces block-wise sparse weights; this
//! subsystem makes the §4 claim — "decreased memory and computation costs
//! during inference" — executable:
//!
//! * **export** ([`export`]): `Backend::materialize` any trained spec
//!   (kpd / group_lasso / elastic_gl / rigl_block / iter_prune / dense,
//!   single- or multi-layer) and pack every slot into BSR
//!   (block-sparse-row) form — only the blocks that survived training are
//!   stored, so the artifact's memory *is* the occupancy.
//! * **format** ([`BsrModel::save`] / [`BsrModel::load`]): a versioned
//!   little-endian container (`"BSRM"`) framed with the same
//!   `checkpoint::wire` helpers and trailing CRC-32 guard as the
//!   checkpoint container, so corruption fails identically loudly.
//!   `save` publishes atomically (write a temp sibling, fsync, rename) —
//!   a reader or hot-swap watcher never observes a torn artifact — and
//!   [`BsrModel::peek`] probes a file's header ([`BsrMeta`]) in O(header)
//!   without reading the payload.
//! * **kernels** ([`bsr`]): gather-free block-GEMM forward over the stored
//!   blocks only (plus a ReLU-fused variant), built on the same threading
//!   substrate as `backend::native::linalg` — inference cost scales with
//!   occupancy, not the dense shape.
//! * **engine** ([`engine`]): a multi-threaded serving engine with
//!   **bounded admission** (a full queue load-sheds with the typed
//!   [`engine::EngineError::Overloaded`] instead of queueing forever),
//!   dynamic micro-batching over `util::pool::ThreadPool`, root-cause
//!   error propagation to every waiter of a failed batch, and atomic
//!   model hot-swap (one `Arc` swap; in-flight batches finish on the
//!   model they started with).
//! * **registry** ([`registry`]): named multi-model serving — deploy /
//!   hot-swap / undeploy engines by model name, from memory or disk.
//!
//! `blocksparse export` / `blocksparse infer` drive this from the CLI;
//! `benches/infer_serve.rs` measures the dense-vs-BSR speedup, the
//! serving latency distribution, the sustained-overload shed behaviour
//! and the hot-swap cost into `BENCH_infer.json`.

pub mod bsr;
pub mod engine;
pub mod registry;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::{Backend, TrainState};
use crate::checkpoint::{crc32, wire};
use crate::flops::block_sparse_infer_flops;
use crate::util::rng::Rng;

const MAGIC: &[u8; 4] = b"BSRM";
const VERSION: u32 = 1;

/// One linear slot in packed block-sparse-row form: Z = X·Wᵀ where only
/// the occupied (m2×n2) blocks of W are stored. `row_ptr`/`col_idx` are
/// the CSR-style index arrays over the (m1×n1) block grid; `blocks` holds
/// each stored block row-major, in `col_idx` order, so the forward kernel
/// streams them contiguously with no gather.
#[derive(Clone, Debug, PartialEq)]
pub struct BsrLayer {
    /// slot name (`fc`, `fc1`, ...) — matches the training spec's slots
    pub name: String,
    /// output features m = m1·m2
    pub m: usize,
    /// input features n = n1·n2
    pub n: usize,
    /// block rows
    pub m2: usize,
    /// block cols
    pub n2: usize,
    /// per-block-row offsets into `col_idx`/`blocks` (length m1 + 1)
    pub row_ptr: Vec<u32>,
    /// block-column index j1 of every stored block, sorted within each row
    pub col_idx: Vec<u32>,
    /// packed (m2×n2) blocks in `col_idx` order (length nnz·m2·n2)
    pub blocks: Vec<f32>,
}

impl BsrLayer {
    /// Pack a dense row-major (m×n) weight matrix. A block is stored iff
    /// it has any non-zero entry — the training paths produce *exact*
    /// zeros (ℓ1/group prox, RigL masks, pruning masks), so no threshold
    /// is needed and packing is lossless.
    pub fn from_dense(
        name: &str,
        w: &[f32],
        m: usize,
        n: usize,
        m2: usize,
        n2: usize,
    ) -> Result<Self> {
        if m == 0 || n == 0 || m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
            bail!("block ({m2},{n2}) does not tile ({m},{n})");
        }
        if w.len() != m * n {
            bail!("slot '{name}': dense weight has {} values, wants {}", w.len(), m * n);
        }
        let (m1, n1) = (m / m2, n / n2);
        let mut row_ptr = Vec::with_capacity(m1 + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::new();
        let mut blocks = Vec::new();
        for i1 in 0..m1 {
            for j1 in 0..n1 {
                let occupied = (0..m2).any(|i2| {
                    let off = (i1 * m2 + i2) * n + j1 * n2;
                    w[off..off + n2].iter().any(|&v| v != 0.0)
                });
                if !occupied {
                    continue;
                }
                col_idx.push(j1 as u32);
                for i2 in 0..m2 {
                    let off = (i1 * m2 + i2) * n + j1 * n2;
                    blocks.extend_from_slice(&w[off..off + n2]);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Ok(Self { name: name.to_string(), m, n, m2, n2, row_ptr, col_idx, blocks })
    }

    /// (m1, n1) block-grid shape.
    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.m2, self.n / self.n2)
    }

    pub fn nnz_blocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Fraction of grid blocks stored (1.0 = fully dense).
    pub fn occupancy(&self) -> f64 {
        let (m1, n1) = self.grid();
        self.nnz_blocks() as f64 / (m1 * n1) as f64
    }

    /// Block sparsity rate = 1 − occupancy (the tables' convention).
    pub fn block_sparsity(&self) -> f64 {
        1.0 - self.occupancy()
    }

    /// Stored weight values (the artifact's parameter memory).
    pub fn nnz_params(&self) -> u64 {
        self.blocks.len() as u64
    }

    /// Inference FLOPs for one example over the stored blocks only
    /// (the §4 claim: 2·m2·n2 per occupied block).
    pub fn infer_flops(&self) -> u64 {
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, self.nnz_blocks() as u64)
    }

    /// Inference FLOPs of the equivalent dense slot.
    pub fn dense_flops(&self) -> u64 {
        let (m1, n1) = self.grid();
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, (m1 * n1) as u64)
    }

    /// Dense row-major reconstruction (tests / debugging).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.m * self.n];
        let (m1, _) = self.grid();
        for i1 in 0..m1 {
            let (lo, hi) = (self.row_ptr[i1] as usize, self.row_ptr[i1 + 1] as usize);
            for k in lo..hi {
                let j1 = self.col_idx[k] as usize;
                let blk = &self.blocks[k * self.m2 * self.n2..(k + 1) * self.m2 * self.n2];
                for i2 in 0..self.m2 {
                    let off = (i1 * self.m2 + i2) * self.n + j1 * self.n2;
                    w[off..off + self.n2]
                        .copy_from_slice(&blk[i2 * self.n2..(i2 + 1) * self.n2]);
                }
            }
        }
        w
    }

    /// Structural invariants the forward kernel indexes by without checks.
    pub fn validate(&self) -> Result<()> {
        if self.m == 0 || self.n == 0 || self.m2 == 0 || self.n2 == 0 {
            bail!("slot '{}': zero dimension", self.name);
        }
        if self.m % self.m2 != 0 || self.n % self.n2 != 0 {
            bail!(
                "slot '{}': block ({},{}) does not tile ({},{})",
                self.name, self.m2, self.n2, self.m, self.n
            );
        }
        let (m1, n1) = self.grid();
        if self.row_ptr.len() != m1 + 1 {
            bail!("slot '{}': row_ptr has {} entries, wants {}", self.name,
                  self.row_ptr.len(), m1 + 1);
        }
        if self.row_ptr[0] != 0 || self.row_ptr[m1] as usize != self.col_idx.len() {
            bail!("slot '{}': row_ptr does not bracket col_idx", self.name);
        }
        for i1 in 0..m1 {
            let (lo, hi) = (self.row_ptr[i1] as usize, self.row_ptr[i1 + 1] as usize);
            if lo > hi || hi > self.col_idx.len() {
                bail!("slot '{}': row_ptr not monotone at block-row {i1}", self.name);
            }
            let row = &self.col_idx[lo..hi];
            for (k, &j1) in row.iter().enumerate() {
                if j1 as usize >= n1 {
                    bail!("slot '{}': block column {j1} out of grid ({n1})", self.name);
                }
                if k > 0 && row[k - 1] >= j1 {
                    bail!("slot '{}': block columns not strictly increasing in row {i1}",
                          self.name);
                }
            }
        }
        if self.blocks.len() != self.col_idx.len() * self.m2 * self.n2 {
            bail!("slot '{}': {} block values, wants {}", self.name,
                  self.blocks.len(), self.col_idx.len() * self.m2 * self.n2);
        }
        Ok(())
    }
}

/// A packed block-sparse model artifact: the sequential slot stack of one
/// trained spec (ReLU between consecutive slots, none after the logits),
/// with per-layer occupancy and FLOPs/params accounting baked in.
#[derive(Clone, Debug, PartialEq)]
pub struct BsrModel {
    /// spec key the artifact was exported from
    pub spec: String,
    /// training method (kpd / group_lasso / rigl_block / ...)
    pub method: String,
    /// input features of the first slot
    pub in_dim: usize,
    /// logit classes of the last slot
    pub out_dim: usize,
    pub layers: Vec<BsrLayer>,
}

/// Header metadata of a saved artifact, from [`BsrModel::peek`]: enough
/// to route/validate a deployment (shape fit, layer count, artifact
/// size) without loading the block payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BsrMeta {
    pub spec: String,
    pub method: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub num_layers: usize,
    /// total artifact size on disk (magic + body + CRC)
    pub file_bytes: u64,
}

impl BsrModel {
    /// Inference FLOPs for one example over the whole stack.
    pub fn infer_flops_per_example(&self) -> u64 {
        self.layers.iter().map(BsrLayer::infer_flops).sum()
    }

    /// Dense-equivalent inference FLOPs for one example.
    pub fn dense_flops_per_example(&self) -> u64 {
        self.layers.iter().map(BsrLayer::dense_flops).sum()
    }

    /// Stored weight values across all layers.
    pub fn nnz_params(&self) -> u64 {
        self.layers.iter().map(BsrLayer::nnz_params).sum()
    }

    /// Whole-model block sparsity, weighted by dense slot size (the same
    /// Σ zeros / Σ entries convention as `sparsity::aggregate`).
    pub fn block_sparsity(&self) -> f64 {
        crate::sparsity::aggregate(
            &self
                .layers
                .iter()
                .map(|l| (l.block_sparsity(), l.m * l.n))
                .collect::<Vec<_>>(),
        )
    }

    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("BSR model '{}' has no layers", self.spec);
        }
        for l in &self.layers {
            l.validate()?;
        }
        if self.layers[0].n != self.in_dim {
            bail!("first slot wants {} inputs, model says {}", self.layers[0].n, self.in_dim);
        }
        let last = self.layers.last().unwrap();
        if last.m != self.out_dim {
            bail!("last slot emits {} features, model says {}", last.m, self.out_dim);
        }
        for w in self.layers.windows(2) {
            if w[0].m != w[1].n {
                bail!(
                    "slot '{}' wants {} inputs but '{}' emits {}",
                    w[1].name, w[1].n, w[0].name, w[0].m
                );
            }
        }
        Ok(())
    }

    /// Serialize: `"BSRM"` | body | crc32(body), body framed with the
    /// shared `checkpoint::wire` helpers.
    ///
    /// The publish is **atomic**: the artifact is fully written and
    /// fsynced to a temp sibling, then `rename`d over `path` (atomic
    /// within a directory on POSIX). A concurrent reader — a hot-swap
    /// watcher re-`load`ing the same path mid-save — sees either the old
    /// complete file or the new complete file, never a torn prefix; this
    /// is the on-disk half of the engine's in-memory `Arc` swap.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut body = Vec::new();
        wire::put_u32(&mut body, VERSION);
        wire::put_str(&mut body, &self.spec);
        wire::put_str(&mut body, &self.method);
        wire::put_u32(&mut body, self.in_dim as u32);
        wire::put_u32(&mut body, self.out_dim as u32);
        wire::put_u32(&mut body, self.layers.len() as u32);
        for l in &self.layers {
            wire::put_str(&mut body, &l.name);
            wire::put_u32(&mut body, l.m as u32);
            wire::put_u32(&mut body, l.n as u32);
            wire::put_u32(&mut body, l.m2 as u32);
            wire::put_u32(&mut body, l.n2 as u32);
            wire::put_u32(&mut body, l.col_idx.len() as u32);
            wire::put_u32s(&mut body, &l.row_ptr);
            wire::put_u32s(&mut body, &l.col_idx);
            wire::put_f32s(&mut body, &l.blocks);
        }
        let crc = crc32(&body);
        // pid + process-wide counter keep concurrent savers (even of the
        // same destination) on distinct temp files; the dot prefix keeps
        // half-written temps out of naive directory globs
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let file_name = path
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("model.bsm");
        let tmp = path.with_file_name(format!(
            ".{file_name}.{}.{seq}.tmp",
            std::process::id()
        ));
        let publish = (|| -> Result<()> {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating BSR model temp {tmp:?}"))?;
            f.write_all(MAGIC)?;
            f.write_all(&body)?;
            f.write_all(&crc.to_le_bytes())?;
            // the rename only publishes bytes that are durably on disk
            f.sync_all()?;
            drop(f);
            std::fs::rename(&tmp, path)
                .with_context(|| format!("publishing BSR model {path:?}"))?;
            Ok(())
        })();
        if publish.is_err() {
            // a failed publish leaves no temp litter; `path` still holds
            // whatever complete artifact it held before
            let _ = std::fs::remove_file(&tmp);
        }
        publish
    }

    /// Probe a saved artifact's header without reading (or CRC-checking)
    /// the block payload: O(header) work no matter how large the model
    /// is. This is what a registry or startup scan uses to answer "what
    /// is this file and does it fit my engine?" before paying for
    /// [`BsrModel::load`]. The CRC trails the body, so `peek` cannot
    /// detect payload corruption — the full `load` still guards that.
    pub fn peek(path: &Path) -> Result<BsrMeta> {
        let file_bytes = std::fs::metadata(path)
            .with_context(|| format!("probing BSR model {path:?}"))?
            .len();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening BSR model {path:?}"))?;
        // the fixed-size fields and the two name strings land well inside
        // 4 KiB (wire strings are length-prefixed and short); take() keeps
        // a multi-MB payload out of memory entirely
        let mut head = Vec::with_capacity(4096);
        f.by_ref().take(4096).read_to_end(&mut head)?;
        if head.len() < 12 || &head[..4] != MAGIC {
            bail!("not a BSRM block-sparse model");
        }
        let body = &head[4..];
        let mut off = 0usize;
        let version = wire::get_u32(body, &mut off).context("reading BSR model header")?;
        if version != VERSION {
            bail!("unsupported BSR model version {version}");
        }
        let spec = wire::get_str(body, &mut off)?;
        let method = wire::get_str(body, &mut off)?;
        let in_dim = wire::get_u32(body, &mut off)? as usize;
        let out_dim = wire::get_u32(body, &mut off)? as usize;
        let num_layers = wire::get_u32(body, &mut off)? as usize;
        Ok(BsrMeta { spec, method, in_dim, out_dim, num_layers, file_bytes })
    }

    /// Load and fully validate a [`BsrModel::save`] artifact. The CRC is
    /// checked before any parsing, so a corrupt file fails with the same
    /// loud guard as a corrupt checkpoint.
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening BSR model {path:?}"))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        if all.len() < 12 || &all[..4] != MAGIC {
            bail!("not a BSRM block-sparse model");
        }
        let body = &all[4..all.len() - 4];
        let stored_crc = u32::from_le_bytes(all[all.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("BSR model CRC mismatch (corrupt file)");
        }
        let mut off = 0usize;
        let version = wire::get_u32(body, &mut off).context("reading BSR model")?;
        if version != VERSION {
            bail!("unsupported BSR model version {version}");
        }
        let spec = wire::get_str(body, &mut off)?;
        let method = wire::get_str(body, &mut off)?;
        let in_dim = wire::get_u32(body, &mut off)? as usize;
        let out_dim = wire::get_u32(body, &mut off)? as usize;
        let num_layers = wire::get_u32(body, &mut off)? as usize;
        let mut layers = Vec::with_capacity(num_layers);
        for _ in 0..num_layers {
            let name = wire::get_str(body, &mut off)?;
            let m = wire::get_u32(body, &mut off)? as usize;
            let n = wire::get_u32(body, &mut off)? as usize;
            let m2 = wire::get_u32(body, &mut off)? as usize;
            let n2 = wire::get_u32(body, &mut off)? as usize;
            let nnz = wire::get_u32(body, &mut off)? as usize;
            if m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
                bail!("slot '{name}': block ({m2},{n2}) does not tile ({m},{n})");
            }
            let row_ptr = wire::get_u32s(body, &mut off, m / m2 + 1)?;
            let col_idx = wire::get_u32s(body, &mut off, nnz)?;
            let blocks = wire::get_f32s(body, &mut off, nnz * m2 * n2)?;
            layers.push(BsrLayer { name, m, n, m2, n2, row_ptr, col_idx, blocks });
        }
        if off != body.len() {
            bail!("BSR model has {} trailing bytes", body.len() - off);
        }
        let model = BsrModel { spec, method, in_dim, out_dim, layers };
        model.validate()?;
        Ok(model)
    }
}

/// Export a trained state to a packed BSR model: `materialize` every slot
/// to its (block-wise sparse) dense W, then pack at the spec's per-slot
/// block shape. Slots without a declared block shape (iterative pruning,
/// dense, pattern survivors) pack at 1×1 — element-level CSR. Transformer
/// specs export their q/k/v/o/FFN projection stack (the block-sparse
/// weights; embeddings, LayerNorm gains and the LM head are dense extras
/// that live in the training checkpoint, not in the BSR pack) — the stack
/// chains because fc2 emits d_model again, so `BsrModel::validate` holds.
pub fn export(be: &dyn Backend, state: &TrainState) -> Result<BsrModel> {
    let spec = be.spec(&state.spec)?;
    let ws = be.materialize(state)?;
    if ws.is_empty() {
        bail!("spec '{}' materialized no slots", spec.key);
    }
    let mut layers = Vec::with_capacity(ws.len());
    for (name, w) in &ws {
        if w.shape().len() != 2 {
            bail!("slot '{name}' materialized to shape {:?}, wants 2-D", w.shape());
        }
        let (m, n) = (w.shape()[0], w.shape()[1]);
        let (m2, n2) = spec.block_of(name).unwrap_or((1, 1));
        layers.push(BsrLayer::from_dense(name, w.data(), m, n, m2, n2)?);
    }
    let model = BsrModel {
        spec: spec.key.clone(),
        method: spec.method.clone(),
        in_dim: layers[0].n,
        out_dim: layers.last().unwrap().m,
        layers,
    };
    model.validate()?;
    Ok(model)
}

/// Synthetic block-sparse dense weights for the bench panels and tests:
/// random-normal values with exactly `round(occupancy · grid)` live
/// (m2×n2) blocks (clamped to ≥ 1), plus the matching (m1·n1) {0,1}
/// block mask. This is the single shared definition of what "X% block
/// sparsity" means across `perf_micro` and `infer_serve`.
pub fn synth_block_sparse_weights(
    rng: &mut Rng,
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
    occupancy: f64,
) -> (Vec<f32>, Vec<f32>) {
    assert!(m2 > 0 && n2 > 0 && m % m2 == 0 && n % n2 == 0,
            "block ({m2},{n2}) does not tile ({m},{n})");
    let (m1, n1) = (m / m2, n / n2);
    let total = m1 * n1;
    let k = ((occupancy * total as f64).round() as usize).clamp(1, total);
    let mut mask = vec![0.0f32; total];
    for i in rng.choose(total, k) {
        mask[i] = 1.0;
    }
    let mut w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
    for i in 0..m {
        for j in 0..n {
            if mask[(i / m2) * n1 + j / n2] == 0.0 {
                w[i * n + j] = 0.0;
            }
        }
    }
    (w, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_with_holes() -> (Vec<f32>, usize, usize) {
        // 4×6 matrix, 2×3 blocks: grid 2×2, zero out blocks (0,0) and (1,1)
        let (m, n) = (4usize, 6usize);
        let mut w = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let (i1, j1) = (i / 2, j / 3);
                if (i1, j1) == (0, 1) || (i1, j1) == (1, 0) {
                    w[i * n + j] = (1 + i * n + j) as f32;
                }
            }
        }
        (w, m, n)
    }

    #[test]
    fn from_dense_packs_only_occupied_blocks() {
        let (w, m, n) = dense_with_holes();
        let l = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        l.validate().unwrap();
        assert_eq!(l.nnz_blocks(), 2);
        assert_eq!(l.row_ptr, vec![0, 1, 2]);
        assert_eq!(l.col_idx, vec![1, 0]);
        assert!((l.occupancy() - 0.5).abs() < 1e-12);
        assert!((l.block_sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(l.nnz_params(), 12);
        // round trip through the dense reconstruction is exact
        assert_eq!(l.to_dense(), w);
    }

    #[test]
    fn from_dense_rejects_bad_shapes() {
        let (w, m, n) = dense_with_holes();
        assert!(BsrLayer::from_dense("fc", &w, m, n, 3, 3).is_err());
        assert!(BsrLayer::from_dense("fc", &w, m, n, 2, 4).is_err());
        assert!(BsrLayer::from_dense("fc", &w, m, n, 0, 3).is_err());
        assert!(BsrLayer::from_dense("fc", &w[1..], m, n, 2, 3).is_err());
    }

    #[test]
    fn flops_scale_with_occupancy() {
        let (w, m, n) = dense_with_holes();
        let l = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        assert_eq!(l.infer_flops(), 2 * 2 * 3 * 2); // 2 blocks of 2×3
        assert_eq!(l.dense_flops(), 2 * l.infer_flops()); // 50% occupancy
        // all-zero slot: zero blocks, zero inference cost
        let zeros = vec![0.0; m * n];
        let z = BsrLayer::from_dense("z", &zeros, m, n, 2, 3).unwrap();
        assert_eq!(z.nnz_blocks(), 0);
        assert_eq!(z.infer_flops(), 0);
        assert!((z.block_sparsity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_structural_corruption() {
        let (w, m, n) = dense_with_holes();
        let good = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        let mut bad = good.clone();
        bad.col_idx[0] = 7; // out of the 2-wide grid
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.row_ptr[1] = 3; // beyond col_idx
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.blocks.pop();
        assert!(bad.validate().is_err());
        let mut bad = good;
        bad.row_ptr = vec![0, 2];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn model_validate_checks_the_chain() {
        let (w1, w2) = (vec![1.0; 6 * 8], vec![1.0; 4 * 6]);
        let l1 = BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap();
        let l2 = BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap();
        let ok = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![l1.clone(), l2.clone()],
        };
        ok.validate().unwrap();
        assert_eq!(ok.nnz_params(), 6 * 8 + 4 * 6);
        let broken = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![l2, l1], // 4×6 then 6×8: chain mismatch
        };
        assert!(broken.validate().is_err());
        let empty = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![],
        };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn synth_weights_hit_exact_occupancy() {
        let mut rng = Rng::new(17);
        let (w, mask) = synth_block_sparse_weights(&mut rng, 12, 20, 3, 4, 0.25);
        // grid 4×5 = 20 blocks → exactly 5 live
        assert_eq!(mask.iter().filter(|&&v| v == 1.0).count(), 5);
        let l = BsrLayer::from_dense("fc", &w, 12, 20, 3, 4).unwrap();
        assert!((l.occupancy() - 0.25).abs() < 1e-12);
        // the packed structure matches the mask, block for block
        let (_, n1) = l.grid();
        for (blk, &mv) in mask.iter().enumerate() {
            let (i1, j1) = (blk / n1, blk % n1);
            let stored = l.col_idx[l.row_ptr[i1] as usize..l.row_ptr[i1 + 1] as usize]
                .contains(&(j1 as u32));
            assert_eq!(stored, mv == 1.0, "block ({i1},{j1})");
        }
        // occupancy 0 still keeps one block (benches never hit div-by-zero)
        let (_, mask0) = synth_block_sparse_weights(&mut rng, 12, 20, 3, 4, 0.0);
        assert_eq!(mask0.iter().filter(|&&v| v == 1.0).count(), 1);
    }

    #[test]
    fn save_load_round_trip_and_crc_guard() {
        let (w, m, n) = dense_with_holes();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "kpd".into(),
            in_dim: n,
            out_dim: m,
            layers: vec![BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap()],
        };
        let dir = std::env::temp_dir().join("bs_bsrm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let back = BsrModel::load(&path).unwrap();
        assert_eq!(back, model);
        // flip one body byte: the load must fail at the CRC guard — the
        // same corruption contract as the checkpoint container
        let clean = std::fs::read(&path).unwrap();
        let mut bytes = clean.clone();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = BsrModel::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "wanted CRC error, got: {err:#}");
        // truncation is caught too (CRC over a shorter body cannot match)
        std::fs::write(&path, &clean[..clean.len() - 9]).unwrap();
        assert!(BsrModel::load(&path).is_err());
        // wrong magic
        let mut bytes = clean;
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = BsrModel::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("not a BSRM"), "{err:#}");
    }

    #[test]
    fn save_publishes_atomically_over_an_existing_artifact() {
        let (w, m, n) = dense_with_holes();
        let mk = |spec: &str| BsrModel {
            spec: spec.into(),
            method: "kpd".into(),
            in_dim: n,
            out_dim: m,
            layers: vec![BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap()],
        };
        let dir = std::env::temp_dir().join("bs_bsrm_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        mk("old").save(&path).unwrap();
        mk("new").save(&path).unwrap(); // overwrite via temp + rename
        assert_eq!(BsrModel::load(&path).unwrap().spec, "new");
        // no temp litter survives a successful publish
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "leftover temp files: {stray:?}");
    }

    #[test]
    fn peek_reads_header_without_payload() {
        let (w, m, n) = dense_with_holes();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "group_lasso".into(),
            in_dim: n,
            out_dim: m,
            layers: vec![BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap()],
        };
        let dir = std::env::temp_dir().join("bs_bsrm_peek_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let meta = BsrModel::peek(&path).unwrap();
        assert_eq!(meta.spec, "tiny");
        assert_eq!(meta.method, "group_lasso");
        assert_eq!(meta.in_dim, n);
        assert_eq!(meta.out_dim, m);
        assert_eq!(meta.num_layers, 1);
        assert_eq!(meta.file_bytes, std::fs::metadata(&path).unwrap().len());
        // peek shares the magic guard with load
        std::fs::write(&path, b"XXXX12345678").unwrap();
        assert!(BsrModel::peek(&path).is_err());
    }
}
