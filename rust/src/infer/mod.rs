//! Block-sparse inference: the train→export→serve half of the paper's
//! story. Training (PRs 1–3) produces block-wise sparse weights; this
//! subsystem makes the §4 claim — "decreased memory and computation costs
//! during inference" — executable:
//!
//! * **export** ([`export`]): `Backend::materialize` any trained spec
//!   (kpd / group_lasso / elastic_gl / rigl_block / iter_prune / dense,
//!   single- or multi-layer) and pack every slot into BSR
//!   (block-sparse-row) form — only the blocks that survived training are
//!   stored, so the artifact's memory *is* the occupancy.
//! * **format** ([`BsrModel::save`] / [`BsrModel::load`]): a versioned
//!   little-endian container (`"BSRM"`). Version 2 (current) is an
//!   **aligned** layout — a fixed 40-byte prologue, a CRC-guarded
//!   wire-framed header holding per-layer metadata plus payload-relative
//!   array offsets, and an 8-byte-aligned payload holding the bulk
//!   arrays, each independently 8-aligned. That layout is what lets
//!   [`mmap::open_model_mmap`] map an artifact and serve block data
//!   zero-copy (start-up cost O(header + index), not O(file)). Version 1
//!   (the PR-4 body+trailing-CRC frame) still loads via the read path.
//!   `save` publishes atomically (write a temp sibling, fsync, rename) —
//!   a reader or hot-swap watcher never observes a torn artifact — and
//!   [`BsrModel::peek`] probes a file's header ([`BsrMeta`], now carrying
//!   the container version and dtype) in O(header) without the payload.
//! * **kernels** ([`bsr`], [`quant`]): gather-free block-GEMM forward over
//!   the stored blocks only (plus a ReLU-fused variant), in f32 or
//!   per-block-row symmetric int8 (f32 accumulate) — inference cost
//!   scales with occupancy, not the dense shape, and the int8 path moves
//!   4× less block memory.
//! * **engine** ([`engine`]): a multi-threaded serving engine with
//!   **bounded admission**, a completion-slot async request path
//!   ([`engine::Engine::predict_async`] — N in-flight clients cost N
//!   queue slots, not N parked OS threads), dynamic micro-batching over
//!   `util::pool::ThreadPool`, root-cause error propagation and atomic
//!   model hot-swap. It serves any [`ServedModel`] — f32 or int8.
//! * **registry** ([`registry`]): named multi-model serving — deploy /
//!   hot-swap / undeploy engines by model name, from memory or disk
//!   (dtype resolved automatically via [`load_auto`]).
//!
//! `blocksparse export` / `blocksparse infer` drive this from the CLI;
//! `benches/infer_serve.rs` measures the dense-vs-BSR speedup, serving
//! latency (blocking and async), overload shed behaviour, hot-swap cost
//! and the int8-vs-f32 panel into `BENCH_infer.json`.

pub mod bsr;
pub mod engine;
pub mod mmap;
pub mod quant;
pub mod registry;

use std::io::{Read, Write};
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{Backend, TrainState};
use crate::checkpoint::{crc32, wire};
use crate::flops::block_sparse_infer_flops;
use crate::util::rng::Rng;

const MAGIC: &[u8; 4] = b"BSRM";
/// Container version 1: `"BSRM" | wire body | crc32(body)` (PR 4).
const VERSION_V1: u32 = 1;
/// Container version 2: aligned prologue/header/payload (this file's
/// layout comment on [`write_container`]). What [`BsrModel::save`] writes.
const VERSION_V2: u32 = 2;
/// Byte length of the fixed v2 prologue.
pub(crate) const PROLOGUE_LEN: usize = 40;
/// v2 dtype code: payload blocks are little-endian f32.
pub const DTYPE_F32: u32 = 0;
/// v2 dtype code: payload blocks are int8 with per-block-row f32 scales.
pub const DTYPE_INT8: u32 = 1;

/// Stable label for a dtype code ("f32" / "int8").
pub(crate) fn dtype_label(code: u32) -> &'static str {
    if code == DTYPE_INT8 {
        "int8"
    } else {
        "f32"
    }
}

// --------------------------------------------------------------- BlockStore

/// Backing storage for a layer's bulk f32 array (packed blocks, or the
/// int8 path's scales): either owned heap memory (`load`, `from_dense`)
/// or a zero-copy window into an mmap'd artifact (`open_mmap`). Derefs to
/// `&[f32]`, so every kernel reads it exactly like the `Vec<f32>` it
/// replaced; `Clone` is cheap for the mapped variant (an `Arc` bump, not
/// a payload copy), which is what keeps hot-swap and registry deploys
/// O(1) for mmap-backed models.
#[derive(Clone)]
pub enum BlockStore {
    Owned(Vec<f32>),
    /// `off`/`len` were bounds- and alignment-checked against the region
    /// when the store was built — the accessor does no per-read checks.
    Mapped {
        region: Arc<mmap::MmapRegion>,
        /// byte offset into the region (8-aligned)
        off: usize,
        /// element count
        len: usize,
    },
}

impl BlockStore {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            BlockStore::Owned(v) => v,
            BlockStore::Mapped { region, off, len } => region.f32s(*off, *len),
        }
    }

    pub fn is_mapped(&self) -> bool {
        matches!(self, BlockStore::Mapped { .. })
    }

    /// Mutable access, copying a mapped store to owned memory first
    /// (copy-on-write). Tests corrupt layers through this; the serving
    /// path never writes blocks.
    pub fn to_mut(&mut self) -> &mut Vec<f32> {
        if self.is_mapped() {
            *self = BlockStore::Owned(self.as_slice().to_vec());
        }
        match self {
            BlockStore::Owned(v) => v,
            BlockStore::Mapped { .. } => unreachable!("to_mut just copied to Owned"),
        }
    }
}

impl Deref for BlockStore {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Vec<f32>> for BlockStore {
    fn from(v: Vec<f32>) -> Self {
        BlockStore::Owned(v)
    }
}

impl PartialEq for BlockStore {
    /// Value equality — an owned store and a mapped store holding the
    /// same bits compare equal (what the mmap bit-identity tests assert).
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for BlockStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "owned" };
        write!(f, "BlockStore<{kind}, {} f32>", self.len())
    }
}

/// One linear slot in packed block-sparse-row form: Z = X·Wᵀ where only
/// the occupied (m2×n2) blocks of W are stored. `row_ptr`/`col_idx` are
/// the CSR-style index arrays over the (m1×n1) block grid; `blocks` holds
/// each stored block row-major, in `col_idx` order, so the forward kernel
/// streams them contiguously with no gather.
#[derive(Clone, Debug, PartialEq)]
pub struct BsrLayer {
    /// slot name (`fc`, `fc1`, ...) — matches the training spec's slots
    pub name: String,
    /// output features m = m1·m2
    pub m: usize,
    /// input features n = n1·n2
    pub n: usize,
    /// block rows
    pub m2: usize,
    /// block cols
    pub n2: usize,
    /// per-block-row offsets into `col_idx`/`blocks` (length m1 + 1)
    pub row_ptr: Vec<u32>,
    /// block-column index j1 of every stored block, sorted within each row
    pub col_idx: Vec<u32>,
    /// packed (m2×n2) blocks in `col_idx` order (length nnz·m2·n2) —
    /// owned after `load`, zero-copy after `open_mmap`
    pub blocks: BlockStore,
}

impl BsrLayer {
    /// Pack a dense row-major (m×n) weight matrix. A block is stored iff
    /// it has any non-zero entry — the training paths produce *exact*
    /// zeros (ℓ1/group prox, RigL masks, pruning masks), so no threshold
    /// is needed and packing is lossless.
    pub fn from_dense(
        name: &str,
        w: &[f32],
        m: usize,
        n: usize,
        m2: usize,
        n2: usize,
    ) -> Result<Self> {
        if m == 0 || n == 0 || m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
            bail!("block ({m2},{n2}) does not tile ({m},{n})");
        }
        if w.len() != m * n {
            bail!("slot '{name}': dense weight has {} values, wants {}", w.len(), m * n);
        }
        let (m1, n1) = (m / m2, n / n2);
        let mut row_ptr = Vec::with_capacity(m1 + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::new();
        let mut blocks = Vec::new();
        for i1 in 0..m1 {
            for j1 in 0..n1 {
                let occupied = (0..m2).any(|i2| {
                    let off = (i1 * m2 + i2) * n + j1 * n2;
                    w[off..off + n2].iter().any(|&v| v != 0.0)
                });
                if !occupied {
                    continue;
                }
                col_idx.push(j1 as u32);
                for i2 in 0..m2 {
                    let off = (i1 * m2 + i2) * n + j1 * n2;
                    blocks.extend_from_slice(&w[off..off + n2]);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Ok(Self { name: name.to_string(), m, n, m2, n2, row_ptr, col_idx, blocks: blocks.into() })
    }

    /// (m1, n1) block-grid shape.
    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.m2, self.n / self.n2)
    }

    pub fn nnz_blocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Fraction of grid blocks stored (1.0 = fully dense).
    pub fn occupancy(&self) -> f64 {
        let (m1, n1) = self.grid();
        self.nnz_blocks() as f64 / (m1 * n1) as f64
    }

    /// Block sparsity rate = 1 − occupancy (the tables' convention).
    pub fn block_sparsity(&self) -> f64 {
        1.0 - self.occupancy()
    }

    /// Stored weight values (the artifact's parameter memory).
    pub fn nnz_params(&self) -> u64 {
        self.blocks.len() as u64
    }

    /// Inference FLOPs for one example over the stored blocks only
    /// (the §4 claim: 2·m2·n2 per occupied block).
    pub fn infer_flops(&self) -> u64 {
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, self.nnz_blocks() as u64)
    }

    /// Inference FLOPs of the equivalent dense slot.
    pub fn dense_flops(&self) -> u64 {
        let (m1, n1) = self.grid();
        block_sparse_infer_flops(1, self.m2 as u64, self.n2 as u64, (m1 * n1) as u64)
    }

    /// Dense row-major reconstruction (tests / debugging).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.m * self.n];
        let (m1, _) = self.grid();
        for i1 in 0..m1 {
            let (lo, hi) = (self.row_ptr[i1] as usize, self.row_ptr[i1 + 1] as usize);
            for k in lo..hi {
                let j1 = self.col_idx[k] as usize;
                let blk = &self.blocks[k * self.m2 * self.n2..(k + 1) * self.m2 * self.n2];
                for i2 in 0..self.m2 {
                    let off = (i1 * self.m2 + i2) * self.n + j1 * self.n2;
                    w[off..off + self.n2]
                        .copy_from_slice(&blk[i2 * self.n2..(i2 + 1) * self.n2]);
                }
            }
        }
        w
    }

    /// Structural invariants the forward kernel indexes by without checks.
    pub fn validate(&self) -> Result<()> {
        if self.m == 0 || self.n == 0 || self.m2 == 0 || self.n2 == 0 {
            bail!("slot '{}': zero dimension", self.name);
        }
        if self.m % self.m2 != 0 || self.n % self.n2 != 0 {
            bail!(
                "slot '{}': block ({},{}) does not tile ({},{})",
                self.name, self.m2, self.n2, self.m, self.n
            );
        }
        let (m1, n1) = self.grid();
        if self.row_ptr.len() != m1 + 1 {
            bail!("slot '{}': row_ptr has {} entries, wants {}", self.name,
                  self.row_ptr.len(), m1 + 1);
        }
        if self.row_ptr[0] != 0 || self.row_ptr[m1] as usize != self.col_idx.len() {
            bail!("slot '{}': row_ptr does not bracket col_idx", self.name);
        }
        for i1 in 0..m1 {
            let (lo, hi) = (self.row_ptr[i1] as usize, self.row_ptr[i1 + 1] as usize);
            if lo > hi || hi > self.col_idx.len() {
                bail!("slot '{}': row_ptr not monotone at block-row {i1}", self.name);
            }
            let row = &self.col_idx[lo..hi];
            for (k, &j1) in row.iter().enumerate() {
                if j1 as usize >= n1 {
                    bail!("slot '{}': block column {j1} out of grid ({n1})", self.name);
                }
                if k > 0 && row[k - 1] >= j1 {
                    bail!("slot '{}': block columns not strictly increasing in row {i1}",
                          self.name);
                }
            }
        }
        if self.blocks.len() != self.col_idx.len() * self.m2 * self.n2 {
            bail!("slot '{}': {} block values, wants {}", self.name,
                  self.blocks.len(), self.col_idx.len() * self.m2 * self.n2);
        }
        Ok(())
    }
}

/// A packed block-sparse model artifact: the sequential slot stack of one
/// trained spec (ReLU between consecutive slots, none after the logits),
/// with per-layer occupancy and FLOPs/params accounting baked in.
#[derive(Clone, Debug, PartialEq)]
pub struct BsrModel {
    /// spec key the artifact was exported from
    pub spec: String,
    /// training method (kpd / group_lasso / rigl_block / ...)
    pub method: String,
    /// input features of the first slot
    pub in_dim: usize,
    /// logit classes of the last slot
    pub out_dim: usize,
    pub layers: Vec<BsrLayer>,
}

/// Header metadata of a saved artifact, from [`BsrModel::peek`]: enough
/// to route/validate a deployment (shape fit, layer count, dtype,
/// artifact size) without loading the block payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BsrMeta {
    pub spec: String,
    pub method: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub num_layers: usize,
    /// container version on disk (1 = legacy frame, 2 = aligned layout)
    pub version: u32,
    /// block payload dtype: "f32" or "int8"
    pub dtype: String,
    /// total artifact size on disk
    pub file_bytes: u64,
}

// ------------------------------------------------------- v2 container core
//
// Byte layout of a version-2 artifact (all integers little-endian):
//
//   off  0  "BSRM"                      magic
//   off  4  u32 version = 2             (same position as v1's wire version)
//   off  8  u32 header_len              wire-framed header byte length
//   off 12  u32 header_crc              crc32 over the header bytes
//   off 16  u64 payload_off             8-aligned start of the payload
//   off 24  u64 payload_len             payload byte length (EOF is exactly
//                                       payload_off + payload_len)
//   off 32  u32 payload_crc             crc32 over the payload bytes
//   off 36  u32 dtype                   DTYPE_F32 | DTYPE_INT8
//   off 40  header                      spec, method, dims, per-layer
//                                       metadata + payload-relative u64
//                                       array offsets (lengths are derived
//                                       from the layer shape, never trusted
//                                       from the file)
//   ...     zero padding to payload_off
//   payload_off  bulk arrays, each 8-aligned within the payload
//
// The header CRC covers every byte the loader *interprets*; the payload
// CRC covers every byte the kernels *read*. The read path verifies both;
// the mmap path verifies the header CRC only (touching the payload would
// defeat the zero-copy point — the read path remains the integrity
// checker of record, and `peek`'s docs carry the same caveat for v1).

/// Parsed v2 prologue (the fixed 40 bytes).
pub(crate) struct Prologue {
    pub header_len: usize,
    pub header_crc: u32,
    pub payload_off: u64,
    pub payload_len: u64,
    pub payload_crc: u32,
    pub dtype: u32,
}

pub(crate) fn read_prologue(bytes: &[u8]) -> Result<Prologue> {
    if bytes.len() < PROLOGUE_LEN || &bytes[..4] != MAGIC {
        bail!("not a BSRM block-sparse model");
    }
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = u32_at(4);
    if version != VERSION_V2 {
        bail!("unsupported BSR model version {version}");
    }
    let p = Prologue {
        header_len: u32_at(8) as usize,
        header_crc: u32_at(12),
        payload_off: u64_at(16),
        payload_len: u64_at(24),
        payload_crc: u32_at(32),
        dtype: u32_at(36),
    };
    if p.dtype != DTYPE_F32 && p.dtype != DTYPE_INT8 {
        bail!("unsupported BSRM dtype code {}", p.dtype);
    }
    if p.payload_off % 8 != 0 {
        bail!("BSRM payload offset {} is not 8-byte aligned", p.payload_off);
    }
    if p.payload_off < (PROLOGUE_LEN + p.header_len) as u64 {
        bail!("BSRM payload overlaps the header");
    }
    Ok(p)
}

/// One layer's header record: shape + payload-relative array offsets.
/// Array *lengths* are always derived from (m, m2, n2, nnz) — a corrupt
/// length field cannot exist, and a corrupt offset is caught by the
/// bounds check in [`span`] before any allocation.
pub(crate) struct LayerHeader {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub m2: usize,
    pub n2: usize,
    pub nnz: usize,
    pub row_ptr_off: u64,
    pub col_idx_off: u64,
    pub blocks_off: u64,
    /// int8 artifacts only (0 and unused for f32)
    pub scales_off: u64,
}

impl LayerHeader {
    /// nnz·m2·n2 with overflow guarded (header fields are attacker- /
    /// corruption-controlled until the CRC is checked — and the fuzz
    /// suite feeds this path unchecked combinations on purpose).
    pub fn block_values(&self) -> Result<u64> {
        (self.nnz as u64)
            .checked_mul(self.m2 as u64)
            .and_then(|v| v.checked_mul(self.n2 as u64))
            .ok_or_else(|| anyhow!("slot '{}': block value count overflows", self.name))
    }
}

pub(crate) struct HeaderV2 {
    pub spec: String,
    pub method: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub layers: Vec<LayerHeader>,
}

pub(crate) fn parse_header_v2(h: &[u8], dtype: u32) -> Result<HeaderV2> {
    let mut off = 0usize;
    let spec = wire::get_str(h, &mut off).context("reading BSRM header")?;
    let method = wire::get_str(h, &mut off)?;
    let in_dim = wire::get_u32(h, &mut off)? as usize;
    let out_dim = wire::get_u32(h, &mut off)? as usize;
    let num_layers = wire::get_u32(h, &mut off)? as usize;
    // no with_capacity(num_layers): the count is untrusted until the
    // records behind it actually parse
    let mut layers = Vec::new();
    for _ in 0..num_layers {
        let name = wire::get_str(h, &mut off)?;
        let m = wire::get_u32(h, &mut off)? as usize;
        let n = wire::get_u32(h, &mut off)? as usize;
        let m2 = wire::get_u32(h, &mut off)? as usize;
        let n2 = wire::get_u32(h, &mut off)? as usize;
        let nnz = wire::get_u32(h, &mut off)? as usize;
        if m == 0 || n == 0 || m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
            bail!("slot '{name}': block ({m2},{n2}) does not tile ({m},{n})");
        }
        let row_ptr_off = wire::get_u64(h, &mut off)?;
        let col_idx_off = wire::get_u64(h, &mut off)?;
        let blocks_off = wire::get_u64(h, &mut off)?;
        let scales_off = if dtype == DTYPE_INT8 { wire::get_u64(h, &mut off)? } else { 0 };
        layers.push(LayerHeader {
            name, m, n, m2, n2, nnz, row_ptr_off, col_idx_off, blocks_off, scales_off,
        });
    }
    if off != h.len() {
        bail!("BSRM header has {} trailing bytes", h.len() - off);
    }
    Ok(HeaderV2 { spec, method, in_dim, out_dim, layers })
}

/// Bounds/alignment check one payload array before anything is allocated
/// or read: returns the (byte offset, byte length) of `count` elements of
/// `elem` bytes at payload-relative `off`. Every failure mode of a
/// corrupt offset or an absurd derived count lands here as a typed error.
pub(crate) fn span(
    payload_len: usize,
    off: u64,
    elem: u64,
    count: u64,
    what: &str,
) -> Result<(usize, usize)> {
    if off % 8 != 0 {
        bail!("BSRM array '{what}' at misaligned offset {off}");
    }
    let bytes = count
        .checked_mul(elem)
        .ok_or_else(|| anyhow!("BSRM array '{what}' byte size overflows"))?;
    let end = off
        .checked_add(bytes)
        .ok_or_else(|| anyhow!("BSRM array '{what}' extent overflows"))?;
    if end > payload_len as u64 {
        bail!("BSRM array '{what}' runs past the payload ({end} > {payload_len} bytes)");
    }
    Ok((off as usize, bytes as usize))
}

pub(crate) fn take_u32s(payload: &[u8], off: u64, count: u64, what: &str) -> Result<Vec<u32>> {
    let (o, b) = span(payload.len(), off, 4, count, what)?;
    Ok(payload[o..o + b]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub(crate) fn take_f32s(payload: &[u8], off: u64, count: u64, what: &str) -> Result<Vec<f32>> {
    let (o, b) = span(payload.len(), off, 4, count, what)?;
    Ok(payload[o..o + b]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub(crate) fn take_i8s(payload: &[u8], off: u64, count: u64, what: &str) -> Result<Vec<i8>> {
    let (o, b) = span(payload.len(), off, 1, count, what)?;
    Ok(payload[o..o + b].iter().map(|&v| v as i8).collect())
}

/// A fully-checked view of a v2 container over in-memory (or mapped)
/// bytes. `verify_payload = false` is the mmap fast path: prologue,
/// header CRC, padding and extents are still verified — only the
/// payload-wide CRC sweep (which would touch every page) is skipped.
pub(crate) struct ContainerV2<'a> {
    pub prologue: Prologue,
    pub header: HeaderV2,
    pub payload: &'a [u8],
}

pub(crate) fn open_v2_bytes(all: &[u8], verify_payload: bool) -> Result<ContainerV2<'_>> {
    let prologue = read_prologue(all)?;
    let end = prologue
        .payload_off
        .checked_add(prologue.payload_len)
        .ok_or_else(|| anyhow!("BSRM payload extent overflows"))?;
    if end != all.len() as u64 {
        bail!("BSRM extents say {end} bytes, file has {}", all.len());
    }
    // past here payload_off/header_end fit in usize: both ≤ all.len()
    let header_end = PROLOGUE_LEN + prologue.header_len;
    let header_bytes = &all[PROLOGUE_LEN..header_end];
    if crc32(header_bytes) != prologue.header_crc {
        bail!("BSRM header CRC mismatch (corrupt file)");
    }
    if all[header_end..prologue.payload_off as usize].iter().any(|&b| b != 0) {
        bail!("BSRM header padding corrupt");
    }
    let payload = &all[prologue.payload_off as usize..];
    if verify_payload && crc32(payload) != prologue.payload_crc {
        bail!("BSRM payload CRC mismatch (corrupt file)");
    }
    let header = parse_header_v2(header_bytes, prologue.dtype)?;
    Ok(ContainerV2 { prologue, header, payload })
}

/// Incrementally lay out the v2 payload: every array is zero-padded to an
/// 8-byte boundary before being appended, and the returned offset is
/// payload-relative — exactly what the header records store.
pub(crate) struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    fn align8(&mut self) -> u64 {
        while self.buf.len() % 8 != 0 {
            self.buf.push(0);
        }
        self.buf.len() as u64
    }

    pub fn put_u32s(&mut self, v: &[u32]) -> u64 {
        let off = self.align8();
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        off
    }

    pub fn put_f32s(&mut self, v: &[f32]) -> u64 {
        let off = self.align8();
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        off
    }

    pub fn put_i8s(&mut self, v: &[i8]) -> u64 {
        let off = self.align8();
        self.buf.extend(v.iter().map(|&x| x as u8));
        off
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Atomically publish `parts` (concatenated) at `path`: write a temp
/// sibling, fsync, rename. A concurrent reader — a hot-swap watcher
/// re-loading the same path mid-save — sees either the old complete file
/// or the new complete file, never a torn prefix; this is the on-disk
/// half of the engine's in-memory `Arc` swap.
pub(crate) fn atomic_publish(path: &Path, parts: &[&[u8]]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // pid + process-wide counter keep concurrent savers (even of the
    // same destination) on distinct temp files; the dot prefix keeps
    // half-written temps out of naive directory globs
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let file_name = path
        .file_name()
        .and_then(|s| s.to_str())
        .unwrap_or("model.bsm");
    let tmp = path.with_file_name(format!(
        ".{file_name}.{}.{seq}.tmp",
        std::process::id()
    ));
    let publish = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating BSR model temp {tmp:?}"))?;
        for p in parts {
            f.write_all(p)?;
        }
        // the rename only publishes bytes that are durably on disk
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing BSR model {path:?}"))?;
        Ok(())
    })();
    if publish.is_err() {
        // a failed publish leaves no temp litter; `path` still holds
        // whatever complete artifact it held before
        let _ = std::fs::remove_file(&tmp);
    }
    publish
}

/// Assemble and atomically publish a v2 container from a wire-framed
/// header and a [`PayloadWriter`]-laid payload.
pub(crate) fn write_container(
    path: &Path,
    dtype: u32,
    header: &[u8],
    payload: &[u8],
) -> Result<()> {
    if header.len() > u32::MAX as usize {
        bail!("BSRM header of {} bytes exceeds the u32 frame", header.len());
    }
    let header_end = PROLOGUE_LEN + header.len();
    let payload_off = header_end.div_ceil(8) * 8;
    let mut pre = Vec::with_capacity(payload_off);
    pre.extend_from_slice(MAGIC);
    pre.extend_from_slice(&VERSION_V2.to_le_bytes());
    pre.extend_from_slice(&(header.len() as u32).to_le_bytes());
    pre.extend_from_slice(&crc32(header).to_le_bytes());
    pre.extend_from_slice(&(payload_off as u64).to_le_bytes());
    pre.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    pre.extend_from_slice(&crc32(payload).to_le_bytes());
    pre.extend_from_slice(&dtype.to_le_bytes());
    pre.extend_from_slice(header);
    pre.resize(payload_off, 0);
    atomic_publish(path, &[&pre, payload])
}

impl BsrModel {
    /// Inference FLOPs for one example over the whole stack.
    pub fn infer_flops_per_example(&self) -> u64 {
        self.layers.iter().map(BsrLayer::infer_flops).sum()
    }

    /// Dense-equivalent inference FLOPs for one example.
    pub fn dense_flops_per_example(&self) -> u64 {
        self.layers.iter().map(BsrLayer::dense_flops).sum()
    }

    /// Stored weight values across all layers.
    pub fn nnz_params(&self) -> u64 {
        self.layers.iter().map(BsrLayer::nnz_params).sum()
    }

    /// Whole-model block sparsity, weighted by dense slot size (the same
    /// Σ zeros / Σ entries convention as `sparsity::aggregate`).
    pub fn block_sparsity(&self) -> f64 {
        crate::sparsity::aggregate(
            &self
                .layers
                .iter()
                .map(|l| (l.block_sparsity(), l.m * l.n))
                .collect::<Vec<_>>(),
        )
    }

    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("BSR model '{}' has no layers", self.spec);
        }
        for l in &self.layers {
            l.validate()?;
        }
        if self.layers[0].n != self.in_dim {
            bail!("first slot wants {} inputs, model says {}", self.layers[0].n, self.in_dim);
        }
        let last = self.layers.last().unwrap();
        if last.m != self.out_dim {
            bail!("last slot emits {} features, model says {}", last.m, self.out_dim);
        }
        for w in self.layers.windows(2) {
            if w[0].m != w[1].n {
                bail!(
                    "slot '{}' wants {} inputs but '{}' emits {}",
                    w[1].name, w[1].n, w[0].name, w[0].m
                );
            }
        }
        Ok(())
    }

    /// Serialize to the current (version-2, aligned) container and
    /// publish atomically — see [`write_container`] for the layout and
    /// [`atomic_publish`] for the torn-artifact guarantee. The aligned
    /// layout is what makes the artifact [`mmap`]-servable.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        let mut pw = PayloadWriter::new();
        let mut header = Vec::new();
        wire::put_str(&mut header, &self.spec);
        wire::put_str(&mut header, &self.method);
        wire::put_u32(&mut header, self.in_dim as u32);
        wire::put_u32(&mut header, self.out_dim as u32);
        wire::put_u32(&mut header, self.layers.len() as u32);
        for l in &self.layers {
            wire::put_str(&mut header, &l.name);
            wire::put_u32(&mut header, l.m as u32);
            wire::put_u32(&mut header, l.n as u32);
            wire::put_u32(&mut header, l.m2 as u32);
            wire::put_u32(&mut header, l.n2 as u32);
            wire::put_u32(&mut header, l.col_idx.len() as u32);
            wire::put_u64(&mut header, pw.put_u32s(&l.row_ptr));
            wire::put_u64(&mut header, pw.put_u32s(&l.col_idx));
            wire::put_u64(&mut header, pw.put_f32s(&l.blocks));
        }
        write_container(path, DTYPE_F32, &header, &pw.finish())
    }

    /// Serialize in the **legacy version-1** frame (`"BSRM"` | wire body |
    /// crc32(body)). Kept so the corruption suite and old-artifact
    /// compatibility tests can mint v1 files; [`BsrModel::load`] reads
    /// both versions, new artifacts are always written v2.
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        self.validate()?;
        let mut body = Vec::new();
        wire::put_u32(&mut body, VERSION_V1);
        wire::put_str(&mut body, &self.spec);
        wire::put_str(&mut body, &self.method);
        wire::put_u32(&mut body, self.in_dim as u32);
        wire::put_u32(&mut body, self.out_dim as u32);
        wire::put_u32(&mut body, self.layers.len() as u32);
        for l in &self.layers {
            wire::put_str(&mut body, &l.name);
            wire::put_u32(&mut body, l.m as u32);
            wire::put_u32(&mut body, l.n as u32);
            wire::put_u32(&mut body, l.m2 as u32);
            wire::put_u32(&mut body, l.n2 as u32);
            wire::put_u32(&mut body, l.col_idx.len() as u32);
            wire::put_u32s(&mut body, &l.row_ptr);
            wire::put_u32s(&mut body, &l.col_idx);
            wire::put_f32s(&mut body, &l.blocks);
        }
        let crc = crc32(&body);
        atomic_publish(path, &[MAGIC, &body, &crc.to_le_bytes()])
    }

    /// Probe a saved artifact's header without reading (or CRC-checking)
    /// the block payload: O(header) work no matter how large the model
    /// is. This is what a registry or startup scan uses to answer "what
    /// is this file and does it fit my engine?" before paying for
    /// [`BsrModel::load`]. Payload corruption is not detectable here —
    /// the full `load` still guards that.
    pub fn peek(path: &Path) -> Result<BsrMeta> {
        let file_bytes = std::fs::metadata(path)
            .with_context(|| format!("probing BSR model {path:?}"))?
            .len();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening BSR model {path:?}"))?;
        // the fixed-size fields and the two name strings land well inside
        // 4 KiB (wire strings are length-prefixed and short); take() keeps
        // a multi-MB payload out of memory entirely
        let mut head = Vec::with_capacity(4096);
        f.by_ref().take(4096).read_to_end(&mut head)?;
        if head.len() < 12 || &head[..4] != MAGIC {
            bail!("not a BSRM block-sparse model");
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        match version {
            VERSION_V1 => {
                let body = &head[4..];
                let mut off = 4usize; // past the wire version field
                let spec = wire::get_str(body, &mut off).context("reading BSR model header")?;
                let method = wire::get_str(body, &mut off)?;
                let in_dim = wire::get_u32(body, &mut off)? as usize;
                let out_dim = wire::get_u32(body, &mut off)? as usize;
                let num_layers = wire::get_u32(body, &mut off)? as usize;
                Ok(BsrMeta {
                    spec, method, in_dim, out_dim, num_layers,
                    version, dtype: "f32".into(), file_bytes,
                })
            }
            VERSION_V2 => {
                let p = read_prologue(&head)?;
                // the top-level header fields sit at the front of the
                // header frame — O(header) stays true even when the
                // per-layer records run past the probe window
                let h = &head[PROLOGUE_LEN..head.len().min(PROLOGUE_LEN + p.header_len)];
                let mut off = 0usize;
                let spec = wire::get_str(h, &mut off).context("reading BSRM header")?;
                let method = wire::get_str(h, &mut off)?;
                let in_dim = wire::get_u32(h, &mut off)? as usize;
                let out_dim = wire::get_u32(h, &mut off)? as usize;
                let num_layers = wire::get_u32(h, &mut off)? as usize;
                Ok(BsrMeta {
                    spec, method, in_dim, out_dim, num_layers,
                    version, dtype: dtype_label(p.dtype).into(), file_bytes,
                })
            }
            v => bail!("unsupported BSR model version {v}"),
        }
    }

    /// Load and fully validate a saved artifact, either container
    /// version. Both CRCs (v2: header + payload; v1: whole body) are
    /// checked before the payload is interpreted, so a corrupt file fails
    /// with the same loud guard as a corrupt checkpoint.
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening BSR model {path:?}"))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        if all.len() < 12 || &all[..4] != MAGIC {
            bail!("not a BSRM block-sparse model");
        }
        match u32::from_le_bytes(all[4..8].try_into().unwrap()) {
            VERSION_V1 => Self::load_v1(&all),
            VERSION_V2 => Self::load_v2(&all),
            v => bail!("unsupported BSR model version {v}"),
        }
    }

    fn load_v1(all: &[u8]) -> Result<Self> {
        let body = &all[4..all.len() - 4];
        let stored_crc = u32::from_le_bytes(all[all.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("BSR model CRC mismatch (corrupt file)");
        }
        let mut off = 0usize;
        let version = wire::get_u32(body, &mut off).context("reading BSR model")?;
        if version != VERSION_V1 {
            bail!("unsupported BSR model version {version}");
        }
        let spec = wire::get_str(body, &mut off)?;
        let method = wire::get_str(body, &mut off)?;
        let in_dim = wire::get_u32(body, &mut off)? as usize;
        let out_dim = wire::get_u32(body, &mut off)? as usize;
        let num_layers = wire::get_u32(body, &mut off)? as usize;
        let mut layers = Vec::new();
        for _ in 0..num_layers {
            let name = wire::get_str(body, &mut off)?;
            let m = wire::get_u32(body, &mut off)? as usize;
            let n = wire::get_u32(body, &mut off)? as usize;
            let m2 = wire::get_u32(body, &mut off)? as usize;
            let n2 = wire::get_u32(body, &mut off)? as usize;
            let nnz = wire::get_u32(body, &mut off)? as usize;
            if m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
                bail!("slot '{name}': block ({m2},{n2}) does not tile ({m},{n})");
            }
            let row_ptr = wire::get_u32s(body, &mut off, m / m2 + 1)?;
            let col_idx = wire::get_u32s(body, &mut off, nnz)?;
            let blocks = wire::get_f32s(body, &mut off, nnz * m2 * n2)?;
            layers.push(BsrLayer { name, m, n, m2, n2, row_ptr, col_idx, blocks: blocks.into() });
        }
        if off != body.len() {
            bail!("BSR model has {} trailing bytes", body.len() - off);
        }
        let model = BsrModel { spec, method, in_dim, out_dim, layers };
        model.validate()?;
        Ok(model)
    }

    fn load_v2(all: &[u8]) -> Result<Self> {
        let c = open_v2_bytes(all, true)?;
        if c.prologue.dtype != DTYPE_F32 {
            bail!(
                "artifact stores {} blocks — open it with `load_auto` or `QuantModel::load`",
                dtype_label(c.prologue.dtype)
            );
        }
        let mut layers = Vec::new();
        for lh in &c.header.layers {
            let m1 = lh.m / lh.m2;
            let row_ptr = take_u32s(
                c.payload, lh.row_ptr_off, (m1 + 1) as u64,
                &format!("{}.row_ptr", lh.name),
            )?;
            let col_idx = take_u32s(
                c.payload, lh.col_idx_off, lh.nnz as u64,
                &format!("{}.col_idx", lh.name),
            )?;
            let blocks = take_f32s(
                c.payload, lh.blocks_off, lh.block_values()?,
                &format!("{}.blocks", lh.name),
            )?;
            layers.push(BsrLayer {
                name: lh.name.clone(),
                m: lh.m,
                n: lh.n,
                m2: lh.m2,
                n2: lh.n2,
                row_ptr,
                col_idx,
                blocks: blocks.into(),
            });
        }
        let model = BsrModel {
            spec: c.header.spec,
            method: c.header.method,
            in_dim: c.header.in_dim,
            out_dim: c.header.out_dim,
            layers,
        };
        model.validate()?;
        Ok(model)
    }

    /// Zero-copy open: see [`mmap::open_bsr_mmap`]. Falls back to the
    /// read path for v1 artifacts and on platforms without the mmap
    /// support gate.
    pub fn open_mmap(path: &Path) -> Result<(Self, mmap::MapStats)> {
        mmap::open_bsr_mmap(path)
    }
}

// ------------------------------------------------------------- ServedModel

/// What the serving engine deploys: a packed model at either payload
/// dtype. The engine, registry and CLI are dtype-agnostic — they route
/// through this enum's accessors and [`ServedModel::forward`], so an int8
/// artifact hot-swaps over an f32 one (and back) with no special casing.
#[derive(Clone, Debug, PartialEq)]
pub enum ServedModel {
    F32(BsrModel),
    Int8(quant::QuantModel),
}

impl ServedModel {
    pub fn spec(&self) -> &str {
        match self {
            ServedModel::F32(m) => &m.spec,
            ServedModel::Int8(m) => &m.spec,
        }
    }

    pub fn method(&self) -> &str {
        match self {
            ServedModel::F32(m) => &m.method,
            ServedModel::Int8(m) => &m.method,
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            ServedModel::F32(m) => m.in_dim,
            ServedModel::Int8(m) => m.in_dim,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            ServedModel::F32(m) => m.out_dim,
            ServedModel::Int8(m) => m.out_dim,
        }
    }

    pub fn num_layers(&self) -> usize {
        match self {
            ServedModel::F32(m) => m.layers.len(),
            ServedModel::Int8(m) => m.layers.len(),
        }
    }

    /// Payload dtype label ("f32" / "int8") — what logs and benches tag
    /// responses with.
    pub fn dtype(&self) -> &'static str {
        match self {
            ServedModel::F32(_) => "f32",
            ServedModel::Int8(_) => "int8",
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            ServedModel::F32(m) => m.validate(),
            ServedModel::Int8(m) => m.validate(),
        }
    }

    /// Full-stack logits on a flat (nb × in_dim) batch — ReLU fused into
    /// every hidden layer, none after the logits, whichever dtype.
    pub fn forward(&self, x: &[f32], nb: usize) -> Result<Vec<f32>> {
        match self {
            ServedModel::F32(m) => bsr::model_forward(m, x, nb),
            ServedModel::Int8(m) => quant::model_forward_q8(m, x, nb),
        }
    }

    pub fn nnz_params(&self) -> u64 {
        match self {
            ServedModel::F32(m) => m.nnz_params(),
            ServedModel::Int8(m) => m.nnz_params(),
        }
    }

    pub fn block_sparsity(&self) -> f64 {
        match self {
            ServedModel::F32(m) => m.block_sparsity(),
            ServedModel::Int8(m) => m.block_sparsity(),
        }
    }

    pub fn infer_flops_per_example(&self) -> u64 {
        match self {
            ServedModel::F32(m) => m.infer_flops_per_example(),
            ServedModel::Int8(m) => m.infer_flops_per_example(),
        }
    }

    pub fn dense_flops_per_example(&self) -> u64 {
        match self {
            ServedModel::F32(m) => m.dense_flops_per_example(),
            ServedModel::Int8(m) => m.dense_flops_per_example(),
        }
    }
}

impl From<BsrModel> for ServedModel {
    fn from(m: BsrModel) -> Self {
        ServedModel::F32(m)
    }
}

impl From<quant::QuantModel> for ServedModel {
    fn from(m: quant::QuantModel) -> Self {
        ServedModel::Int8(m)
    }
}

/// Load an artifact of either dtype: one O(header) [`BsrModel::peek`]
/// routes to the matching loader. This is what `deploy_from_path`, the
/// CLI and any artifact watcher call — they never hard-code a dtype.
pub fn load_auto(path: &Path) -> Result<ServedModel> {
    let meta = BsrModel::peek(path)?;
    if meta.dtype == "int8" {
        Ok(ServedModel::Int8(quant::QuantModel::load(path)?))
    } else {
        Ok(ServedModel::F32(BsrModel::load(path)?))
    }
}

/// Export a trained state to a packed BSR model: `materialize` every slot
/// to its (block-wise sparse) dense W, then pack at the spec's per-slot
/// block shape. Slots without a declared block shape (iterative pruning,
/// dense, pattern survivors) pack at 1×1 — element-level CSR. Transformer
/// specs export their q/k/v/o/FFN projection stack (the block-sparse
/// weights; embeddings, LayerNorm gains and the LM head are dense extras
/// that live in the training checkpoint, not in the BSR pack) — the stack
/// chains because fc2 emits d_model again, so `BsrModel::validate` holds.
pub fn export(be: &dyn Backend, state: &TrainState) -> Result<BsrModel> {
    let spec = be.spec(&state.spec)?;
    let ws = be.materialize(state)?;
    if ws.is_empty() {
        bail!("spec '{}' materialized no slots", spec.key);
    }
    let mut layers = Vec::with_capacity(ws.len());
    for (name, w) in &ws {
        if w.shape().len() != 2 {
            bail!("slot '{name}' materialized to shape {:?}, wants 2-D", w.shape());
        }
        let (m, n) = (w.shape()[0], w.shape()[1]);
        let (m2, n2) = spec.block_of(name).unwrap_or((1, 1));
        layers.push(BsrLayer::from_dense(name, w.data(), m, n, m2, n2)?);
    }
    let model = BsrModel {
        spec: spec.key.clone(),
        method: spec.method.clone(),
        in_dim: layers[0].n,
        out_dim: layers.last().unwrap().m,
        layers,
    };
    model.validate()?;
    Ok(model)
}

/// Synthetic block-sparse dense weights for the bench panels and tests:
/// random-normal values with exactly `round(occupancy · grid)` live
/// (m2×n2) blocks (clamped to ≥ 1), plus the matching (m1·n1) {0,1}
/// block mask. This is the single shared definition of what "X% block
/// sparsity" means across `perf_micro` and `infer_serve`.
pub fn synth_block_sparse_weights(
    rng: &mut Rng,
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
    occupancy: f64,
) -> (Vec<f32>, Vec<f32>) {
    assert!(m2 > 0 && n2 > 0 && m % m2 == 0 && n % n2 == 0,
            "block ({m2},{n2}) does not tile ({m},{n})");
    let (m1, n1) = (m / m2, n / n2);
    let total = m1 * n1;
    let k = ((occupancy * total as f64).round() as usize).clamp(1, total);
    let mut mask = vec![0.0f32; total];
    for i in rng.choose(total, k) {
        mask[i] = 1.0;
    }
    let mut w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
    for i in 0..m {
        for j in 0..n {
            if mask[(i / m2) * n1 + j / n2] == 0.0 {
                w[i * n + j] = 0.0;
            }
        }
    }
    (w, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_with_holes() -> (Vec<f32>, usize, usize) {
        // 4×6 matrix, 2×3 blocks: grid 2×2, zero out blocks (0,0) and (1,1)
        let (m, n) = (4usize, 6usize);
        let mut w = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let (i1, j1) = (i / 2, j / 3);
                if (i1, j1) == (0, 1) || (i1, j1) == (1, 0) {
                    w[i * n + j] = (1 + i * n + j) as f32;
                }
            }
        }
        (w, m, n)
    }

    #[test]
    fn from_dense_packs_only_occupied_blocks() {
        let (w, m, n) = dense_with_holes();
        let l = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        l.validate().unwrap();
        assert_eq!(l.nnz_blocks(), 2);
        assert_eq!(l.row_ptr, vec![0, 1, 2]);
        assert_eq!(l.col_idx, vec![1, 0]);
        assert!((l.occupancy() - 0.5).abs() < 1e-12);
        assert!((l.block_sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(l.nnz_params(), 12);
        // round trip through the dense reconstruction is exact
        assert_eq!(l.to_dense(), w);
    }

    #[test]
    fn from_dense_rejects_bad_shapes() {
        let (w, m, n) = dense_with_holes();
        assert!(BsrLayer::from_dense("fc", &w, m, n, 3, 3).is_err());
        assert!(BsrLayer::from_dense("fc", &w, m, n, 2, 4).is_err());
        assert!(BsrLayer::from_dense("fc", &w, m, n, 0, 3).is_err());
        assert!(BsrLayer::from_dense("fc", &w[1..], m, n, 2, 3).is_err());
    }

    #[test]
    fn flops_scale_with_occupancy() {
        let (w, m, n) = dense_with_holes();
        let l = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        assert_eq!(l.infer_flops(), 2 * 2 * 3 * 2); // 2 blocks of 2×3
        assert_eq!(l.dense_flops(), 2 * l.infer_flops()); // 50% occupancy
        // all-zero slot: zero blocks, zero inference cost
        let zeros = vec![0.0; m * n];
        let z = BsrLayer::from_dense("z", &zeros, m, n, 2, 3).unwrap();
        assert_eq!(z.nnz_blocks(), 0);
        assert_eq!(z.infer_flops(), 0);
        assert!((z.block_sparsity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn block_store_cow_and_equality() {
        let owned: BlockStore = vec![1.0f32, 2.0, 3.0].into();
        assert!(!owned.is_mapped());
        assert_eq!(&owned[..], &[1.0, 2.0, 3.0]);
        let mut copy = owned.clone();
        copy.to_mut().push(4.0);
        assert_eq!(copy.len(), 4);
        assert_eq!(owned.len(), 3, "to_mut on a clone must not alias");
        assert_ne!(owned, copy);
        assert_eq!(owned, BlockStore::from(vec![1.0f32, 2.0, 3.0]));
    }

    #[test]
    fn validate_catches_structural_corruption() {
        let (w, m, n) = dense_with_holes();
        let good = BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap();
        let mut bad = good.clone();
        bad.col_idx[0] = 7; // out of the 2-wide grid
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.row_ptr[1] = 3; // beyond col_idx
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.blocks.to_mut().pop();
        assert!(bad.validate().is_err());
        let mut bad = good;
        bad.row_ptr = vec![0, 2];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn model_validate_checks_the_chain() {
        let (w1, w2) = (vec![1.0; 6 * 8], vec![1.0; 4 * 6]);
        let l1 = BsrLayer::from_dense("fc1", &w1, 6, 8, 2, 2).unwrap();
        let l2 = BsrLayer::from_dense("fc2", &w2, 4, 6, 2, 2).unwrap();
        let ok = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![l1.clone(), l2.clone()],
        };
        ok.validate().unwrap();
        assert_eq!(ok.nnz_params(), 6 * 8 + 4 * 6);
        let broken = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![l2, l1], // 4×6 then 6×8: chain mismatch
        };
        assert!(broken.validate().is_err());
        let empty = BsrModel {
            spec: "s".into(),
            method: "dense".into(),
            in_dim: 8,
            out_dim: 4,
            layers: vec![],
        };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn synth_weights_hit_exact_occupancy() {
        let mut rng = Rng::new(17);
        let (w, mask) = synth_block_sparse_weights(&mut rng, 12, 20, 3, 4, 0.25);
        // grid 4×5 = 20 blocks → exactly 5 live
        assert_eq!(mask.iter().filter(|&&v| v == 1.0).count(), 5);
        let l = BsrLayer::from_dense("fc", &w, 12, 20, 3, 4).unwrap();
        assert!((l.occupancy() - 0.25).abs() < 1e-12);
        // the packed structure matches the mask, block for block
        let (_, n1) = l.grid();
        for (blk, &mv) in mask.iter().enumerate() {
            let (i1, j1) = (blk / n1, blk % n1);
            let stored = l.col_idx[l.row_ptr[i1] as usize..l.row_ptr[i1 + 1] as usize]
                .contains(&(j1 as u32));
            assert_eq!(stored, mv == 1.0, "block ({i1},{j1})");
        }
        // occupancy 0 still keeps one block (benches never hit div-by-zero)
        let (_, mask0) = synth_block_sparse_weights(&mut rng, 12, 20, 3, 4, 0.0);
        assert_eq!(mask0.iter().filter(|&&v| v == 1.0).count(), 1);
    }

    fn tiny_model(spec: &str) -> BsrModel {
        let (w, m, n) = dense_with_holes();
        BsrModel {
            spec: spec.into(),
            method: "kpd".into(),
            in_dim: n,
            out_dim: m,
            layers: vec![BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap()],
        }
    }

    // NOTE: the hostile-input coverage (byte-flip/truncation sweeps over
    // both container versions, read + mmap paths) lives in
    // tests/corruption.rs — these tests pin the happy paths and the v2
    // byte layout.

    #[test]
    fn save_load_round_trip_v2() {
        let model = tiny_model("tiny");
        let dir = std::env::temp_dir().join("bs_bsrm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let back = BsrModel::load(&path).unwrap();
        assert_eq!(back, model);
        // wrong magic fails the same loud way as always
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = BsrModel::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("not a BSRM"), "{err:#}");
    }

    #[test]
    fn v2_layout_is_aligned_and_extent_checked() {
        let model = tiny_model("layout");
        let dir = std::env::temp_dir().join("bs_bsrm_layout_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let all = std::fs::read(&path).unwrap();
        let p = read_prologue(&all).unwrap();
        assert_eq!(p.dtype, DTYPE_F32);
        assert_eq!(p.payload_off % 8, 0);
        assert_eq!(p.payload_off + p.payload_len, all.len() as u64);
        let c = open_v2_bytes(&all, true).unwrap();
        assert_eq!(c.header.spec, "layout");
        for lh in &c.header.layers {
            assert_eq!(lh.row_ptr_off % 8, 0);
            assert_eq!(lh.col_idx_off % 8, 0);
            assert_eq!(lh.blocks_off % 8, 0);
        }
        // a trailing byte breaks the extent equation — typed error, no
        // trailing-garbage acceptance
        let mut grown = all.clone();
        grown.push(0);
        std::fs::write(&path, &grown).unwrap();
        assert!(BsrModel::load(&path).is_err());
    }

    #[test]
    fn save_v1_round_trips_through_the_version_branch() {
        let model = tiny_model("legacy");
        let dir = std::env::temp_dir().join("bs_bsrm_v1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save_v1(&path).unwrap();
        assert_eq!(BsrModel::load(&path).unwrap(), model);
        let meta = BsrModel::peek(&path).unwrap();
        assert_eq!(meta.version, 1);
        assert_eq!(meta.dtype, "f32");
        // and the same model written v2 peeks as version 2
        model.save(&path).unwrap();
        let meta = BsrModel::peek(&path).unwrap();
        assert_eq!(meta.version, 2);
        assert_eq!(meta.dtype, "f32");
    }

    #[test]
    fn save_publishes_atomically_over_an_existing_artifact() {
        let mk = tiny_model;
        let dir = std::env::temp_dir().join("bs_bsrm_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        mk("old").save(&path).unwrap();
        mk("new").save(&path).unwrap(); // overwrite via temp + rename
        assert_eq!(BsrModel::load(&path).unwrap().spec, "new");
        // no temp litter survives a successful publish
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "leftover temp files: {stray:?}");
    }

    #[test]
    fn peek_reads_header_without_payload() {
        let (w, m, n) = dense_with_holes();
        let model = BsrModel {
            spec: "tiny".into(),
            method: "group_lasso".into(),
            in_dim: n,
            out_dim: m,
            layers: vec![BsrLayer::from_dense("fc", &w, m, n, 2, 3).unwrap()],
        };
        let dir = std::env::temp_dir().join("bs_bsrm_peek_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let meta = BsrModel::peek(&path).unwrap();
        assert_eq!(meta.spec, "tiny");
        assert_eq!(meta.method, "group_lasso");
        assert_eq!(meta.in_dim, n);
        assert_eq!(meta.out_dim, m);
        assert_eq!(meta.num_layers, 1);
        assert_eq!(meta.version, 2);
        assert_eq!(meta.dtype, "f32");
        assert_eq!(meta.file_bytes, std::fs::metadata(&path).unwrap().len());
        // peek shares the magic guard with load
        std::fs::write(&path, b"XXXX12345678").unwrap();
        assert!(BsrModel::peek(&path).is_err());
    }

    #[test]
    fn load_auto_routes_f32() {
        let model = tiny_model("auto");
        let dir = std::env::temp_dir().join("bs_bsrm_auto_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bsm");
        model.save(&path).unwrap();
        let served = load_auto(&path).unwrap();
        assert_eq!(served.dtype(), "f32");
        assert_eq!(served.spec(), "auto");
        assert_eq!((served.in_dim(), served.out_dim()), (model.in_dim, model.out_dim));
        match served {
            ServedModel::F32(back) => assert_eq!(back, model),
            other => panic!("wanted F32, got {other:?}"),
        }
    }
}
