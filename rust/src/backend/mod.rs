//! Execution backends: the coordinator's only window onto "how a step runs".
//!
//! The `Backend` trait mirrors the execution surface the training loop
//! needs — `init_state` / `train_step` / `eval_step` / `materialize` /
//! `rigl_update` / `prune` — with two implementations:
//!
//! * [`native::NativeBackend`] (default): pure-Rust KPD-factorized
//!   forward/backward, block-sparse baselines, SGD/momentum and the
//!   ℓ1-on-S proximal update. Hermetic: no AOT artifacts, no PJRT.
//! * `pjrt::PjrtBackend` (`--features pjrt`): the original AOT/HLO path,
//!   wrapping `crate::runtime::Runtime`. All math lives in the lowered
//!   executables; this adapter marshals `Tensor` state in and out of
//!   `xla::Literal`s per call.
//!
//! State crossing the boundary is host-owned (`tensor::Tensor` /
//! `HostValue`), so probes, checkpoints and tests are backend-agnostic.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{anyhow, bail, Result};

use crate::manifest::SpecEntry;
use crate::tensor::{HostValue, Tensor};

/// Mutable training state for one spec: named parameter and optimizer
/// tensors, threaded through consecutive train steps.
pub struct TrainState {
    pub spec: String,
    pub param_names: Vec<String>,
    pub opt_names: Vec<String>,
    pub params: Vec<Tensor>,
    pub opt: Vec<Tensor>,
}

impl TrainState {
    pub fn param(&self, key: &str) -> Result<&Tensor> {
        let i = self
            .param_names
            .iter()
            .position(|n| n == key)
            .ok_or_else(|| anyhow!("no param '{key}' in spec {}", self.spec))?;
        Ok(&self.params[i])
    }

    /// Owned copy of a parameter (probe/test convenience).
    pub fn param_tensor(&self, key: &str) -> Result<Tensor> {
        self.param(key).cloned()
    }

    pub fn set_param(&mut self, key: &str, value: Tensor) -> Result<()> {
        let i = self
            .param_names
            .iter()
            .position(|n| n == key)
            .ok_or_else(|| anyhow!("no param '{key}' in spec {}", self.spec))?;
        if self.params[i].shape() != value.shape() {
            bail!(
                "set_param '{key}': shape {:?} != {:?}",
                value.shape(),
                self.params[i].shape()
            );
        }
        self.params[i] = value;
        Ok(())
    }
}

/// Raw gradient of one batch shard, as produced by [`Backend::grad_step`].
///
/// Everything is a per-example **sum** (not a mean): shard gradients then
/// combine by pure addition — the unit the data-parallel trainer's
/// fixed-order tree reduction (`crate::train::reduce`) operates on — and
/// one final division by the total example count recovers the full-batch
/// mean gradient. (The sums come from rescaling `softmax_ce`'s 1/N-scaled
/// dZ by the shard size, so for shard sizes that are not powers of two
/// they match the mathematical sums to f32 rounding, not bit-exactly —
/// deterministic either way, which is what the replica guarantee needs.)
#[derive(Clone, Debug)]
pub struct GradOut {
    /// Σ over the shard of per-example CE gradients, flattened as the
    /// concatenation of the spec's gradient leaves in registry order
    /// ([`Backend::grad_len`] gives the total length).
    pub grad_sum: Vec<f32>,
    /// Σ over the shard of per-example CE losses.
    pub ce_sum: f32,
    /// Number of correctly classified shard examples.
    pub correct: f32,
    /// Shard size in examples.
    pub examples: usize,
}

/// An execution engine for training/eval steps. Object-safe: the
/// coordinator, CLI and benches hold a `&dyn Backend` / `Box<dyn Backend>`.
/// `Send + Sync` so the data-parallel trainer can run `grad_step` from
/// replica worker threads against one shared backend.
pub trait Backend: Send + Sync {
    /// Human-readable backend identity ("native-cpu", PJRT platform, ...).
    fn name(&self) -> String;

    /// All specs this backend can run, sorted by key.
    fn specs(&self) -> Vec<&SpecEntry>;

    fn spec(&self, key: &str) -> Result<&SpecEntry>;

    /// Seed-deterministic fresh parameter + optimizer state.
    fn init_state(&self, spec: &str, seed: u32) -> Result<TrainState>;

    /// One training step: updates `state` in place, returns the metrics
    /// vector (names in `spec.metrics`, `metrics[0]` is the loss).
    fn train_step(
        &self,
        state: &mut TrainState,
        x: &HostValue,
        y: &HostValue,
        hyper: &[f32],
    ) -> Result<Vec<f32>>;

    /// Evaluation on the current parameters: `[mean_ce, correct_count]`
    /// (pattern-selection specs instead return the per-pattern layout
    /// `[ce_0..ce_{K-1}, correct_0..correct_{K-1}]`).
    fn eval_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<Vec<f32>>;

    /// Whether executables are compiled for one exact batch size (AOT/PJRT),
    /// in which case evaluation must drop a trailing partial batch. The
    /// native backend accepts any batch size and keeps the default `false`.
    fn fixed_batch(&self) -> bool {
        false
    }

    /// Reconstruct the (block-wise sparse) dense W of every slot.
    fn materialize(&self, state: &TrainState) -> Result<Vec<(String, Tensor)>>;

    /// Blockwise-RigL mask update (paper §6.1 baseline).
    fn rigl_update(&self, state: &mut TrainState, gnorm: &[f32], alpha: f32) -> Result<()>;

    /// Iterative-pruning step to a global sparsity target.
    fn prune(&self, state: &mut TrainState, target: f32) -> Result<()>;

    /// Number of per-block gradient-norm values appended to `train_step`
    /// metrics for RigL specs (0 for every other method).
    fn gnorm_len(&self, spec: &str) -> Result<usize>;

    /// Whether [`Backend::grad_step`] / [`Backend::apply_update`] are
    /// implemented for `spec` — the data-parallel trainer's precondition.
    /// Backends without a separable gradient path (AOT/PJRT executables
    /// fuse gradient and update into one lowered program) keep the default
    /// `false` and train single-replica through the fused `train_step`.
    fn supports_grad_step(&self, spec: &str) -> bool {
        let _ = spec;
        false
    }

    /// Length of the flat gradient buffer [`Backend::grad_step`] produces
    /// for `spec` (the concatenation of every gradient leaf).
    fn grad_len(&self, spec: &str) -> Result<usize> {
        bail!("backend '{}' has no separable gradient path for '{spec}'", self.name())
    }

    /// Forward/backward on one batch shard **without touching the state**:
    /// per-leaf gradient *sums* plus summed loss/accuracy stats. Together
    /// with [`Backend::apply_update`] this splits `train_step` so shard
    /// gradients can be computed on replica workers and reduced
    /// deterministically before one optimizer step.
    fn grad_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<GradOut> {
        let _ = (state, x, y);
        bail!("backend '{}' has no separable gradient path", self.name())
    }

    /// Optimizer + proximal update from a reduced **mean**-gradient buffer
    /// (laid out exactly as `grad_step` produces it); `ce_mean` /
    /// `acc_frac` are the reduced batch statistics. Returns the same
    /// metrics vector `train_step` returns — both paths call the same
    /// per-method apply kernels, so the math cannot drift.
    fn apply_update(
        &self,
        state: &mut TrainState,
        grad: Vec<f32>,
        ce_mean: f32,
        acc_frac: f32,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let _ = (state, grad, ce_mean, acc_frac, hyper);
        bail!("backend '{}' has no separable gradient path", self.name())
    }
}

/// Open the backend for `artifact_dir`, honoring an explicit `--backend`
/// override. Auto mode prefers PJRT when the build has it *and* AOT
/// artifacts exist; otherwise the hermetic native backend.
pub fn open(artifact_dir: &std::path::Path, force: Option<&str>) -> Result<Box<dyn Backend>> {
    match force {
        None => open_auto(artifact_dir),
        Some("native") => Ok(Box::new(native::NativeBackend::with_default_specs())),
        Some("pjrt") => open_pjrt(artifact_dir),
        Some(other) => bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
    }
}

/// Default backend for benches/tests: auto mode on the default artifact dir.
pub fn open_default() -> Result<Box<dyn Backend>> {
    open(&crate::artifact_dir(), None)
}

fn open_auto(artifact_dir: &std::path::Path) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("manifest.json").exists() {
        return Ok(Box::new(pjrt::PjrtBackend::new(artifact_dir)?));
    }
    let _ = artifact_dir;
    Ok(Box::new(native::NativeBackend::with_default_specs()))
}

#[cfg(feature = "pjrt")]
fn open_pjrt(artifact_dir: &std::path::Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(pjrt::PjrtBackend::new(artifact_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(artifact_dir: &std::path::Path) -> Result<Box<dyn Backend>> {
    let _ = artifact_dir;
    bail!("this build has no PJRT support; rebuild with `--features pjrt` to run AOT artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_param_lookup_and_set() {
        let mut st = TrainState {
            spec: "t".into(),
            param_names: vec!["fc.W".into()],
            opt_names: vec![],
            params: vec![Tensor::zeros(&[2, 3])],
            opt: vec![],
        };
        assert!(st.param("fc.W").is_ok());
        assert!(st.param("nope").is_err());
        assert!(st.set_param("fc.W", Tensor::full(&[2, 3], 1.0)).is_ok());
        assert_eq!(st.param("fc.W").unwrap().data()[0], 1.0);
        assert!(st.set_param("fc.W", Tensor::zeros(&[3, 2])).is_err());
    }

    #[test]
    fn open_unknown_backend_errors() {
        let e = open(std::path::Path::new("."), Some("bogus"));
        assert!(e.is_err());
    }
}
