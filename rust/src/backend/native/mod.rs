//! Pure-Rust execution backend: the default, hermetic way to train.
//!
//! Implements the paper's linear-spec methods directly on host tensors —
//! no AOT artifacts, no PJRT:
//!
//! * `kpd`          — factorized forward/backward (module [`kpd`]) with the
//!                    ℓ1-on-S proximal (soft-threshold) update;
//! * `pattern_kpd`  — joint multi-pattern training (module [`pattern`]):
//!                    K block-size candidates share the input, sum logits,
//!                    and each takes the ℓ1-on-S prox — Eq. 7 / Figure 3;
//! * `group_lasso` / `elastic_gl` — dense W with the block-group proximal
//!                    shrink (and ridge term for elastic);
//! * `rigl_block`   — block-masked W via the block-sparse matmul, dense
//!                    gradient-norm metrics for the mask controller;
//! * `iter_prune`   — elementwise-masked W, magnitude pruning to a target;
//! * `dense`        — the unregularized baseline.
//!
//! Specs are registered from [`SpecConfig`]s (manifest-free), so tests and
//! the CLI can construct and train models without any build-time python.
//! Optimization is SGD with classical momentum; the regularized leaves
//! (S, W-blocks) use plain SGD plus their proximal operator so exact
//! zeros appear.
//!
//! Every family runs on one composable layer graph (module [`layers`]):
//! the per-slot forward/backward/update primitives plus the sequential
//! ReLU stack. The single-slot `linear` specs are a one-slot stack, the
//! Table-2 `mlp` specs a three-slot stack (784→304→100→10, the
//! LeNet-300-100 stand-in), `pattern_kpd` drives one slot per candidate
//! (module [`pattern`]), and the Table-3 `t3_*` transformer specs
//! (module [`transformer`]) hang embedding / LayerNorm / causal
//! multi-head attention around block-sparse q/k/v/o/FFN slots. This
//! module is the thin outer driver: spec configs, the registry, and the
//! `Backend` routing into those families.

pub mod kpd;
pub mod layers;
pub mod linalg;
pub mod pattern;
pub mod simd;
pub mod transformer;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::flops::KpdDims;
use crate::manifest::{HyperParam, SlotInfo, SpecEntry};
use crate::tensor::{DType, HostValue, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{Backend, GradOut, TrainState};

const METHODS: &[&str] = &[
    "kpd",
    "pattern_kpd",
    "group_lasso",
    "elastic_gl",
    "rigl_block",
    "iter_prune",
    "dense",
];

/// One linear slot of a multi-layer (`mlp`) spec: a W ∈ R^{m×n} with its
/// own (m2, n2) block size. The method decides the parameterization
/// (KPD factors / dense W / masked W), shared across the whole stack.
#[derive(Clone, Debug)]
pub struct LayerCfg {
    /// slot name (`fc1`, `fc2`, ...) — the parameter-name prefix
    pub name: String,
    /// output features
    pub m: usize,
    /// input features
    pub n: usize,
    /// block rows
    pub m2: usize,
    /// block cols
    pub n2: usize,
}

impl LayerCfg {
    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.m2, self.n / self.n2)
    }

    /// KPD dims of this slot at the spec rank (clamped by the Eq. 2 bound).
    pub fn dims(&self, rank: usize) -> KpdDims {
        KpdDims::from_block(self.m, self.n, self.m2, self.n2, rank.max(1))
    }
}

/// Manifest-free description of one trainable linear spec.
#[derive(Clone, Debug)]
pub struct SpecConfig {
    pub key: String,
    /// one of `kpd | group_lasso | elastic_gl | rigl_block | iter_prune | dense`
    pub method: String,
    /// input features n (= n1·n2)
    pub in_dim: usize,
    /// classes m (= m1·m2)
    pub out_dim: usize,
    /// block rows m2
    pub m2: usize,
    /// block cols n2
    pub n2: usize,
    /// KPD decomposition rank r
    pub rank: usize,
    pub batch: usize,
    /// classical momentum for the smooth parameters (0 = plain SGD)
    pub momentum: f32,
    /// initial fraction of active blocks for `rigl_block`
    pub rigl_density: f64,
    /// candidate `(m2, n2)` block sizes for `pattern_kpd` (empty otherwise)
    pub patterns: Vec<(usize, usize)>,
    /// the linear slots of the layer graph: one `fc` slot for the linear
    /// specs, `fc1..fcN` with ReLU between them for `mlp` specs, the
    /// q/k/v/o/fc1/fc2 projection slots per block for transformer specs;
    /// empty only for `pattern_kpd` (which builds one slot per candidate)
    pub layers: Vec<LayerCfg>,
    /// model family label for the spec entry (`""` keeps the implied
    /// `linear`/`mlp`; transformer specs set `lm_*` so the coordinator
    /// picks the Markov LM corpus and cosine LR schedule)
    pub model: String,
    /// transformer sequence length (tokens per example; 0 = not a
    /// transformer)
    pub seq: usize,
    /// transformer residual width
    pub d_model: usize,
    /// attention heads (must divide `d_model`)
    pub heads: usize,
    /// FFN hidden width
    pub d_ff: usize,
    /// encoder blocks; `depth > 0` marks the spec as a transformer
    pub depth: usize,
    pub tags: Vec<String>,
}

impl SpecConfig {
    /// A linear classifier spec with repo-standard defaults.
    #[allow(clippy::too_many_arguments)]
    pub fn linear(
        key: &str,
        method: &str,
        in_dim: usize,
        out_dim: usize,
        m2: usize,
        n2: usize,
        rank: usize,
        batch: usize,
    ) -> Self {
        // pattern_kpd builds one slot per candidate at train time; every
        // other method runs the one-slot layer graph directly
        let layers = if method == "pattern_kpd" {
            Vec::new()
        } else {
            vec![LayerCfg { name: "fc".to_string(), m: out_dim, n: in_dim, m2, n2 }]
        };
        SpecConfig {
            key: key.to_string(),
            method: method.to_string(),
            in_dim,
            out_dim,
            m2,
            n2,
            rank,
            batch,
            momentum: 0.9,
            rigl_density: 0.5,
            patterns: Vec::new(),
            layers,
            model: String::new(),
            seq: 0,
            d_model: 0,
            heads: 0,
            d_ff: 0,
            depth: 0,
            tags: Vec::new(),
        }
    }

    /// A sequential multi-layer perceptron spec: `widths` gives the layer
    /// widths (e.g. `[784, 304, 100, 10]` → three linear slots `fc1..fc3`
    /// with ReLU between them), `blocks[i]` the (m2, n2) block size of
    /// slot i (missing entries default to 1×1 — elementwise). The method
    /// applies to every slot; `rank` is shared and clamped per slot.
    pub fn mlp(
        key: &str,
        method: &str,
        widths: &[usize],
        blocks: &[(usize, usize)],
        rank: usize,
        batch: usize,
    ) -> Self {
        assert!(widths.len() >= 2, "mlp needs at least input and output widths");
        let mut cfg = SpecConfig::linear(
            key,
            method,
            widths[0],
            *widths.last().unwrap(),
            1,
            1,
            rank,
            batch,
        );
        cfg.layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| LayerCfg {
                name: format!("fc{}", i + 1),
                m: w[1],
                n: w[0],
                m2: blocks.get(i).map(|b| b.0).unwrap_or(1),
                n2: blocks.get(i).map(|b| b.1).unwrap_or(1),
            })
            .collect();
        cfg
    }

    /// Whether this spec is a sequential multi-layer (`mlp`) model.
    pub fn is_mlp(&self) -> bool {
        self.layers.len() > 1 && !self.is_transformer()
    }

    /// Whether this spec is a transformer (`t3_*`) model.
    pub fn is_transformer(&self) -> bool {
        self.depth > 0
    }

    /// A block-sparse transformer LM spec: `depth` pre-LN encoder blocks
    /// (causal multi-head attention + ReLU FFN, residual around each) over
    /// token + positional embeddings, LayerNorm → tied-width vocab head on
    /// top. The q/k/v/o projections (`d×d`) and FFN matrices (`d_ff×d`,
    /// `d×d_ff`) are linear slots of the shared layer graph, so every
    /// method (KPD factorization, group-lasso prox, RigL masks, ...)
    /// applies to them unchanged; embeddings, LayerNorm gains/biases and
    /// the head stay dense (plain SGD/momentum).
    #[allow(clippy::too_many_arguments)]
    pub fn transformer(
        key: &str,
        model: &str,
        method: &str,
        vocab: usize,
        seq: usize,
        d_model: usize,
        heads: usize,
        d_ff: usize,
        depth: usize,
        m2: usize,
        n2: usize,
        rank: usize,
        batch: usize,
    ) -> Self {
        let mut cfg = SpecConfig::linear(key, method, seq, vocab, m2, n2, rank, batch);
        cfg.model = model.to_string();
        cfg.seq = seq;
        cfg.d_model = d_model;
        cfg.heads = heads;
        cfg.d_ff = d_ff;
        cfg.depth = depth;
        let mut layers = Vec::with_capacity(depth * 6);
        for i in 0..depth {
            for (leaf, m, n) in [
                ("q", d_model, d_model),
                ("k", d_model, d_model),
                ("v", d_model, d_model),
                ("o", d_model, d_model),
                ("fc1", d_ff, d_model),
                ("fc2", d_model, d_ff),
            ] {
                layers.push(LayerCfg { name: format!("b{i}.{leaf}"), m, n, m2, n2 });
            }
        }
        cfg.layers = layers;
        cfg
    }

    /// A joint pattern-selection spec (Eq. 7): K candidate block sizes of
    /// one linear layer trained together with summed logits.
    pub fn pattern(
        key: &str,
        in_dim: usize,
        out_dim: usize,
        patterns: &[(usize, usize)],
        rank: usize,
        batch: usize,
    ) -> Self {
        let mut cfg = SpecConfig::linear(key, "pattern_kpd", in_dim, out_dim, 1, 1, rank, batch);
        cfg.patterns = patterns.to_vec();
        cfg
    }

    pub fn validate(&self) -> Result<()> {
        // every bail names the offending spec key and the families the
        // native backend supports, so registry errors are actionable
        const FAMILIES: &str =
            "supported families: linear (one slot), mlp (slot stack), \
             pattern_kpd (one slot per block-size candidate), transformer (t3_*)";
        if !METHODS.contains(&self.method.as_str()) {
            bail!(
                "spec '{}': unknown method '{}' — the native backend supports \
                 {METHODS:?}; {FAMILIES}",
                self.key, self.method
            );
        }
        if self.batch == 0 {
            bail!("spec '{}': batch must be positive", self.key);
        }
        if (self.method == "kpd" || self.method == "pattern_kpd") && self.rank == 0 {
            bail!("spec '{}': {} rank must be ≥ 1", self.key, self.method);
        }
        if self.method == "pattern_kpd" {
            if !self.layers.is_empty() || self.is_transformer() {
                bail!(
                    "spec '{}': pattern_kpd builds its own per-candidate slots and \
                     cannot take a layer stack; {FAMILIES}",
                    self.key
                );
            }
            if self.patterns.is_empty() {
                bail!(
                    "spec '{}': pattern_kpd needs at least one (m2, n2) candidate",
                    self.key
                );
            }
            for &(m2, n2) in &self.patterns {
                if m2 == 0 || self.out_dim % m2 != 0 {
                    bail!(
                        "spec '{}': pattern block rows {m2} do not tile out_dim {}",
                        self.key, self.out_dim
                    );
                }
                if n2 == 0 || self.in_dim % n2 != 0 {
                    bail!(
                        "spec '{}': pattern block cols {n2} do not tile in_dim {}",
                        self.key, self.in_dim
                    );
                }
            }
            return Ok(());
        }
        if !self.patterns.is_empty() {
            bail!(
                "spec '{}': block-size candidates only apply to the pattern_kpd \
                 family, not method '{}'; {FAMILIES}",
                self.key, self.method
            );
        }
        if !(0.0..=1.0).contains(&self.rigl_density) {
            bail!("spec '{}': rigl_density must be in [0, 1]", self.key);
        }
        if self.layers.is_empty() {
            bail!(
                "spec '{}': no layer slots — every non-pattern spec runs on the \
                 layer graph; {FAMILIES}",
                self.key
            );
        }
        if self.is_transformer() {
            if self.seq == 0 {
                bail!("spec '{}': transformer seq length must be positive", self.key);
            }
            if self.d_model == 0 || self.heads == 0 || self.d_model % self.heads != 0 {
                bail!(
                    "spec '{}': attention heads {} must divide d_model {}",
                    self.key, self.heads, self.d_model
                );
            }
            if self.d_ff == 0 {
                bail!("spec '{}': transformer d_ff must be positive", self.key);
            }
        } else {
            // linear/mlp: the slot chain must span in_dim → out_dim; a
            // transformer's slots hang off the residual stream instead
            if self.layers[0].n != self.in_dim {
                bail!(
                    "spec '{}': first slot wants {} inputs, spec has in_dim {}",
                    self.key, self.layers[0].n, self.in_dim
                );
            }
            if self.layers.last().unwrap().m != self.out_dim {
                bail!(
                    "spec '{}': last slot emits {} features, spec has out_dim {}",
                    self.key, self.layers.last().unwrap().m, self.out_dim
                );
            }
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.m == 0 || l.n == 0 {
                bail!("spec '{}': slot '{}' has a zero dimension", self.key, l.name);
            }
            if l.m2 == 0 || l.m % l.m2 != 0 {
                bail!(
                    "spec '{}': slot '{}': block rows {} do not tile {}",
                    self.key, l.name, l.m2, l.m
                );
            }
            if l.n2 == 0 || l.n % l.n2 != 0 {
                bail!(
                    "spec '{}': slot '{}': block cols {} do not tile {}",
                    self.key, l.name, l.n2, l.n
                );
            }
            if i > 0 && !self.is_transformer() && self.layers[i - 1].m != l.n {
                bail!(
                    "spec '{}': slot '{}' wants {} inputs but '{}' emits {}",
                    self.key, l.name, l.n, self.layers[i - 1].name, self.layers[i - 1].m
                );
            }
        }
        Ok(())
    }

    pub fn dims(&self) -> KpdDims {
        KpdDims::from_block(self.out_dim, self.in_dim, self.m2, self.n2, self.rank.max(1))
    }

    /// KPD dims of every candidate pattern (`pattern_kpd` specs).
    pub fn pattern_dims(&self) -> Vec<KpdDims> {
        self.patterns
            .iter()
            .map(|&(m2, n2)| {
                KpdDims::from_block(self.out_dim, self.in_dim, m2, n2, self.rank.max(1))
            })
            .collect()
    }

    fn grid(&self) -> (usize, usize) {
        (self.out_dim / self.m2, self.in_dim / self.n2)
    }
}

struct NativeSpec {
    cfg: SpecConfig,
    entry: SpecEntry,
}

/// The native (pure-Rust, CPU) backend: a registry of [`SpecConfig`]s.
pub struct NativeBackend {
    specs: BTreeMap<String, NativeSpec>,
}

impl NativeBackend {
    /// Empty registry; add specs with [`NativeBackend::add_spec`].
    pub fn empty() -> Self {
        NativeBackend { specs: BTreeMap::new() }
    }

    /// Single-spec backend (the manifest-free test constructor).
    pub fn from_spec(cfg: SpecConfig) -> Result<Self> {
        let mut be = NativeBackend::empty();
        be.add_spec(cfg)?;
        Ok(be)
    }

    pub fn add_spec(&mut self, cfg: SpecConfig) -> Result<()> {
        let entry = build_entry(&cfg)?;
        self.specs.insert(cfg.key.clone(), NativeSpec { cfg, entry });
        Ok(())
    }

    /// The built-in linear-model registry mirroring the Table-1/Table-4
    /// spec keys of the AOT manifest, so the CLI and benches run offline.
    pub fn with_default_specs() -> Self {
        let mut be = NativeBackend::empty();
        let mut add = |mut cfg: SpecConfig, tag: &str| {
            cfg.tags = vec![tag.to_string()];
            be.add_spec(cfg).expect("default spec registry");
        };
        add(SpecConfig::linear("qs_kpd", "kpd", 784, 10, 2, 16, 2, 64), "quickstart");
        for (bk, n2) in [("b2x2", 2usize), ("b4x2", 4), ("b8x2", 8), ("b16x2", 16)] {
            add(
                SpecConfig::linear(&format!("t1_kpd_{bk}"), "kpd", 784, 10, 2, n2, 2, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_gl_{bk}"), "group_lasso", 784, 10, 2, n2, 1, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_egl_{bk}"), "elastic_gl", 784, 10, 2, n2, 1, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_rigl_{bk}"), "rigl_block", 784, 10, 2, n2, 1, 128),
                "table1",
            );
        }
        add(SpecConfig::linear("t1_prune", "iter_prune", 784, 10, 1, 1, 1, 128), "table1");
        add(SpecConfig::linear("t1_dense", "dense", 784, 10, 1, 1, 1, 128), "table1");
        for r in [1usize, 2, 4, 6] {
            add(
                SpecConfig::linear(&format!("t4_linear_r{r}"), "kpd", 784, 10, 2, 16, r, 128),
                "table4",
            );
        }
        // Table 2 natively: a 784→304→100→10 MLP stands in for the paper's
        // LeNet FC stack (LeNet-300-100 shape, first hidden width rounded
        // 300→304 so the coarsest paper combo's 8-row blocks tile it).
        // Per-combo blocks follow the paper's "(a, b)" → (m2, n2) = (b, a)
        // label convention (see python/compile/specs.py); rank 5 like the
        // AOT t2 specs, clamped per slot by the Eq. 2 bound.
        let t2_widths = [784usize, 304, 100, 10];
        let t2_combos: [(&str, [(usize, usize); 3]); 5] = [
            ("16x8_8x4_4x2", [(8, 16), (4, 8), (2, 4)]),
            ("8x4_4x4_2x2", [(4, 8), (4, 4), (2, 2)]),
            ("4x4_4x4_2x2", [(4, 4), (4, 4), (2, 2)]),
            ("4x4_2x2_2x2", [(4, 4), (2, 2), (2, 2)]),
            ("2x2_2x2_2x2", [(2, 2), (2, 2), (2, 2)]),
        ];
        for (name, blocks) in t2_combos {
            for (short, method) in [
                ("kpd", "kpd"),
                ("gl", "group_lasso"),
                ("egl", "elastic_gl"),
                ("rigl", "rigl_block"),
            ] {
                add(
                    SpecConfig::mlp(
                        &format!("t2_{short}_{name}"),
                        method,
                        &t2_widths,
                        &blocks,
                        5,
                        64,
                    ),
                    "table2",
                );
            }
        }
        add(SpecConfig::mlp("t2_prune", "iter_prune", &t2_widths, &[], 1, 64), "table2");
        add(SpecConfig::mlp("t2_dense", "dense", &t2_widths, &[], 1, 64), "table2");
        // Figure 3a: the Table-1 block-size grid trained jointly (Eq. 7).
        // Rank 1 gives the sharpest capacity cliff between candidates: a
        // rank-1 coarse-block teacher is exactly representable at its own
        // block size but only partially at any other, which is what makes
        // block-size *selection* well-posed.
        add(
            SpecConfig::pattern(
                "f3a_pattern",
                784,
                10,
                &[(2, 2), (2, 4), (2, 8), (2, 16)],
                1,
                128,
            ),
            "fig3",
        );
        // Table 3 natively: width/depth-scaled encoder LMs on the Markov
        // corpus stand in for the paper's ViT-t / ViT-b / Swin-t rows
        // (same "scaled proxy" convention as the t2 LeNet stand-in). All
        // projection/FFN slots use 4×4 blocks, KPD rank 2; seq 16 over a
        // 64-token vocabulary. The `lm_*` model labels route the specs to
        // `data::corpus::lm_dataset` and the cosine LR schedule.
        let t3_models: [(&str, &str, usize, usize, usize, usize); 3] = [
            ("vit_t", "lm_vit_t", 64, 4, 128, 2),
            ("vit_b", "lm_vit_b", 96, 6, 192, 3),
            ("swin_t", "lm_swin_t", 80, 4, 160, 2),
        ];
        for (tag, model, d, heads, d_ff, depth) in t3_models {
            for (short, method) in [
                ("dense", "dense"),
                ("gl", "group_lasso"),
                ("egl", "elastic_gl"),
                ("rigl", "rigl_block"),
                ("kpd", "kpd"),
            ] {
                add(
                    SpecConfig::transformer(
                        &format!("t3_{tag}_{short}"),
                        model,
                        method,
                        64,
                        16,
                        d,
                        heads,
                        d_ff,
                        depth,
                        4,
                        4,
                        2,
                        16,
                    ),
                    "table3",
                );
            }
        }
        be
    }

    fn get(&self, key: &str) -> Result<&NativeSpec> {
        self.specs
            .get(key)
            .ok_or_else(|| anyhow!("spec '{key}' not registered with the native backend"))
    }
}

// ------------------------------------------------------------ spec entry

fn build_entry(cfg: &SpecConfig) -> Result<SpecEntry> {
    cfg.validate()?;
    if cfg.is_transformer() {
        return build_t3_entry(cfg);
    }
    if cfg.is_mlp() {
        return build_mlp_entry(cfg);
    }
    let (m, n) = (cfg.out_dim, cfg.in_dim);
    let (m1, n1) = cfg.grid();
    let mut metrics: Vec<String> =
        ["loss", "ce", "acc"].iter().map(|s| s.to_string()).collect();
    let hyper: Vec<String> = match cfg.method.as_str() {
        "kpd" => {
            metrics.push("s_l1".to_string());
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "pattern_kpd" => {
            // the Figure-3 series: one ‖S^(k)‖₁ metric per candidate
            metrics.extend((0..cfg.patterns.len()).map(|p| format!("s_l1_p{p}")));
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "group_lasso" => vec!["lambda".to_string(), "lr".to_string()],
        "elastic_gl" => {
            vec!["lambda".to_string(), "lambda2".to_string(), "lr".to_string()]
        }
        "rigl_block" => {
            metrics.extend((0..m1 * n1).map(|i| format!("gnorm{i}")));
            vec!["lr".to_string()]
        }
        _ => vec!["lr".to_string()],
    };
    let params_total = match cfg.method.as_str() {
        "kpd" => cfg.dims().train_params() as usize,
        "pattern_kpd" => {
            cfg.pattern_dims().iter().map(|d| d.train_params() as usize).sum()
        }
        _ => m * n,
    };
    let mut info = BTreeMap::new();
    if cfg.method == "pattern_kpd" {
        // layout consumed by `experiment::accounting` and `Trainer`:
        // num_patterns + per-candidate {slot: [m2, n2]} entries
        info.insert(
            "num_patterns".to_string(),
            Json::Num(cfg.patterns.len() as f64),
        );
        info.insert(
            "patterns".to_string(),
            Json::Arr(
                cfg.patterns
                    .iter()
                    .map(|&(m2, n2)| {
                        let mut pat = BTreeMap::new();
                        pat.insert(
                            "fc".to_string(),
                            Json::Arr(vec![Json::Num(m2 as f64), Json::Num(n2 as f64)]),
                        );
                        Json::Obj(pat)
                    })
                    .collect(),
            ),
        );
        info.insert("rank".to_string(), Json::Num(cfg.rank.max(1) as f64));
    } else {
        let mut blocks = BTreeMap::new();
        blocks.insert(
            "fc".to_string(),
            Json::Arr(vec![Json::Num(cfg.m2 as f64), Json::Num(cfg.n2 as f64)]),
        );
        info.insert("blocks".to_string(), Json::Obj(blocks));
    }
    if cfg.method == "kpd" {
        let d = cfg.dims();
        info.insert("rank".to_string(), Json::Num(d.r as f64));
        let mut shape = BTreeMap::new();
        shape.insert("m1".to_string(), Json::Num(d.m1 as f64));
        shape.insert("n1".to_string(), Json::Num(d.n1 as f64));
        shape.insert("m2".to_string(), Json::Num(d.m2 as f64));
        shape.insert("n2".to_string(), Json::Num(d.n2 as f64));
        shape.insert("r".to_string(), Json::Num(d.r as f64));
        let mut shapes = BTreeMap::new();
        shapes.insert("fc".to_string(), Json::Obj(shape));
        info.insert("shapes".to_string(), Json::Obj(shapes));
    }
    Ok(SpecEntry {
        key: cfg.key.clone(),
        model: "linear".to_string(),
        batch: cfg.batch,
        tags: cfg.tags.clone(),
        input_shape: vec![n],
        input_dtype: DType::F32,
        num_classes: m,
        slots: vec![SlotInfo { name: "fc".to_string(), m, n }],
        method: cfg.method.clone(),
        hyper,
        metrics,
        params_total,
        info: Json::Obj(info),
    })
}

/// Spec entry for the sequential multi-layer (`mlp`) family. Per-slot
/// block sizes land in `info.blocks` (what the sparsity probe reads) and,
/// for KPD, per-slot factorization shapes in `info.shapes` (what the
/// FLOPs accounting reads). KPD specs report per-layer ‖S‖₁ metrics
/// (`s_l1_fc1`, ...) after the whole-model `s_l1`. RigL specs append the
/// concatenated per-slot block gradient norms to the train metrics like
/// the single-slot path, but the tail stays *unnamed* in the registry —
/// fine-block MLP grids reach ~10⁵ blocks and naming each would bloat
/// every registry construction; `Backend::gnorm_len` is the contract.
fn build_mlp_entry(cfg: &SpecConfig) -> Result<SpecEntry> {
    let mut metrics: Vec<String> =
        ["loss", "ce", "acc"].iter().map(|s| s.to_string()).collect();
    let hyper: Vec<String> = match cfg.method.as_str() {
        "kpd" => {
            metrics.push("s_l1".to_string());
            metrics.extend(cfg.layers.iter().map(|l| format!("s_l1_{}", l.name)));
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "group_lasso" => vec!["lambda".to_string(), "lr".to_string()],
        "elastic_gl" => {
            vec!["lambda".to_string(), "lambda2".to_string(), "lr".to_string()]
        }
        _ => vec!["lr".to_string()],
    };
    let params_total: usize = if cfg.method == "kpd" {
        cfg.layers.iter().map(|l| l.dims(cfg.rank).train_params() as usize).sum()
    } else {
        cfg.layers.iter().map(|l| l.m * l.n).sum()
    };
    let mut blocks = BTreeMap::new();
    for l in &cfg.layers {
        blocks.insert(
            l.name.clone(),
            Json::Arr(vec![Json::Num(l.m2 as f64), Json::Num(l.n2 as f64)]),
        );
    }
    let mut info = BTreeMap::new();
    info.insert("blocks".to_string(), Json::Obj(blocks));
    if cfg.method == "kpd" {
        info.insert("rank".to_string(), Json::Num(cfg.rank.max(1) as f64));
        let mut shapes = BTreeMap::new();
        for l in &cfg.layers {
            let d = l.dims(cfg.rank);
            let mut shape = BTreeMap::new();
            shape.insert("m1".to_string(), Json::Num(d.m1 as f64));
            shape.insert("n1".to_string(), Json::Num(d.n1 as f64));
            shape.insert("m2".to_string(), Json::Num(d.m2 as f64));
            shape.insert("n2".to_string(), Json::Num(d.n2 as f64));
            shape.insert("r".to_string(), Json::Num(d.r as f64));
            shapes.insert(l.name.clone(), Json::Obj(shape));
        }
        info.insert("shapes".to_string(), Json::Obj(shapes));
    }
    Ok(SpecEntry {
        key: cfg.key.clone(),
        model: "mlp".to_string(),
        batch: cfg.batch,
        tags: cfg.tags.clone(),
        input_shape: vec![cfg.in_dim],
        input_dtype: DType::F32,
        num_classes: cfg.out_dim,
        slots: cfg
            .layers
            .iter()
            .map(|l| SlotInfo { name: l.name.clone(), m: l.m, n: l.n })
            .collect(),
        method: cfg.method.clone(),
        hyper,
        metrics,
        params_total,
        info: Json::Obj(info),
    })
}

/// Spec entry for the transformer (`t3_*`) family. The projection/FFN
/// slots report like an mlp entry — per-slot block sizes in `info.blocks`
/// (the sparsity probe's layout), per-slot KPD shapes in `info.shapes`
/// (the FLOPs accounting's layout), per-slot `s_l1_{slot}` metrics after
/// the whole-model one, an unnamed RigL gradient-norm tail. Dense extras
/// (embeddings, LayerNorms, head) count toward `params_total` but carry
/// no block structure; the FLOPs columns cover the slot matmuls only —
/// the attention/LayerNorm backbone is method-invariant, so it cancels
/// out of every cross-method comparison the tables make.
fn build_t3_entry(cfg: &SpecConfig) -> Result<SpecEntry> {
    let mut metrics: Vec<String> =
        ["loss", "ce", "acc"].iter().map(|s| s.to_string()).collect();
    let hyper: Vec<String> = match cfg.method.as_str() {
        "kpd" => {
            metrics.push("s_l1".to_string());
            metrics.extend(cfg.layers.iter().map(|l| format!("s_l1_{}", l.name)));
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "group_lasso" => vec!["lambda".to_string(), "lr".to_string()],
        "elastic_gl" => {
            vec!["lambda".to_string(), "lambda2".to_string(), "lr".to_string()]
        }
        _ => vec!["lr".to_string()],
    };
    let slot_params: usize = if cfg.method == "kpd" {
        cfg.layers.iter().map(|l| l.dims(cfg.rank).train_params() as usize).sum()
    } else {
        cfg.layers.iter().map(|l| l.m * l.n).sum()
    };
    let extra_params: usize =
        transformer::dense_extra_layout(cfg).iter().map(|(_, l)| l).sum();
    let mut blocks = BTreeMap::new();
    for l in &cfg.layers {
        blocks.insert(
            l.name.clone(),
            Json::Arr(vec![Json::Num(l.m2 as f64), Json::Num(l.n2 as f64)]),
        );
    }
    let mut info = BTreeMap::new();
    info.insert("blocks".to_string(), Json::Obj(blocks));
    if cfg.method == "kpd" {
        info.insert("rank".to_string(), Json::Num(cfg.rank.max(1) as f64));
        let mut shapes = BTreeMap::new();
        for l in &cfg.layers {
            let d = l.dims(cfg.rank);
            let mut shape = BTreeMap::new();
            shape.insert("m1".to_string(), Json::Num(d.m1 as f64));
            shape.insert("n1".to_string(), Json::Num(d.n1 as f64));
            shape.insert("m2".to_string(), Json::Num(d.m2 as f64));
            shape.insert("n2".to_string(), Json::Num(d.n2 as f64));
            shape.insert("r".to_string(), Json::Num(d.r as f64));
            shapes.insert(l.name.clone(), Json::Obj(shape));
        }
        info.insert("shapes".to_string(), Json::Obj(shapes));
    }
    let mut dims = BTreeMap::new();
    dims.insert("seq".to_string(), Json::Num(cfg.seq as f64));
    dims.insert("d_model".to_string(), Json::Num(cfg.d_model as f64));
    dims.insert("heads".to_string(), Json::Num(cfg.heads as f64));
    dims.insert("d_ff".to_string(), Json::Num(cfg.d_ff as f64));
    dims.insert("depth".to_string(), Json::Num(cfg.depth as f64));
    info.insert("transformer".to_string(), Json::Obj(dims));
    Ok(SpecEntry {
        key: cfg.key.clone(),
        model: cfg.model.clone(),
        batch: cfg.batch,
        tags: cfg.tags.clone(),
        input_shape: vec![cfg.seq],
        input_dtype: DType::I32,
        num_classes: cfg.out_dim,
        slots: cfg
            .layers
            .iter()
            .map(|l| SlotInfo { name: l.name.clone(), m: l.m, n: l.n })
            .collect(),
        method: cfg.method.clone(),
        hyper,
        metrics,
        params_total: slot_params + extra_params,
        info: Json::Obj(info),
    })
}

// ------------------------------------------------------------- helpers

fn fnv(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn pidx(state: &TrainState, key: &str) -> Result<usize> {
    state
        .param_names
        .iter()
        .position(|k| k == key)
        .ok_or_else(|| anyhow!("no param '{key}' in spec {}", state.spec))
}

fn oidx(state: &TrainState, key: &str) -> Result<usize> {
    state
        .opt_names
        .iter()
        .position(|k| k == key)
        .ok_or_else(|| anyhow!("no optimizer slot '{key}' in spec {}", state.spec))
}

/// v ← μ·v + g;  p ← p − lr·v   (classical momentum; v=g on the first step).
fn sgd_momentum(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    for ((pi, vi), gi) in p.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + gi;
        *pi -= lr * *vi;
    }
}

// Fused update passes: each helper below folds what used to be a separate
// whole-leaf sweep (gradient masking / ridge term / prox) into the single
// optimizer sweep. Every one keeps the exact per-element arithmetic
// *sequence* of the old two-sweep code, so results are bit-identical —
// pinned by `fused_updates_match_two_sweep_reference` below.

/// p ← prox_{t·‖·‖₁}(p − lr·g): plain SGD fused with the elementwise
/// soft-threshold (exact zeros) — the S-leaf update of every KPD path.
fn sgd_prox_l1(p: &mut [f32], g: &[f32], lr: f32, t: f32) {
    if t <= 0.0 {
        for (pi, gi) in p.iter_mut().zip(g) {
            *pi -= lr * gi;
        }
        return;
    }
    for (pi, gi) in p.iter_mut().zip(g) {
        let v = *pi - lr * gi;
        *pi = v.signum() * (v.abs() - t).max(0.0);
    }
}

/// [`sgd_momentum`] with the elastic ridge term λ₂·p folded into the
/// gradient (reads the pre-update p, like the old separate g-sweep).
fn sgd_momentum_l2(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32, lam2: f32) {
    for ((pi, vi), gi) in p.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + (gi + lam2 * *pi);
        *pi -= lr * *vi;
    }
}

/// [`sgd_momentum`] with an elementwise gradient mask (iter_prune):
/// g ⊙ mask feeds the momentum, no separate masking sweep or mask clone.
fn sgd_momentum_masked(p: &mut [f32], v: &mut [f32], g: &[f32], mask: &[f32], lr: f32, mu: f32) {
    for (((pi, vi), gi), mv) in p.iter_mut().zip(v.iter_mut()).zip(g).zip(mask) {
        *vi = mu * *vi + gi * mv;
        *pi -= lr * *vi;
    }
}

/// [`sgd_momentum`] with an (m2×n2) block mask expanded on the fly
/// (rigl_block): replaces `mul_expand_mask` + momentum, and with it the
/// m·n-sized mask expansion and the mask `.to_vec()` clone.
#[allow(clippy::too_many_arguments)]
fn sgd_momentum_block_masked(
    p: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    mask: &[f32],
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
    lr: f32,
    mu: f32,
) {
    let n1 = n / n2;
    for i in 0..m {
        let mrow = &mask[(i / m2) * n1..(i / m2 + 1) * n1];
        let prow = &mut p[i * n..(i + 1) * n];
        let vrow = &mut v[i * n..(i + 1) * n];
        let grow = &g[i * n..(i + 1) * n];
        for (j, ((pi, vi), gi)) in prow.iter_mut().zip(vrow.iter_mut()).zip(grow).enumerate() {
            *vi = mu * *vi + gi * mrow[j / n2];
            *pi -= lr * *vi;
        }
    }
}

/// Simultaneous `&mut` to param `i` and `&` to param `j` (i ≠ j) — lets
/// the masked updates above read a mask leaf while mutating W, instead of
/// cloning the mask out of the state.
fn param_pair_mut(params: &mut [Tensor], i: usize, j: usize) -> (&mut Tensor, &Tensor) {
    debug_assert_ne!(i, j);
    if i < j {
        let (lo, hi) = params.split_at_mut(j);
        (&mut lo[i], &hi[0])
    } else {
        let (lo, hi) = params.split_at_mut(i);
        (&mut hi[0], &lo[j])
    }
}

/// Undo `softmax_ce`'s 1/N scaling on dZ so every gradient chained from
/// it becomes a per-example *sum* — the unit the data-parallel tree
/// reduction combines (`backend::GradOut`).
fn scale_to_sum(dz: &mut [f32], nb: usize) {
    let s = nb as f32;
    for v in dz.iter_mut() {
        *v *= s;
    }
}

/// Flat gradient-buffer layout of a spec: `(leaf name, length)` in the
/// canonical order `grad_step` concatenates and `apply_update` slices —
/// KPD slots contribute `[S, A, B]`, dense-parameterized slots `[W]`,
/// pattern specs one `[S, A, B]` triple per candidate, transformer specs
/// their slot layout followed by the dense extras (embeddings, LayerNorm
/// gains/biases, head).
pub fn grad_layout(cfg: &SpecConfig) -> Vec<(String, usize)> {
    if cfg.method == "pattern_kpd" {
        let mut out = Vec::new();
        for (p, d) in cfg.pattern_dims().iter().enumerate() {
            out.push((pattern::pname(p, "S"), d.m1 * d.n1));
            out.push((pattern::pname(p, "A"), d.r * d.m1 * d.n1));
            out.push((pattern::pname(p, "B"), d.r * d.m2 * d.n2));
        }
        return out;
    }
    let mut out = layers::grad_layout(cfg);
    if cfg.is_transformer() {
        out.extend(transformer::dense_extra_layout(cfg));
    }
    out
}

/// Per-block Frobenius norms on an (m2×n2) grid — the shared tensor-layer
/// kernel, re-exported under the short local name the step paths use.
fn block_fro(w: &[f32], m: usize, n: usize, m2: usize, n2: usize) -> Vec<f32> {
    crate::tensor::block_fro_norms_slice(w, m, n, m2, n2)
}

/// dw ⊙= expand(mask): zero gradient entries of inactive (m2×n2) blocks.
fn mul_expand_mask(dw: &mut [f32], mask: &[f32], m: usize, n: usize, m2: usize, n2: usize) {
    let n1 = n / n2;
    for i in 0..m {
        let mrow = &mask[(i / m2) * n1..(i / m2 + 1) * n1];
        let row = &mut dw[i * n..(i + 1) * n];
        for (j, v) in row.iter_mut().enumerate() {
            *v *= mrow[j / n2];
        }
    }
}

/// Block-group prox: shrink every (m2×n2) block of `w` toward zero by
/// `kappa` in Frobenius norm, zeroing blocks whose norm is below it.
fn block_prox(w: &mut [f32], m: usize, n: usize, m2: usize, n2: usize, kappa: f32) {
    if kappa <= 0.0 {
        return;
    }
    let norms = block_fro(w, m, n, m2, n2);
    let n1 = n / n2;
    for i in 0..m {
        let nrow = &norms[(i / m2) * n1..(i / m2 + 1) * n1];
        let row = &mut w[i * n..(i + 1) * n];
        for (j, v) in row.iter_mut().enumerate() {
            let norm = nrow[j / n2];
            if norm <= kappa {
                *v = 0.0;
            } else {
                *v *= 1.0 - kappa / norm;
            }
        }
    }
}

fn batch_xy<'a>(
    x: &'a HostValue,
    y: &'a HostValue,
    in_dim: usize,
) -> Result<(&'a [f32], usize, &'a [i32])> {
    let xt = x.as_f32()?;
    if xt.shape().len() != 2 || xt.shape()[1] != in_dim {
        bail!("native backend wants x of shape [batch, {in_dim}], got {:?}", xt.shape());
    }
    let nb = xt.shape()[0];
    if nb == 0 {
        bail!("empty batch");
    }
    let ys = match y {
        HostValue::I32 { shape, data } if shape.len() == 1 && shape[0] == nb => {
            data.as_slice()
        }
        _ => bail!("native backend wants i32 class-id labels of shape [{nb}]"),
    };
    Ok((xt.data(), nb, ys))
}

/// Token batch of a transformer spec: x and y are i32 id grids of shape
/// `[batch, seq]` (y = next-token targets, the `lm_dataset` layout).
fn batch_tokens<'a>(
    x: &'a HostValue,
    y: &'a HostValue,
    seq: usize,
) -> Result<(&'a [i32], usize, &'a [i32])> {
    let (toks, nb) = match x {
        HostValue::I32 { shape, data } if shape.len() == 2 && shape[1] == seq => {
            (data.as_slice(), shape[0])
        }
        _ => bail!("transformer spec wants i32 token ids of shape [batch, {seq}]"),
    };
    if nb == 0 {
        bail!("empty batch");
    }
    let targets = match y {
        HostValue::I32 { shape, data }
            if shape.len() == 2 && shape[0] == nb && shape[1] == seq =>
        {
            data.as_slice()
        }
        _ => bail!("transformer spec wants i32 target ids of shape [{nb}, {seq}]"),
    };
    Ok((toks, nb, targets))
}

struct Hyper {
    lam: f32,
    lam2: f32,
    lr: f32,
}

fn parse_hyper(entry: &SpecEntry, hyper: &[f32]) -> Result<Hyper> {
    if hyper.len() != entry.hyper.len() {
        bail!(
            "{} train_step wants hyper {:?}, got {} values",
            entry.key,
            entry.hyper,
            hyper.len()
        );
    }
    let mut out = Hyper { lam: 0.0, lam2: 0.0, lr: 0.0 };
    // names resolve through the shared HyperParam vocabulary, so this stays
    // in lockstep with the trainer's build_hyper on the other side
    for (name, &v) in entry.hyper.iter().zip(hyper) {
        match HyperParam::parse(name)? {
            HyperParam::Lambda1 => out.lam = v,
            HyperParam::Lambda2 => out.lam2 = v,
            HyperParam::Lr => out.lr = v,
        }
    }
    Ok(out)
}

// ---------------------------------------------------- Backend routing

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native-cpu".to_string()
    }

    fn specs(&self) -> Vec<&SpecEntry> {
        self.specs.values().map(|ns| &ns.entry).collect()
    }

    fn spec(&self, key: &str) -> Result<&SpecEntry> {
        Ok(&self.get(key)?.entry)
    }

    fn init_state(&self, spec: &str, seed: u32) -> Result<TrainState> {
        let ns = self.get(spec)?;
        let cfg = &ns.cfg;
        let mut rng = Rng::new((seed as u64) ^ fnv(&cfg.key));
        let (pn, ps, on, os) = if cfg.method == "pattern_kpd" {
            pattern::init_state_parts(&cfg.pattern_dims(), &mut rng)
        } else if cfg.is_transformer() {
            transformer::init_state_parts(cfg, &mut rng)
        } else {
            // linear and mlp specs are one-slot and N-slot stacks of the
            // same layer graph — one init path, bit-identical RNG order
            layers::init_state_parts(cfg, &mut rng)
        };
        Ok(TrainState {
            spec: spec.to_string(),
            param_names: pn,
            opt_names: on,
            params: ps,
            opt: os,
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &HostValue,
        y: &HostValue,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        let h = parse_hyper(&ns.entry, hyper)?;
        if ns.cfg.is_transformer() {
            let (toks, nb, targets) = batch_tokens(x, y, ns.cfg.seq)?;
            return transformer::train_step(&ns.cfg, state, toks, nb, targets, &h);
        }
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        match ns.cfg.method.as_str() {
            "pattern_kpd" => {
                pattern::train_step(&ns.cfg, state, xs, nb, ys, h.lam, h.lr, ns.cfg.momentum)
            }
            _ => layers::train_step(&ns.cfg, state, xs, nb, ys, &h),
        }
    }

    fn eval_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        if ns.cfg.is_transformer() {
            let (toks, nb, targets) = batch_tokens(x, y, ns.cfg.seq)?;
            // [per-token mean CE, correct token count] — the trainer's
            // evaluate divides by examples·seq for token-level accuracy
            return transformer::eval_step(&ns.cfg, state, toks, nb, targets);
        }
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        if ns.cfg.method == "pattern_kpd" {
            // per-pattern layout [ce_0..ce_{K-1}, correct_0..correct_{K-1}]
            return pattern::eval_step(&ns.cfg, state, xs, nb, ys);
        }
        let z = layers::forward_logits(&ns.cfg, state, xs, nb)?;
        let sm = linalg::softmax_ce(&z, ys, nb, ns.cfg.out_dim)?;
        Ok(vec![sm.ce_mean, sm.correct])
    }

    fn materialize(&self, state: &TrainState) -> Result<Vec<(String, Tensor)>> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.method == "pattern_kpd" {
            // survivor extraction: the max-retention candidate's dense W
            let (p, w) = pattern::materialize_survivor(state, &cfg.pattern_dims())?;
            crate::debug!("{}: materializing surviving pattern k={p}", cfg.key);
            return Ok(vec![("fc".to_string(), w)]);
        }
        // every slot of the layer graph — for transformers that is the
        // q/k/v/o/FFN projection stack (the block-sparse weights; the
        // dense extras live in the training checkpoint, not the export)
        layers::materialize(cfg, state)
    }

    fn rigl_update(&self, state: &mut TrainState, gnorm: &[f32], alpha: f32) -> Result<()> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.method != "rigl_block" {
            bail!("rigl_update on non-RigL spec '{}'", state.spec);
        }
        // per-slot drop/grow on the concatenated gradient-norm layout
        layers::rigl_update(cfg, state, gnorm, alpha)
    }

    fn prune(&self, state: &mut TrainState, target: f32) -> Result<()> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.method != "iter_prune" {
            bail!("prune on non-pruning spec '{}'", state.spec);
        }
        if !(0.0..1.0).contains(&target) {
            bail!("prune target {target} outside [0, 1)");
        }
        // global magnitude ranking across every slot (standard
        // whole-model iterative pruning)
        layers::prune(cfg, state, target)
    }

    fn gnorm_len(&self, spec: &str) -> Result<usize> {
        let ns = self.get(spec)?;
        if ns.cfg.method != "rigl_block" {
            return Ok(0);
        }
        Ok(layers::gnorm_len(&ns.cfg))
    }

    fn supports_grad_step(&self, spec: &str) -> bool {
        // every native family (single-slot, mlp, pattern) has a separable
        // gradient path
        self.get(spec).is_ok()
    }

    fn grad_len(&self, spec: &str) -> Result<usize> {
        Ok(grad_layout(&self.get(spec)?.cfg).iter().map(|(_, l)| l).sum())
    }

    fn grad_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<GradOut> {
        let ns = self.get(&state.spec)?;
        if ns.cfg.is_transformer() {
            let (toks, nb, targets) = batch_tokens(x, y, ns.cfg.seq)?;
            return transformer::grad_step(&ns.cfg, state, toks, nb, targets);
        }
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        match ns.cfg.method.as_str() {
            "pattern_kpd" => pattern::grad_step(&ns.cfg, state, xs, nb, ys),
            _ => layers::grad_step(&ns.cfg, state, xs, nb, ys),
        }
    }

    fn apply_update(
        &self,
        state: &mut TrainState,
        grad: Vec<f32>,
        ce_mean: f32,
        acc_frac: f32,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        let h = parse_hyper(&ns.entry, hyper)?;
        let want = self.grad_len(&state.spec)?;
        if grad.len() != want {
            bail!(
                "apply_update on '{}': gradient buffer has {} values, layout wants {want}",
                state.spec,
                grad.len()
            );
        }
        if ns.cfg.is_transformer() {
            return transformer::apply_update(&ns.cfg, state, &grad, ce_mean, acc_frac, &h);
        }
        match ns.cfg.method.as_str() {
            "pattern_kpd" => pattern::apply_update(
                state,
                &grad,
                &ns.cfg.pattern_dims(),
                ce_mean,
                acc_frac,
                h.lam,
                h.lr,
                ns.cfg.momentum,
            ),
            _ => layers::apply_update(&ns.cfg, state, &grad, ce_mean, acc_frac, &h),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(nb: usize, in_dim: usize, classes: usize, seed: u64) -> (HostValue, HostValue) {
        let mut rng = Rng::new(seed);
        let x = Tensor::from_fn(&[nb, in_dim], |_| rng.normal());
        let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
        (HostValue::F32(x), HostValue::I32 { shape: vec![nb], data: y })
    }

    #[test]
    fn default_registry_has_table1_specs() {
        let be = NativeBackend::with_default_specs();
        assert!(be.spec("qs_kpd").is_ok());
        assert!(be.spec("t1_kpd_b16x2").is_ok());
        assert!(be.spec("t1_rigl_b2x2").is_ok());
        assert!(be.spec("t4_linear_r6").is_ok());
        assert!(be.spec("nope").is_err());
        let e = be.spec("t1_kpd_b16x2").unwrap();
        assert_eq!(e.block_of("fc"), Some((2, 16)));
        assert_eq!(e.rank(), Some(2));
        assert!(e.params_total < 7840);
    }

    #[test]
    fn t2_mlp_registry_layout() {
        let be = NativeBackend::with_default_specs();
        for combo in
            ["16x8_8x4_4x2", "8x4_4x4_2x2", "4x4_4x4_2x2", "4x4_2x2_2x2", "2x2_2x2_2x2"]
        {
            for m in ["kpd", "gl", "egl", "rigl"] {
                assert!(be.spec(&format!("t2_{m}_{combo}")).is_ok(), "t2_{m}_{combo}");
            }
        }
        let e = be.spec("t2_kpd_16x8_8x4_4x2").unwrap().clone();
        assert_eq!(e.model, "mlp");
        assert_eq!(e.slots.len(), 3);
        assert_eq!(e.slots[0].m, 304);
        assert_eq!(e.slots[0].n, 784);
        assert_eq!(e.block_of("fc1"), Some((8, 16)));
        assert_eq!(e.block_of("fc3"), Some((2, 4)));
        // per-layer ‖S‖₁ metrics follow the whole-model one
        assert_eq!(e.metric_index("s_l1"), Some(3));
        assert_eq!(e.metric_index("s_l1_fc2"), Some(5));
        // factorized training params far below the dense stack (Table 2's
        // params column: "Ours" 6-23K vs 61K dense at LeNet scale)
        let dense = be.spec("t2_dense").unwrap();
        assert_eq!(dense.model, "mlp");
        assert!(
            e.params_total < dense.params_total / 4,
            "{} vs dense {}",
            e.params_total,
            dense.params_total
        );
        assert!(be.spec("t2_prune").is_ok());
    }

    #[test]
    fn mlp_config_validation() {
        // width chain must tile per-layer blocks
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(2, 3), (2, 2)], 2, 8)
            .validate()
            .is_ok());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(3, 3), (2, 2)], 2, 8)
            .validate()
            .is_err());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(2, 5), (2, 2)], 2, 8)
            .validate()
            .is_err());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[], 0, 8).validate().is_err());
        assert!(SpecConfig::mlp("m", "pattern_kpd", &[12, 8, 4], &[], 1, 8)
            .validate()
            .is_err());
        // broken chain caught even when built by hand
        let mut cfg = SpecConfig::mlp("m", "dense", &[12, 8, 4], &[], 1, 8);
        cfg.layers[1].n = 6;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn init_is_seed_deterministic_and_s_starts_at_one() {
        let be = NativeBackend::with_default_specs();
        let a = be.init_state("qs_kpd", 7).unwrap();
        let b = be.init_state("qs_kpd", 7).unwrap();
        let c = be.init_state("qs_kpd", 8).unwrap();
        assert_eq!(a.param("fc.A").unwrap().data(), b.param("fc.A").unwrap().data());
        assert_ne!(a.param("fc.A").unwrap().data(), c.param("fc.A").unwrap().data());
        assert!(a.param("fc.S").unwrap().data().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn every_method_takes_a_finite_step() {
        let be = NativeBackend::with_default_specs();
        for spec in
            ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_egl_b2x2", "t1_rigl_b2x2", "t1_prune", "t1_dense"]
        {
            let entry = be.spec(spec).unwrap().clone();
            let mut state = be.init_state(spec, 0).unwrap();
            let (x, y) = batch(16, 784, 10, 3);
            let hyper: Vec<f32> = entry
                .hyper
                .iter()
                .map(|h| match h.as_str() {
                    "lr" => 0.05,
                    "lambda2" => 1e-4,
                    _ => 0.01,
                })
                .collect();
            let m = be.train_step(&mut state, &x, &y, &hyper).unwrap();
            assert_eq!(m.len(), entry.metrics.len(), "{spec}");
            assert!(m.iter().all(|v| v.is_finite()), "{spec}: {m:?}");
            let e = be.eval_step(&state, &x, &y).unwrap();
            assert!(e[0].is_finite());
            assert!(e[1] >= 0.0 && e[1] <= 16.0);
        }
    }

    #[test]
    fn rigl_update_preserves_active_count() {
        let be = NativeBackend::with_default_specs();
        let mut state = be.init_state("t1_rigl_b2x2", 0).unwrap();
        let mask0 = state.param("fc.mask").unwrap().clone();
        let nnz0: f32 = mask0.data().iter().sum();
        let gnorm: Vec<f32> = (0..mask0.len()).map(|i| (i as f32 * 0.37 + 0.01) % 5.0).collect();
        be.rigl_update(&mut state, &gnorm, 0.3).unwrap();
        let mask1 = state.param("fc.mask").unwrap().clone();
        let nnz1: f32 = mask1.data().iter().sum();
        assert_eq!(nnz0, nnz1, "active block count changed");
        assert!(mask0.max_abs_diff(&mask1) > 0.0, "mask did not change");
    }

    #[test]
    fn prune_hits_exact_target() {
        let be = NativeBackend::with_default_specs();
        let mut state = be.init_state("t1_prune", 0).unwrap();
        be.prune(&mut state, 0.6).unwrap();
        let emask = state.param("fc.emask").unwrap().clone();
        let sparsity = crate::sparsity::mask_sparsity(&emask);
        assert!((sparsity - 0.6).abs() < 0.001, "sparsity {sparsity}");
        // pruned weights are zeroed
        let w = state.param("fc.W").unwrap();
        for (wv, mv) in w.data().iter().zip(emask.data()) {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0);
            }
        }
    }

    #[test]
    fn materialize_shapes_per_method() {
        let be = NativeBackend::with_default_specs();
        for spec in ["qs_kpd", "t1_gl_b2x2", "t1_rigl_b2x2", "t1_prune", "t1_dense"] {
            let state = be.init_state(spec, 1).unwrap();
            let ws = be.materialize(&state).unwrap();
            assert_eq!(ws.len(), 1);
            assert_eq!(ws[0].0, "fc");
            assert_eq!(ws[0].1.shape(), &[10, 784], "{spec}");
        }
    }

    #[test]
    fn pattern_spec_registered_with_fig3_layout() {
        let be = NativeBackend::with_default_specs();
        let e = be.spec("f3a_pattern").unwrap().clone();
        assert_eq!(e.method, "pattern_kpd");
        assert_eq!(e.num_patterns(), Some(4));
        // metrics: [loss, ce, acc, s_l1_p0..s_l1_p3]
        assert_eq!(e.metrics.len(), 7);
        assert_eq!(e.metric_index("s_l1_p3"), Some(6));
        assert_eq!(e.hyper, vec!["lambda".to_string(), "lr".to_string()]);
        // params_total = Σ_k candidate factorization params
        let cfg = SpecConfig::pattern(
            "x", 784, 10, &[(2, 2), (2, 4), (2, 8), (2, 16)], 1, 128,
        );
        let want: usize =
            cfg.pattern_dims().iter().map(|d| d.train_params() as usize).sum();
        assert_eq!(e.params_total, want);
    }

    #[test]
    fn pattern_spec_trains_evals_and_materializes() {
        let be = NativeBackend::with_default_specs();
        let e = be.spec("f3a_pattern").unwrap().clone();
        let mut state = be.init_state("f3a_pattern", 0).unwrap();
        let (x, y) = batch(16, 784, 10, 3);
        let m = be.train_step(&mut state, &x, &y, &[0.01, 0.05]).unwrap();
        assert_eq!(m.len(), e.metrics.len());
        assert!(m.iter().all(|v| v.is_finite()), "{m:?}");
        // the per-pattern eval layout Trainer::evaluate expects: 2K values
        let ev = be.eval_step(&state, &x, &y).unwrap();
        assert_eq!(ev.len(), 8);
        for p in 0..4 {
            assert!(ev[p] > 0.0, "ce_{p} must be positive");
            assert!((0.0..=16.0).contains(&ev[4 + p]), "correct_{p} out of range");
        }
        // survivor extraction: exactly one dense fc slot at the full shape
        let ws = be.materialize(&state).unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].0, "fc");
        assert_eq!(ws[0].1.shape(), &[10, 784]);
        // pattern probes read the p{k}.fc.S layout
        let norms = crate::coordinator::probe::pattern_s_norms(&e, &state).unwrap();
        assert_eq!(norms.len(), 4);
        assert!(norms.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn pattern_config_validation() {
        assert!(SpecConfig::pattern("p", 784, 10, &[], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(3, 2)], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 3)], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 4)], 0, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 4)], 2, 64).validate().is_ok());
        // candidates on a non-pattern method are rejected
        let mut cfg = SpecConfig::linear("q", "kpd", 784, 10, 2, 4, 2, 64);
        cfg.patterns = vec![(2, 4)];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn momentum_buffers_populate_after_one_step() {
        let cfg = SpecConfig::linear("mom", "dense", 8, 4, 1, 1, 1, 4);
        let be = NativeBackend::from_spec(cfg).unwrap();
        let mut state = be.init_state("mom", 0).unwrap();
        let (x, y) = batch(4, 8, 4, 11);
        be.train_step(&mut state, &x, &y, &[0.1]).unwrap();
        let v = &state.opt[0];
        assert!(v.data().iter().any(|&g| g != 0.0), "velocity stayed zero");
    }

    /// The fused optimizer sweeps must be *bit-identical* to the old
    /// two-sweep formulations they replaced — this is what keeps every
    /// golden-pinned run valid across the fusion refactor.
    #[test]
    fn fused_updates_match_two_sweep_reference() {
        let mut rng = Rng::new(77);
        let (m, n, m2, n2) = (6usize, 8usize, 2usize, 4usize);
        let len = m * n;
        let p0: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let v0: Vec<f32> = (0..len).map(|_| rng.normal() * 0.1).collect();
        let g: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let (lr, mu, lam2, t) = (0.07f32, 0.9f32, 1e-3f32, 0.05f32);

        // sgd_prox_l1 vs SGD sweep + the old standalone soft-threshold
        // sweep (prox of t·‖·‖₁)
        let mut fused = p0.clone();
        sgd_prox_l1(&mut fused, &g, lr, t);
        let mut reference = p0.clone();
        for (p, gi) in reference.iter_mut().zip(&g) {
            *p -= lr * gi;
        }
        for v in reference.iter_mut() {
            *v = v.signum() * (v.abs() - t).max(0.0);
        }
        assert_eq!(fused, reference, "sgd_prox_l1");
        // t = 0 degenerates to plain SGD
        let mut plain = p0.clone();
        sgd_prox_l1(&mut plain, &g, lr, 0.0);
        assert_eq!(plain, p0.iter().zip(&g).map(|(p, gi)| p - lr * gi).collect::<Vec<_>>());

        // sgd_momentum_l2 vs g += λ₂·w sweep + sgd_momentum
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_l2(&mut fp, &mut fv, &g, lr, mu, lam2);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let mut g2 = g.clone();
        for (gi, wv) in g2.iter_mut().zip(&p0) {
            *gi += lam2 * wv;
        }
        sgd_momentum(&mut rp, &mut rv, &g2, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_l2 params");
        assert_eq!(fv, rv, "sgd_momentum_l2 velocity");

        // sgd_momentum_masked vs g ⊙ mask sweep + sgd_momentum
        let emask: Vec<f32> = (0..len).map(|i| (i % 3 == 0) as u8 as f32).collect();
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_masked(&mut fp, &mut fv, &g, &emask, lr, mu);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let gm: Vec<f32> = g.iter().zip(&emask).map(|(gi, mv)| gi * mv).collect();
        sgd_momentum(&mut rp, &mut rv, &gm, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_masked params");
        assert_eq!(fv, rv, "sgd_momentum_masked velocity");

        // sgd_momentum_block_masked vs mul_expand_mask + sgd_momentum
        let mask: Vec<f32> = (0..(m / m2) * (n / n2)).map(|i| (i % 2) as f32).collect();
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_block_masked(&mut fp, &mut fv, &g, &mask, m, n, m2, n2, lr, mu);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let mut gb = g.clone();
        mul_expand_mask(&mut gb, &mask, m, n, m2, n2);
        sgd_momentum(&mut rp, &mut rv, &gb, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_block_masked params");
        assert_eq!(fv, rv, "sgd_momentum_block_masked velocity");
    }

    #[test]
    fn param_pair_mut_borrows_both_orders() {
        let mut params = vec![Tensor::full(&[2], 1.0), Tensor::full(&[2], 2.0)];
        {
            let (a, b) = param_pair_mut(&mut params, 0, 1);
            a.data_mut()[0] = 5.0;
            assert_eq!(b.data()[0], 2.0);
        }
        let (a, b) = param_pair_mut(&mut params, 1, 0);
        a.data_mut()[0] = 7.0;
        assert_eq!(b.data()[0], 5.0);
        assert_eq!(params[0].data()[0], 5.0);
        assert_eq!(params[1].data()[0], 7.0);
    }
}
