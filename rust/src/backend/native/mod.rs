//! Pure-Rust execution backend: the default, hermetic way to train.
//!
//! Implements the paper's linear-spec methods directly on host tensors —
//! no AOT artifacts, no PJRT:
//!
//! * `kpd`          — factorized forward/backward (module [`kpd`]) with the
//!                    ℓ1-on-S proximal (soft-threshold) update;
//! * `pattern_kpd`  — joint multi-pattern training (module [`pattern`]):
//!                    K block-size candidates share the input, sum logits,
//!                    and each takes the ℓ1-on-S prox — Eq. 7 / Figure 3;
//! * `group_lasso` / `elastic_gl` — dense W with the block-group proximal
//!                    shrink (and ridge term for elastic);
//! * `rigl_block`   — block-masked W via the block-sparse matmul, dense
//!                    gradient-norm metrics for the mask controller;
//! * `iter_prune`   — elementwise-masked W, magnitude pruning to a target;
//! * `dense`        — the unregularized baseline.
//!
//! Specs are registered from [`SpecConfig`]s (manifest-free), so tests and
//! the CLI can construct and train models without any build-time python.
//! Optimization is SGD with classical momentum; the regularized leaves
//! (S, W-blocks) use plain SGD plus their proximal operator so exact
//! zeros appear.
//!
//! Beyond the single linear slot, every method also runs on sequential
//! **multi-layer** models (the `mlp` spec family, module [`layers`]):
//! a stack of linear slots with ReLU between them, per-layer block sizes,
//! a shared forward that caches activations and a backward that chains dZ
//! through the stack. The built-in registry uses it for the Table-2
//! `t2_*` specs (784→304→100→10, the LeNet-300-100 stand-in).

pub mod kpd;
pub mod layers;
pub mod linalg;
pub mod pattern;
pub mod simd;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::flops::KpdDims;
use crate::manifest::{HyperParam, SlotInfo, SpecEntry};
use crate::tensor::{DType, HostValue, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{Backend, GradOut, TrainState};

const METHODS: &[&str] = &[
    "kpd",
    "pattern_kpd",
    "group_lasso",
    "elastic_gl",
    "rigl_block",
    "iter_prune",
    "dense",
];

/// One linear slot of a multi-layer (`mlp`) spec: a W ∈ R^{m×n} with its
/// own (m2, n2) block size. The method decides the parameterization
/// (KPD factors / dense W / masked W), shared across the whole stack.
#[derive(Clone, Debug)]
pub struct LayerCfg {
    /// slot name (`fc1`, `fc2`, ...) — the parameter-name prefix
    pub name: String,
    /// output features
    pub m: usize,
    /// input features
    pub n: usize,
    /// block rows
    pub m2: usize,
    /// block cols
    pub n2: usize,
}

impl LayerCfg {
    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.m2, self.n / self.n2)
    }

    /// KPD dims of this slot at the spec rank (clamped by the Eq. 2 bound).
    pub fn dims(&self, rank: usize) -> KpdDims {
        KpdDims::from_block(self.m, self.n, self.m2, self.n2, rank.max(1))
    }
}

/// Manifest-free description of one trainable linear spec.
#[derive(Clone, Debug)]
pub struct SpecConfig {
    pub key: String,
    /// one of `kpd | group_lasso | elastic_gl | rigl_block | iter_prune | dense`
    pub method: String,
    /// input features n (= n1·n2)
    pub in_dim: usize,
    /// classes m (= m1·m2)
    pub out_dim: usize,
    /// block rows m2
    pub m2: usize,
    /// block cols n2
    pub n2: usize,
    /// KPD decomposition rank r
    pub rank: usize,
    pub batch: usize,
    /// classical momentum for the smooth parameters (0 = plain SGD)
    pub momentum: f32,
    /// initial fraction of active blocks for `rigl_block`
    pub rigl_density: f64,
    /// candidate `(m2, n2)` block sizes for `pattern_kpd` (empty otherwise)
    pub patterns: Vec<(usize, usize)>,
    /// sequential linear slots of an `mlp` spec (ReLU between consecutive
    /// slots); empty for the single-slot linear specs
    pub layers: Vec<LayerCfg>,
    pub tags: Vec<String>,
}

impl SpecConfig {
    /// A linear classifier spec with repo-standard defaults.
    #[allow(clippy::too_many_arguments)]
    pub fn linear(
        key: &str,
        method: &str,
        in_dim: usize,
        out_dim: usize,
        m2: usize,
        n2: usize,
        rank: usize,
        batch: usize,
    ) -> Self {
        SpecConfig {
            key: key.to_string(),
            method: method.to_string(),
            in_dim,
            out_dim,
            m2,
            n2,
            rank,
            batch,
            momentum: 0.9,
            rigl_density: 0.5,
            patterns: Vec::new(),
            layers: Vec::new(),
            tags: Vec::new(),
        }
    }

    /// A sequential multi-layer perceptron spec: `widths` gives the layer
    /// widths (e.g. `[784, 304, 100, 10]` → three linear slots `fc1..fc3`
    /// with ReLU between them), `blocks[i]` the (m2, n2) block size of
    /// slot i (missing entries default to 1×1 — elementwise). The method
    /// applies to every slot; `rank` is shared and clamped per slot.
    pub fn mlp(
        key: &str,
        method: &str,
        widths: &[usize],
        blocks: &[(usize, usize)],
        rank: usize,
        batch: usize,
    ) -> Self {
        assert!(widths.len() >= 2, "mlp needs at least input and output widths");
        let mut cfg = SpecConfig::linear(
            key,
            method,
            widths[0],
            *widths.last().unwrap(),
            1,
            1,
            rank,
            batch,
        );
        cfg.layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| LayerCfg {
                name: format!("fc{}", i + 1),
                m: w[1],
                n: w[0],
                m2: blocks.get(i).map(|b| b.0).unwrap_or(1),
                n2: blocks.get(i).map(|b| b.1).unwrap_or(1),
            })
            .collect();
        cfg
    }

    /// Whether this spec is a sequential multi-layer model.
    pub fn is_mlp(&self) -> bool {
        !self.layers.is_empty()
    }

    /// A joint pattern-selection spec (Eq. 7): K candidate block sizes of
    /// one linear layer trained together with summed logits.
    pub fn pattern(
        key: &str,
        in_dim: usize,
        out_dim: usize,
        patterns: &[(usize, usize)],
        rank: usize,
        batch: usize,
    ) -> Self {
        let mut cfg = SpecConfig::linear(key, "pattern_kpd", in_dim, out_dim, 1, 1, rank, batch);
        cfg.patterns = patterns.to_vec();
        cfg
    }

    pub fn validate(&self) -> Result<()> {
        if !METHODS.contains(&self.method.as_str()) {
            bail!("unknown method '{}' (native backend supports {METHODS:?})", self.method);
        }
        if self.is_mlp() {
            if self.method == "pattern_kpd" {
                bail!("pattern_kpd is a single-slot method (no mlp support yet)");
            }
            if !self.patterns.is_empty() {
                bail!("block-size candidates only apply to the pattern_kpd method");
            }
            if self.batch == 0 {
                bail!("batch must be positive");
            }
            if self.method == "kpd" && self.rank == 0 {
                bail!("kpd rank must be ≥ 1");
            }
            if self.layers[0].n != self.in_dim {
                bail!("mlp first slot wants {} inputs, spec has in_dim {}",
                      self.layers[0].n, self.in_dim);
            }
            if self.layers.last().unwrap().m != self.out_dim {
                bail!("mlp last slot emits {} features, spec has out_dim {}",
                      self.layers.last().unwrap().m, self.out_dim);
            }
            for (i, l) in self.layers.iter().enumerate() {
                if l.m == 0 || l.n == 0 {
                    bail!("slot '{}' has a zero dimension", l.name);
                }
                if l.m2 == 0 || l.m % l.m2 != 0 {
                    bail!("slot '{}': block rows {} do not tile {}", l.name, l.m2, l.m);
                }
                if l.n2 == 0 || l.n % l.n2 != 0 {
                    bail!("slot '{}': block cols {} do not tile {}", l.name, l.n2, l.n);
                }
                if i > 0 && self.layers[i - 1].m != l.n {
                    bail!(
                        "slot '{}' wants {} inputs but '{}' emits {}",
                        l.name, l.n, self.layers[i - 1].name, self.layers[i - 1].m
                    );
                }
            }
            return Ok(());
        }
        if self.m2 == 0 || self.out_dim % self.m2 != 0 {
            bail!("block rows {} do not tile out_dim {}", self.m2, self.out_dim);
        }
        if self.n2 == 0 || self.in_dim % self.n2 != 0 {
            bail!("block cols {} do not tile in_dim {}", self.n2, self.in_dim);
        }
        if self.batch == 0 {
            bail!("batch must be positive");
        }
        if (self.method == "kpd" || self.method == "pattern_kpd") && self.rank == 0 {
            bail!("{} rank must be ≥ 1", self.method);
        }
        if self.method == "pattern_kpd" {
            if self.patterns.is_empty() {
                bail!("pattern_kpd needs at least one (m2, n2) candidate");
            }
            for &(m2, n2) in &self.patterns {
                if m2 == 0 || self.out_dim % m2 != 0 {
                    bail!("pattern block rows {m2} do not tile out_dim {}", self.out_dim);
                }
                if n2 == 0 || self.in_dim % n2 != 0 {
                    bail!("pattern block cols {n2} do not tile in_dim {}", self.in_dim);
                }
            }
        } else if !self.patterns.is_empty() {
            bail!("block-size candidates only apply to the pattern_kpd method");
        }
        if !(0.0..=1.0).contains(&self.rigl_density) {
            bail!("rigl_density must be in [0, 1]");
        }
        Ok(())
    }

    pub fn dims(&self) -> KpdDims {
        KpdDims::from_block(self.out_dim, self.in_dim, self.m2, self.n2, self.rank.max(1))
    }

    /// KPD dims of every candidate pattern (`pattern_kpd` specs).
    pub fn pattern_dims(&self) -> Vec<KpdDims> {
        self.patterns
            .iter()
            .map(|&(m2, n2)| {
                KpdDims::from_block(self.out_dim, self.in_dim, m2, n2, self.rank.max(1))
            })
            .collect()
    }

    fn grid(&self) -> (usize, usize) {
        (self.out_dim / self.m2, self.in_dim / self.n2)
    }
}

struct NativeSpec {
    cfg: SpecConfig,
    entry: SpecEntry,
}

/// The native (pure-Rust, CPU) backend: a registry of [`SpecConfig`]s.
pub struct NativeBackend {
    specs: BTreeMap<String, NativeSpec>,
}

impl NativeBackend {
    /// Empty registry; add specs with [`NativeBackend::add_spec`].
    pub fn empty() -> Self {
        NativeBackend { specs: BTreeMap::new() }
    }

    /// Single-spec backend (the manifest-free test constructor).
    pub fn from_spec(cfg: SpecConfig) -> Result<Self> {
        let mut be = NativeBackend::empty();
        be.add_spec(cfg)?;
        Ok(be)
    }

    pub fn add_spec(&mut self, cfg: SpecConfig) -> Result<()> {
        let entry = build_entry(&cfg)?;
        self.specs.insert(cfg.key.clone(), NativeSpec { cfg, entry });
        Ok(())
    }

    /// The built-in linear-model registry mirroring the Table-1/Table-4
    /// spec keys of the AOT manifest, so the CLI and benches run offline.
    pub fn with_default_specs() -> Self {
        let mut be = NativeBackend::empty();
        let mut add = |mut cfg: SpecConfig, tag: &str| {
            cfg.tags = vec![tag.to_string()];
            be.add_spec(cfg).expect("default spec registry");
        };
        add(SpecConfig::linear("qs_kpd", "kpd", 784, 10, 2, 16, 2, 64), "quickstart");
        for (bk, n2) in [("b2x2", 2usize), ("b4x2", 4), ("b8x2", 8), ("b16x2", 16)] {
            add(
                SpecConfig::linear(&format!("t1_kpd_{bk}"), "kpd", 784, 10, 2, n2, 2, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_gl_{bk}"), "group_lasso", 784, 10, 2, n2, 1, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_egl_{bk}"), "elastic_gl", 784, 10, 2, n2, 1, 128),
                "table1",
            );
            add(
                SpecConfig::linear(&format!("t1_rigl_{bk}"), "rigl_block", 784, 10, 2, n2, 1, 128),
                "table1",
            );
        }
        add(SpecConfig::linear("t1_prune", "iter_prune", 784, 10, 1, 1, 1, 128), "table1");
        add(SpecConfig::linear("t1_dense", "dense", 784, 10, 1, 1, 1, 128), "table1");
        for r in [1usize, 2, 4, 6] {
            add(
                SpecConfig::linear(&format!("t4_linear_r{r}"), "kpd", 784, 10, 2, 16, r, 128),
                "table4",
            );
        }
        // Table 2 natively: a 784→304→100→10 MLP stands in for the paper's
        // LeNet FC stack (LeNet-300-100 shape, first hidden width rounded
        // 300→304 so the coarsest paper combo's 8-row blocks tile it).
        // Per-combo blocks follow the paper's "(a, b)" → (m2, n2) = (b, a)
        // label convention (see python/compile/specs.py); rank 5 like the
        // AOT t2 specs, clamped per slot by the Eq. 2 bound.
        let t2_widths = [784usize, 304, 100, 10];
        let t2_combos: [(&str, [(usize, usize); 3]); 5] = [
            ("16x8_8x4_4x2", [(8, 16), (4, 8), (2, 4)]),
            ("8x4_4x4_2x2", [(4, 8), (4, 4), (2, 2)]),
            ("4x4_4x4_2x2", [(4, 4), (4, 4), (2, 2)]),
            ("4x4_2x2_2x2", [(4, 4), (2, 2), (2, 2)]),
            ("2x2_2x2_2x2", [(2, 2), (2, 2), (2, 2)]),
        ];
        for (name, blocks) in t2_combos {
            for (short, method) in [
                ("kpd", "kpd"),
                ("gl", "group_lasso"),
                ("egl", "elastic_gl"),
                ("rigl", "rigl_block"),
            ] {
                add(
                    SpecConfig::mlp(
                        &format!("t2_{short}_{name}"),
                        method,
                        &t2_widths,
                        &blocks,
                        5,
                        64,
                    ),
                    "table2",
                );
            }
        }
        add(SpecConfig::mlp("t2_prune", "iter_prune", &t2_widths, &[], 1, 64), "table2");
        add(SpecConfig::mlp("t2_dense", "dense", &t2_widths, &[], 1, 64), "table2");
        // Figure 3a: the Table-1 block-size grid trained jointly (Eq. 7).
        // Rank 1 gives the sharpest capacity cliff between candidates: a
        // rank-1 coarse-block teacher is exactly representable at its own
        // block size but only partially at any other, which is what makes
        // block-size *selection* well-posed.
        add(
            SpecConfig::pattern(
                "f3a_pattern",
                784,
                10,
                &[(2, 2), (2, 4), (2, 8), (2, 16)],
                1,
                128,
            ),
            "fig3",
        );
        be
    }

    fn get(&self, key: &str) -> Result<&NativeSpec> {
        self.specs
            .get(key)
            .ok_or_else(|| anyhow!("spec '{key}' not registered with the native backend"))
    }
}

// ------------------------------------------------------------ spec entry

fn build_entry(cfg: &SpecConfig) -> Result<SpecEntry> {
    cfg.validate()?;
    if cfg.is_mlp() {
        return build_mlp_entry(cfg);
    }
    let (m, n) = (cfg.out_dim, cfg.in_dim);
    let (m1, n1) = cfg.grid();
    let mut metrics: Vec<String> =
        ["loss", "ce", "acc"].iter().map(|s| s.to_string()).collect();
    let hyper: Vec<String> = match cfg.method.as_str() {
        "kpd" => {
            metrics.push("s_l1".to_string());
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "pattern_kpd" => {
            // the Figure-3 series: one ‖S^(k)‖₁ metric per candidate
            metrics.extend((0..cfg.patterns.len()).map(|p| format!("s_l1_p{p}")));
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "group_lasso" => vec!["lambda".to_string(), "lr".to_string()],
        "elastic_gl" => {
            vec!["lambda".to_string(), "lambda2".to_string(), "lr".to_string()]
        }
        "rigl_block" => {
            metrics.extend((0..m1 * n1).map(|i| format!("gnorm{i}")));
            vec!["lr".to_string()]
        }
        _ => vec!["lr".to_string()],
    };
    let params_total = match cfg.method.as_str() {
        "kpd" => cfg.dims().train_params() as usize,
        "pattern_kpd" => {
            cfg.pattern_dims().iter().map(|d| d.train_params() as usize).sum()
        }
        _ => m * n,
    };
    let mut info = BTreeMap::new();
    if cfg.method == "pattern_kpd" {
        // layout consumed by `experiment::accounting` and `Trainer`:
        // num_patterns + per-candidate {slot: [m2, n2]} entries
        info.insert(
            "num_patterns".to_string(),
            Json::Num(cfg.patterns.len() as f64),
        );
        info.insert(
            "patterns".to_string(),
            Json::Arr(
                cfg.patterns
                    .iter()
                    .map(|&(m2, n2)| {
                        let mut pat = BTreeMap::new();
                        pat.insert(
                            "fc".to_string(),
                            Json::Arr(vec![Json::Num(m2 as f64), Json::Num(n2 as f64)]),
                        );
                        Json::Obj(pat)
                    })
                    .collect(),
            ),
        );
        info.insert("rank".to_string(), Json::Num(cfg.rank.max(1) as f64));
    } else {
        let mut blocks = BTreeMap::new();
        blocks.insert(
            "fc".to_string(),
            Json::Arr(vec![Json::Num(cfg.m2 as f64), Json::Num(cfg.n2 as f64)]),
        );
        info.insert("blocks".to_string(), Json::Obj(blocks));
    }
    if cfg.method == "kpd" {
        let d = cfg.dims();
        info.insert("rank".to_string(), Json::Num(d.r as f64));
        let mut shape = BTreeMap::new();
        shape.insert("m1".to_string(), Json::Num(d.m1 as f64));
        shape.insert("n1".to_string(), Json::Num(d.n1 as f64));
        shape.insert("m2".to_string(), Json::Num(d.m2 as f64));
        shape.insert("n2".to_string(), Json::Num(d.n2 as f64));
        shape.insert("r".to_string(), Json::Num(d.r as f64));
        let mut shapes = BTreeMap::new();
        shapes.insert("fc".to_string(), Json::Obj(shape));
        info.insert("shapes".to_string(), Json::Obj(shapes));
    }
    Ok(SpecEntry {
        key: cfg.key.clone(),
        model: "linear".to_string(),
        batch: cfg.batch,
        tags: cfg.tags.clone(),
        input_shape: vec![n],
        input_dtype: DType::F32,
        num_classes: m,
        slots: vec![SlotInfo { name: "fc".to_string(), m, n }],
        method: cfg.method.clone(),
        hyper,
        metrics,
        params_total,
        info: Json::Obj(info),
    })
}

/// Spec entry for the sequential multi-layer (`mlp`) family. Per-slot
/// block sizes land in `info.blocks` (what the sparsity probe reads) and,
/// for KPD, per-slot factorization shapes in `info.shapes` (what the
/// FLOPs accounting reads). KPD specs report per-layer ‖S‖₁ metrics
/// (`s_l1_fc1`, ...) after the whole-model `s_l1`. RigL specs append the
/// concatenated per-slot block gradient norms to the train metrics like
/// the single-slot path, but the tail stays *unnamed* in the registry —
/// fine-block MLP grids reach ~10⁵ blocks and naming each would bloat
/// every registry construction; `Backend::gnorm_len` is the contract.
fn build_mlp_entry(cfg: &SpecConfig) -> Result<SpecEntry> {
    let mut metrics: Vec<String> =
        ["loss", "ce", "acc"].iter().map(|s| s.to_string()).collect();
    let hyper: Vec<String> = match cfg.method.as_str() {
        "kpd" => {
            metrics.push("s_l1".to_string());
            metrics.extend(cfg.layers.iter().map(|l| format!("s_l1_{}", l.name)));
            vec!["lambda".to_string(), "lr".to_string()]
        }
        "group_lasso" => vec!["lambda".to_string(), "lr".to_string()],
        "elastic_gl" => {
            vec!["lambda".to_string(), "lambda2".to_string(), "lr".to_string()]
        }
        _ => vec!["lr".to_string()],
    };
    let params_total: usize = if cfg.method == "kpd" {
        cfg.layers.iter().map(|l| l.dims(cfg.rank).train_params() as usize).sum()
    } else {
        cfg.layers.iter().map(|l| l.m * l.n).sum()
    };
    let mut blocks = BTreeMap::new();
    for l in &cfg.layers {
        blocks.insert(
            l.name.clone(),
            Json::Arr(vec![Json::Num(l.m2 as f64), Json::Num(l.n2 as f64)]),
        );
    }
    let mut info = BTreeMap::new();
    info.insert("blocks".to_string(), Json::Obj(blocks));
    if cfg.method == "kpd" {
        info.insert("rank".to_string(), Json::Num(cfg.rank.max(1) as f64));
        let mut shapes = BTreeMap::new();
        for l in &cfg.layers {
            let d = l.dims(cfg.rank);
            let mut shape = BTreeMap::new();
            shape.insert("m1".to_string(), Json::Num(d.m1 as f64));
            shape.insert("n1".to_string(), Json::Num(d.n1 as f64));
            shape.insert("m2".to_string(), Json::Num(d.m2 as f64));
            shape.insert("n2".to_string(), Json::Num(d.n2 as f64));
            shape.insert("r".to_string(), Json::Num(d.r as f64));
            shapes.insert(l.name.clone(), Json::Obj(shape));
        }
        info.insert("shapes".to_string(), Json::Obj(shapes));
    }
    Ok(SpecEntry {
        key: cfg.key.clone(),
        model: "mlp".to_string(),
        batch: cfg.batch,
        tags: cfg.tags.clone(),
        input_shape: vec![cfg.in_dim],
        input_dtype: DType::F32,
        num_classes: cfg.out_dim,
        slots: cfg
            .layers
            .iter()
            .map(|l| SlotInfo { name: l.name.clone(), m: l.m, n: l.n })
            .collect(),
        method: cfg.method.clone(),
        hyper,
        metrics,
        params_total,
        info: Json::Obj(info),
    })
}

// ------------------------------------------------------------- helpers

fn fnv(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn pidx(state: &TrainState, key: &str) -> Result<usize> {
    state
        .param_names
        .iter()
        .position(|k| k == key)
        .ok_or_else(|| anyhow!("no param '{key}' in spec {}", state.spec))
}

fn oidx(state: &TrainState, key: &str) -> Result<usize> {
    state
        .opt_names
        .iter()
        .position(|k| k == key)
        .ok_or_else(|| anyhow!("no optimizer slot '{key}' in spec {}", state.spec))
}

/// v ← μ·v + g;  p ← p − lr·v   (classical momentum; v=g on the first step).
fn sgd_momentum(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    for ((pi, vi), gi) in p.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + gi;
        *pi -= lr * *vi;
    }
}

// Fused update passes: each helper below folds what used to be a separate
// whole-leaf sweep (gradient masking / ridge term / prox) into the single
// optimizer sweep. Every one keeps the exact per-element arithmetic
// *sequence* of the old two-sweep code, so results are bit-identical —
// pinned by `fused_updates_match_two_sweep_reference` below.

/// p ← prox_{t·‖·‖₁}(p − lr·g): plain SGD fused with the elementwise
/// soft-threshold (exact zeros) — the S-leaf update of every KPD path.
fn sgd_prox_l1(p: &mut [f32], g: &[f32], lr: f32, t: f32) {
    if t <= 0.0 {
        for (pi, gi) in p.iter_mut().zip(g) {
            *pi -= lr * gi;
        }
        return;
    }
    for (pi, gi) in p.iter_mut().zip(g) {
        let v = *pi - lr * gi;
        *pi = v.signum() * (v.abs() - t).max(0.0);
    }
}

/// [`sgd_momentum`] with the elastic ridge term λ₂·p folded into the
/// gradient (reads the pre-update p, like the old separate g-sweep).
fn sgd_momentum_l2(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32, lam2: f32) {
    for ((pi, vi), gi) in p.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + (gi + lam2 * *pi);
        *pi -= lr * *vi;
    }
}

/// [`sgd_momentum`] with an elementwise gradient mask (iter_prune):
/// g ⊙ mask feeds the momentum, no separate masking sweep or mask clone.
fn sgd_momentum_masked(p: &mut [f32], v: &mut [f32], g: &[f32], mask: &[f32], lr: f32, mu: f32) {
    for (((pi, vi), gi), mv) in p.iter_mut().zip(v.iter_mut()).zip(g).zip(mask) {
        *vi = mu * *vi + gi * mv;
        *pi -= lr * *vi;
    }
}

/// [`sgd_momentum`] with an (m2×n2) block mask expanded on the fly
/// (rigl_block): replaces `mul_expand_mask` + momentum, and with it the
/// m·n-sized mask expansion and the mask `.to_vec()` clone.
#[allow(clippy::too_many_arguments)]
fn sgd_momentum_block_masked(
    p: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    mask: &[f32],
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
    lr: f32,
    mu: f32,
) {
    let n1 = n / n2;
    for i in 0..m {
        let mrow = &mask[(i / m2) * n1..(i / m2 + 1) * n1];
        let prow = &mut p[i * n..(i + 1) * n];
        let vrow = &mut v[i * n..(i + 1) * n];
        let grow = &g[i * n..(i + 1) * n];
        for (j, ((pi, vi), gi)) in prow.iter_mut().zip(vrow.iter_mut()).zip(grow).enumerate() {
            *vi = mu * *vi + gi * mrow[j / n2];
            *pi -= lr * *vi;
        }
    }
}

/// Simultaneous `&mut` to param `i` and `&` to param `j` (i ≠ j) — lets
/// the masked updates above read a mask leaf while mutating W, instead of
/// cloning the mask out of the state.
fn param_pair_mut(params: &mut [Tensor], i: usize, j: usize) -> (&mut Tensor, &Tensor) {
    debug_assert_ne!(i, j);
    if i < j {
        let (lo, hi) = params.split_at_mut(j);
        (&mut lo[i], &hi[0])
    } else {
        let (lo, hi) = params.split_at_mut(i);
        (&mut hi[0], &lo[j])
    }
}

/// Undo `softmax_ce`'s 1/N scaling on dZ so every gradient chained from
/// it becomes a per-example *sum* — the unit the data-parallel tree
/// reduction combines (`backend::GradOut`).
fn scale_to_sum(dz: &mut [f32], nb: usize) {
    let s = nb as f32;
    for v in dz.iter_mut() {
        *v *= s;
    }
}

/// Flat gradient-buffer layout of a spec: `(leaf name, length)` in the
/// canonical order `grad_step` concatenates and `apply_update` slices —
/// KPD slots contribute `[S, A, B]`, dense-parameterized slots `[W]`,
/// pattern specs one `[S, A, B]` triple per candidate.
pub fn grad_layout(cfg: &SpecConfig) -> Vec<(String, usize)> {
    if cfg.method == "pattern_kpd" {
        let mut out = Vec::new();
        for (p, d) in cfg.pattern_dims().iter().enumerate() {
            out.push((pattern::pname(p, "S"), d.m1 * d.n1));
            out.push((pattern::pname(p, "A"), d.r * d.m1 * d.n1));
            out.push((pattern::pname(p, "B"), d.r * d.m2 * d.n2));
        }
        return out;
    }
    if cfg.is_mlp() {
        return layers::grad_layout(cfg);
    }
    if cfg.method == "kpd" {
        let d = cfg.dims();
        return vec![
            ("fc.S".to_string(), d.m1 * d.n1),
            ("fc.A".to_string(), d.r * d.m1 * d.n1),
            ("fc.B".to_string(), d.r * d.m2 * d.n2),
        ];
    }
    vec![("fc.W".to_string(), cfg.out_dim * cfg.in_dim)]
}

/// Per-block Frobenius norms on an (m2×n2) grid — the shared tensor-layer
/// kernel, re-exported under the short local name the step paths use.
fn block_fro(w: &[f32], m: usize, n: usize, m2: usize, n2: usize) -> Vec<f32> {
    crate::tensor::block_fro_norms_slice(w, m, n, m2, n2)
}

/// dw ⊙= expand(mask): zero gradient entries of inactive (m2×n2) blocks.
fn mul_expand_mask(dw: &mut [f32], mask: &[f32], m: usize, n: usize, m2: usize, n2: usize) {
    let n1 = n / n2;
    for i in 0..m {
        let mrow = &mask[(i / m2) * n1..(i / m2 + 1) * n1];
        let row = &mut dw[i * n..(i + 1) * n];
        for (j, v) in row.iter_mut().enumerate() {
            *v *= mrow[j / n2];
        }
    }
}

/// Block-group prox: shrink every (m2×n2) block of `w` toward zero by
/// `kappa` in Frobenius norm, zeroing blocks whose norm is below it.
fn block_prox(w: &mut [f32], m: usize, n: usize, m2: usize, n2: usize, kappa: f32) {
    if kappa <= 0.0 {
        return;
    }
    let norms = block_fro(w, m, n, m2, n2);
    let n1 = n / n2;
    for i in 0..m {
        let nrow = &norms[(i / m2) * n1..(i / m2 + 1) * n1];
        let row = &mut w[i * n..(i + 1) * n];
        for (j, v) in row.iter_mut().enumerate() {
            let norm = nrow[j / n2];
            if norm <= kappa {
                *v = 0.0;
            } else {
                *v *= 1.0 - kappa / norm;
            }
        }
    }
}

fn batch_xy<'a>(
    x: &'a HostValue,
    y: &'a HostValue,
    in_dim: usize,
) -> Result<(&'a [f32], usize, &'a [i32])> {
    let xt = x.as_f32()?;
    if xt.shape().len() != 2 || xt.shape()[1] != in_dim {
        bail!("native backend wants x of shape [batch, {in_dim}], got {:?}", xt.shape());
    }
    let nb = xt.shape()[0];
    if nb == 0 {
        bail!("empty batch");
    }
    let ys = match y {
        HostValue::I32 { shape, data } if shape.len() == 1 && shape[0] == nb => {
            data.as_slice()
        }
        _ => bail!("native backend wants i32 class-id labels of shape [{nb}]"),
    };
    Ok((xt.data(), nb, ys))
}

struct Hyper {
    lam: f32,
    lam2: f32,
    lr: f32,
}

fn parse_hyper(entry: &SpecEntry, hyper: &[f32]) -> Result<Hyper> {
    if hyper.len() != entry.hyper.len() {
        bail!(
            "{} train_step wants hyper {:?}, got {} values",
            entry.key,
            entry.hyper,
            hyper.len()
        );
    }
    let mut out = Hyper { lam: 0.0, lam2: 0.0, lr: 0.0 };
    // names resolve through the shared HyperParam vocabulary, so this stays
    // in lockstep with the trainer's build_hyper on the other side
    for (name, &v) in entry.hyper.iter().zip(hyper) {
        match HyperParam::parse(name)? {
            HyperParam::Lambda1 => out.lam = v,
            HyperParam::Lambda2 => out.lam2 = v,
            HyperParam::Lr => out.lr = v,
        }
    }
    Ok(out)
}

// ------------------------------------------------------------- the impl

impl NativeBackend {
    /// Logits for the current parameters under the spec's method.
    fn forward(&self, ns: &NativeSpec, state: &TrainState, x: &[f32], nb: usize) -> Result<Vec<f32>> {
        let cfg = &ns.cfg;
        let (m, n) = (cfg.out_dim, cfg.in_dim);
        match cfg.method.as_str() {
            "kpd" => {
                let s = state.param("fc.S")?;
                let a = state.param("fc.A")?;
                let b = state.param("fc.B")?;
                let (z, _) = kpd::forward(x, nb, s.data(), a.data(), b.data(), cfg.dims());
                Ok(z)
            }
            "rigl_block" => {
                let w = state.param("fc.W")?;
                let mask = state.param("fc.mask")?;
                linalg::block_sparse_matmul_nt(
                    x,
                    w.data(),
                    mask.data(),
                    nb,
                    m,
                    n,
                    cfg.m2,
                    cfg.n2,
                )
            }
            "iter_prune" => {
                let w = state.param("fc.W")?;
                let emask = state.param("fc.emask")?;
                let weff: Vec<f32> =
                    w.data().iter().zip(emask.data()).map(|(a, b)| a * b).collect();
                Ok(linalg::matmul_nt(x, &weff, nb, n, m))
            }
            _ => {
                let w = state.param("fc.W")?;
                Ok(linalg::matmul_nt(x, w.data(), nb, n, m))
            }
        }
    }

    fn step_kpd(
        &self,
        ns: &NativeSpec,
        state: &mut TrainState,
        x: &[f32],
        nb: usize,
        y: &[i32],
        h: &Hyper,
    ) -> Result<Vec<f32>> {
        let d = ns.cfg.dims();
        let s = state.param("fc.S")?.data().to_vec();
        let a = state.param("fc.A")?.data().to_vec();
        let b = state.param("fc.B")?.data().to_vec();
        let (z, tp) = kpd::forward(x, nb, &s, &a, &b, d);
        let sm = linalg::softmax_ce(&z, y, nb, d.m())?;
        let g = kpd::backward(x, nb, &s, &a, &sm.dz, &tp, d);
        self.apply_kpd(ns, state, &g.gs, &g.ga, &g.gb, sm.ce_mean, sm.acc_frac, h)
    }

    /// KPD gradient half of [`Backend::grad_step`]: per-example gradient
    /// sums of (S, A, B) on one shard, state untouched.
    fn grad_kpd(
        &self,
        ns: &NativeSpec,
        state: &TrainState,
        x: &[f32],
        nb: usize,
        y: &[i32],
    ) -> Result<GradOut> {
        let d = ns.cfg.dims();
        // `state` is a shared borrow here (unlike the fused step, which
        // must snapshot before mutating): no parameter copies
        let s = state.param("fc.S")?;
        let a = state.param("fc.A")?;
        let b = state.param("fc.B")?;
        let (z, tp) = kpd::forward(x, nb, s.data(), a.data(), b.data(), d);
        let mut sm = linalg::softmax_ce(&z, y, nb, d.m())?;
        scale_to_sum(&mut sm.dz, nb);
        let g = kpd::backward(x, nb, s.data(), a.data(), &sm.dz, &tp, d);
        let mut grad_sum = g.gs;
        grad_sum.extend(g.ga);
        grad_sum.extend(g.gb);
        Ok(GradOut {
            grad_sum,
            ce_sum: sm.ce_mean * nb as f32,
            correct: sm.correct,
            examples: nb,
        })
    }

    /// KPD update half: SGD/momentum on A/B, plain SGD + ℓ1 prox on S
    /// (the gradients are batch means). Shared by the fused `train_step`
    /// and the data-parallel `apply_update` so the two paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    fn apply_kpd(
        &self,
        ns: &NativeSpec,
        state: &mut TrainState,
        gs: &[f32],
        ga: &[f32],
        gb: &[f32],
        ce_mean: f32,
        acc_frac: f32,
        h: &Hyper,
    ) -> Result<Vec<f32>> {
        let mu = ns.cfg.momentum;
        // ‖S‖₁ pre-update, so the loss reports the objective the
        // gradients were taken at
        let s_l1 = state.param("fc.S")?.abs_sum();
        let (ai, avi) = (pidx(state, "fc.A")?, oidx(state, "fc.A.m")?);
        sgd_momentum(
            state.params[ai].data_mut(),
            state.opt[avi].data_mut(),
            ga,
            h.lr,
            mu,
        );
        let (bi, bvi) = (pidx(state, "fc.B")?, oidx(state, "fc.B.m")?);
        sgd_momentum(
            state.params[bi].data_mut(),
            state.opt[bvi].data_mut(),
            gb,
            h.lr,
            mu,
        );
        // S: plain SGD step fused with the ℓ1 prox → exact zeros
        let si = pidx(state, "fc.S")?;
        sgd_prox_l1(state.params[si].data_mut(), gs, h.lr, h.lr * h.lam);

        let loss = ce_mean + h.lam * s_l1;
        Ok(vec![loss, ce_mean, acc_frac, s_l1])
    }

    fn step_dense_family(
        &self,
        ns: &NativeSpec,
        state: &mut TrainState,
        x: &[f32],
        nb: usize,
        y: &[i32],
        h: &Hyper,
    ) -> Result<Vec<f32>> {
        let z = self.forward(ns, state, x, nb)?;
        let sm = linalg::softmax_ce(&z, y, nb, ns.cfg.out_dim)?;
        let dw = linalg::matmul_tn(&sm.dz, x, nb, ns.cfg.out_dim, ns.cfg.in_dim);
        self.apply_dense(ns, state, dw, sm.ce_mean, sm.acc_frac, h)
    }

    /// Dense-family gradient half of [`Backend::grad_step`]: the raw
    /// per-example-summed dW = dZᵀ·X of one shard — before any masking or
    /// ridge term, which are state-dependent and belong to the update half.
    fn grad_dense(
        &self,
        ns: &NativeSpec,
        state: &TrainState,
        x: &[f32],
        nb: usize,
        y: &[i32],
    ) -> Result<GradOut> {
        let z = self.forward(ns, state, x, nb)?;
        let mut sm = linalg::softmax_ce(&z, y, nb, ns.cfg.out_dim)?;
        scale_to_sum(&mut sm.dz, nb);
        let dw = linalg::matmul_tn(&sm.dz, x, nb, ns.cfg.out_dim, ns.cfg.in_dim);
        Ok(GradOut {
            grad_sum: dw,
            ce_sum: sm.ce_mean * nb as f32,
            correct: sm.correct,
            examples: nb,
        })
    }

    /// Dense-family update half: regularizer terms, gradient masking,
    /// SGD/momentum and the block-group prox — `dw` is the raw mean
    /// gradient. Shared by the fused `train_step` and `apply_update`.
    fn apply_dense(
        &self,
        ns: &NativeSpec,
        state: &mut TrainState,
        dw: Vec<f32>,
        ce_mean: f32,
        acc_frac: f32,
        h: &Hyper,
    ) -> Result<Vec<f32>> {
        let cfg = &ns.cfg;
        let (m, n, m2, n2) = (cfg.out_dim, cfg.in_dim, cfg.m2, cfg.n2);
        let method = cfg.method.as_str();
        let mu = cfg.momentum;

        // Regularizer terms read the *pre-update* W through a shared
        // borrow — the old W clone is gone; the mask/ridge sweeps are
        // fused into the momentum update below.
        let mut reg = 0.0f32;
        {
            let w = state.param("fc.W")?.data();
            if method == "elastic_gl" {
                let wsq: f32 = w.iter().map(|v| v * v).sum();
                reg += 0.5 * h.lam2 * wsq;
            }
            if method == "group_lasso" || method == "elastic_gl" {
                let weight = h.lam * ((m2 * n2) as f32).sqrt();
                reg += weight * block_fro(w, m, n, m2, n2).iter().sum::<f32>();
            }
        }
        // dense-gradient block norms (the RigL growth signal) come from
        // the *unmasked* gradient, so they are taken before the update
        let mut gnorm_tail: Vec<f32> = Vec::new();
        if method == "rigl_block" {
            gnorm_tail = block_fro(&dw, m, n, m2, n2);
        }

        let (wi, wvi) = (pidx(state, "fc.W")?, oidx(state, "fc.W.m")?);
        match method {
            "elastic_gl" => sgd_momentum_l2(
                state.params[wi].data_mut(),
                state.opt[wvi].data_mut(),
                &dw,
                h.lr,
                mu,
                h.lam2,
            ),
            "rigl_block" => {
                let mi = pidx(state, "fc.mask")?;
                let (wt, mt) = param_pair_mut(&mut state.params, wi, mi);
                sgd_momentum_block_masked(
                    wt.data_mut(),
                    state.opt[wvi].data_mut(),
                    &dw,
                    mt.data(),
                    m,
                    n,
                    m2,
                    n2,
                    h.lr,
                    mu,
                );
            }
            "iter_prune" => {
                let ei = pidx(state, "fc.emask")?;
                let (wt, et) = param_pair_mut(&mut state.params, wi, ei);
                sgd_momentum_masked(
                    wt.data_mut(),
                    state.opt[wvi].data_mut(),
                    &dw,
                    et.data(),
                    h.lr,
                    mu,
                );
            }
            _ => sgd_momentum(
                state.params[wi].data_mut(),
                state.opt[wvi].data_mut(),
                &dw,
                h.lr,
                mu,
            ),
        }
        if method == "group_lasso" || method == "elastic_gl" {
            let kappa = h.lr * h.lam * ((m2 * n2) as f32).sqrt();
            block_prox(state.params[wi].data_mut(), m, n, m2, n2, kappa);
        }

        let mut out = vec![ce_mean + reg, ce_mean, acc_frac];
        out.extend(gnorm_tail);
        Ok(out)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native-cpu".to_string()
    }

    fn specs(&self) -> Vec<&SpecEntry> {
        self.specs.values().map(|ns| &ns.entry).collect()
    }

    fn spec(&self, key: &str) -> Result<&SpecEntry> {
        Ok(&self.get(key)?.entry)
    }

    fn init_state(&self, spec: &str, seed: u32) -> Result<TrainState> {
        let ns = self.get(spec)?;
        let cfg = &ns.cfg;
        let mut rng = Rng::new((seed as u64) ^ fnv(&cfg.key));
        if cfg.method == "pattern_kpd" {
            let (pn, ps, on, os) = pattern::init_state_parts(&cfg.pattern_dims(), &mut rng);
            return Ok(TrainState {
                spec: spec.to_string(),
                param_names: pn,
                opt_names: on,
                params: ps,
                opt: os,
            });
        }
        if cfg.is_mlp() {
            let (pn, ps, on, os) = layers::init_state_parts(cfg, &mut rng);
            return Ok(TrainState {
                spec: spec.to_string(),
                param_names: pn,
                opt_names: on,
                params: ps,
                opt: os,
            });
        }
        let (m, n) = (cfg.out_dim, cfg.in_dim);
        let mut param_names = Vec::new();
        let mut params = Vec::new();
        let mut opt_names = Vec::new();
        let mut opt = Vec::new();
        if cfg.method == "kpd" {
            let d = cfg.dims();
            // scaled so the reconstructed W has ≈ sqrt(1/n) entries
            let a_std = (1.0 / (d.r * d.n1) as f32).sqrt();
            let b_std = (1.0 / d.n2 as f32).sqrt();
            param_names.push("fc.S".to_string());
            params.push(Tensor::full(&[d.m1, d.n1], 1.0));
            param_names.push("fc.A".to_string());
            params.push(Tensor::from_fn(&[d.r, d.m1, d.n1], |_| rng.normal() * a_std));
            param_names.push("fc.B".to_string());
            params.push(Tensor::from_fn(&[d.r, d.m2, d.n2], |_| rng.normal() * b_std));
            opt_names.push("fc.A.m".to_string());
            opt.push(Tensor::zeros(&[d.r, d.m1, d.n1]));
            opt_names.push("fc.B.m".to_string());
            opt.push(Tensor::zeros(&[d.r, d.m2, d.n2]));
        } else {
            let w_std = (1.0 / n as f32).sqrt();
            param_names.push("fc.W".to_string());
            params.push(Tensor::from_fn(&[m, n], |_| rng.normal() * w_std));
            if cfg.method == "rigl_block" {
                let (m1, n1) = cfg.grid();
                let total = m1 * n1;
                let k = ((cfg.rigl_density * total as f64).round() as usize).clamp(1, total);
                let chosen = rng.choose(total, k);
                let mut mask = vec![0.0f32; total];
                for i in chosen {
                    mask[i] = 1.0;
                }
                // inactive blocks start (and later grow) from exactly zero:
                // without this, the first grow step would resurrect the
                // untrained random init of a never-active block
                mul_expand_mask(params[0].data_mut(), &mask, m, n, cfg.m2, cfg.n2);
                param_names.push("fc.mask".to_string());
                params.push(Tensor::new(&[m1, n1], mask)?);
            } else if cfg.method == "iter_prune" {
                param_names.push("fc.emask".to_string());
                params.push(Tensor::full(&[m, n], 1.0));
            }
            opt_names.push("fc.W.m".to_string());
            opt.push(Tensor::zeros(&[m, n]));
        }
        Ok(TrainState { spec: spec.to_string(), param_names, opt_names, params, opt })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &HostValue,
        y: &HostValue,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        let h = parse_hyper(&ns.entry, hyper)?;
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        if ns.cfg.is_mlp() {
            return layers::train_step(&ns.cfg, state, xs, nb, ys, &h);
        }
        match ns.cfg.method.as_str() {
            "kpd" => self.step_kpd(ns, state, xs, nb, ys, &h),
            "pattern_kpd" => pattern::train_step(
                state,
                xs,
                nb,
                ys,
                &ns.cfg.pattern_dims(),
                h.lam,
                h.lr,
                ns.cfg.momentum,
            ),
            _ => self.step_dense_family(ns, state, xs, nb, ys, &h),
        }
    }

    fn eval_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        if ns.cfg.method == "pattern_kpd" {
            // per-pattern layout [ce_0..ce_{K-1}, correct_0..correct_{K-1}]
            return pattern::eval_step(state, xs, nb, ys, &ns.cfg.pattern_dims());
        }
        if ns.cfg.is_mlp() {
            let z = layers::forward_logits(&ns.cfg, state, xs, nb)?;
            let sm = linalg::softmax_ce(&z, ys, nb, ns.cfg.out_dim)?;
            return Ok(vec![sm.ce_mean, sm.correct]);
        }
        let z = self.forward(ns, state, xs, nb)?;
        let sm = linalg::softmax_ce(&z, ys, nb, ns.cfg.out_dim)?;
        Ok(vec![sm.ce_mean, sm.correct])
    }

    fn materialize(&self, state: &TrainState) -> Result<Vec<(String, Tensor)>> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.is_mlp() {
            return layers::materialize(cfg, state);
        }
        let (m, n) = (cfg.out_dim, cfg.in_dim);
        let w = match cfg.method.as_str() {
            "kpd" => {
                let s = state.param("fc.S")?;
                let a = state.param("fc.A")?;
                let b = state.param("fc.B")?;
                Tensor::kpd_reconstruct(s, a, b)?
            }
            "pattern_kpd" => {
                // survivor extraction: the max-retention candidate's dense W
                let (p, w) = pattern::materialize_survivor(state, &cfg.pattern_dims())?;
                crate::debug!("{}: materializing surviving pattern k={p}", cfg.key);
                w
            }
            "rigl_block" => {
                let mut w = state.param("fc.W")?.data().to_vec();
                let mask = state.param("fc.mask")?;
                mul_expand_mask(&mut w, mask.data(), m, n, cfg.m2, cfg.n2);
                Tensor::new(&[m, n], w)?
            }
            "iter_prune" => {
                let w = state.param("fc.W")?;
                let emask = state.param("fc.emask")?;
                w.hadamard(emask)?
            }
            _ => state.param("fc.W")?.clone(),
        };
        Ok(vec![("fc".to_string(), w)])
    }

    fn rigl_update(&self, state: &mut TrainState, gnorm: &[f32], alpha: f32) -> Result<()> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.method != "rigl_block" {
            bail!("rigl_update on non-RigL spec '{}'", state.spec);
        }
        if cfg.is_mlp() {
            // per-slot drop/grow on the concatenated gradient-norm layout
            return layers::rigl_update(cfg, state, gnorm, alpha);
        }
        let (m1, n1) = cfg.grid();
        if gnorm.len() != m1 * n1 {
            bail!("rigl_update wants {} block gradient norms, got {}", m1 * n1, gnorm.len());
        }
        layers::rigl_update_slot(state, "fc", cfg.out_dim, cfg.in_dim, cfg.m2, cfg.n2, gnorm, alpha)
    }

    fn prune(&self, state: &mut TrainState, target: f32) -> Result<()> {
        let ns = self.get(&state.spec)?;
        let cfg = &ns.cfg;
        if cfg.method != "iter_prune" {
            bail!("prune on non-pruning spec '{}'", state.spec);
        }
        if !(0.0..1.0).contains(&target) {
            bail!("prune target {target} outside [0, 1)");
        }
        if cfg.is_mlp() {
            // global magnitude ranking across every slot (standard
            // whole-model iterative pruning)
            return layers::prune(cfg, state, target);
        }
        let total = cfg.out_dim * cfg.in_dim;
        let keep = total - ((target as f64) * total as f64).round() as usize;
        let wi = pidx(state, "fc.W")?;
        let vi = oidx(state, "fc.W.m")?;
        let ei = pidx(state, "fc.emask")?;
        let w = state.params[wi].data().to_vec();
        let mut order: Vec<usize> = (0..total).collect();
        order.sort_by(|&a, &b| w[b].abs().total_cmp(&w[a].abs()));
        let mut emask = vec![0.0f32; total];
        for &i in &order[..keep] {
            emask[i] = 1.0;
        }
        for i in 0..total {
            if emask[i] == 0.0 {
                state.params[wi].data_mut()[i] = 0.0;
                state.opt[vi].data_mut()[i] = 0.0;
            }
        }
        state.params[ei] = Tensor::new(&[cfg.out_dim, cfg.in_dim], emask)?;
        Ok(())
    }

    fn gnorm_len(&self, spec: &str) -> Result<usize> {
        let ns = self.get(spec)?;
        if ns.cfg.method != "rigl_block" {
            return Ok(0);
        }
        if ns.cfg.is_mlp() {
            return Ok(layers::gnorm_len(&ns.cfg));
        }
        let (m1, n1) = ns.cfg.grid();
        Ok(m1 * n1)
    }

    fn supports_grad_step(&self, spec: &str) -> bool {
        // every native family (single-slot, mlp, pattern) has a separable
        // gradient path
        self.get(spec).is_ok()
    }

    fn grad_len(&self, spec: &str) -> Result<usize> {
        Ok(grad_layout(&self.get(spec)?.cfg).iter().map(|(_, l)| l).sum())
    }

    fn grad_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<GradOut> {
        let ns = self.get(&state.spec)?;
        let (xs, nb, ys) = batch_xy(x, y, ns.cfg.in_dim)?;
        if ns.cfg.is_mlp() {
            return layers::grad_step(&ns.cfg, state, xs, nb, ys);
        }
        match ns.cfg.method.as_str() {
            "kpd" => self.grad_kpd(ns, state, xs, nb, ys),
            "pattern_kpd" => pattern::grad_step(state, xs, nb, ys, &ns.cfg.pattern_dims()),
            _ => self.grad_dense(ns, state, xs, nb, ys),
        }
    }

    fn apply_update(
        &self,
        state: &mut TrainState,
        grad: Vec<f32>,
        ce_mean: f32,
        acc_frac: f32,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let ns = self.get(&state.spec)?;
        let h = parse_hyper(&ns.entry, hyper)?;
        let want = self.grad_len(&state.spec)?;
        if grad.len() != want {
            bail!(
                "apply_update on '{}': gradient buffer has {} values, layout wants {want}",
                state.spec,
                grad.len()
            );
        }
        if ns.cfg.is_mlp() {
            return layers::apply_update(&ns.cfg, state, &grad, ce_mean, acc_frac, &h);
        }
        match ns.cfg.method.as_str() {
            "kpd" => {
                let d = ns.cfg.dims();
                let (gs, rest) = grad.split_at(d.m1 * d.n1);
                let (ga, gb) = rest.split_at(d.r * d.m1 * d.n1);
                self.apply_kpd(ns, state, gs, ga, gb, ce_mean, acc_frac, &h)
            }
            "pattern_kpd" => pattern::apply_update(
                state,
                &grad,
                &ns.cfg.pattern_dims(),
                ce_mean,
                acc_frac,
                h.lam,
                h.lr,
                ns.cfg.momentum,
            ),
            _ => self.apply_dense(ns, state, grad, ce_mean, acc_frac, &h),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(nb: usize, in_dim: usize, classes: usize, seed: u64) -> (HostValue, HostValue) {
        let mut rng = Rng::new(seed);
        let x = Tensor::from_fn(&[nb, in_dim], |_| rng.normal());
        let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
        (HostValue::F32(x), HostValue::I32 { shape: vec![nb], data: y })
    }

    #[test]
    fn default_registry_has_table1_specs() {
        let be = NativeBackend::with_default_specs();
        assert!(be.spec("qs_kpd").is_ok());
        assert!(be.spec("t1_kpd_b16x2").is_ok());
        assert!(be.spec("t1_rigl_b2x2").is_ok());
        assert!(be.spec("t4_linear_r6").is_ok());
        assert!(be.spec("nope").is_err());
        let e = be.spec("t1_kpd_b16x2").unwrap();
        assert_eq!(e.block_of("fc"), Some((2, 16)));
        assert_eq!(e.rank(), Some(2));
        assert!(e.params_total < 7840);
    }

    #[test]
    fn t2_mlp_registry_layout() {
        let be = NativeBackend::with_default_specs();
        for combo in
            ["16x8_8x4_4x2", "8x4_4x4_2x2", "4x4_4x4_2x2", "4x4_2x2_2x2", "2x2_2x2_2x2"]
        {
            for m in ["kpd", "gl", "egl", "rigl"] {
                assert!(be.spec(&format!("t2_{m}_{combo}")).is_ok(), "t2_{m}_{combo}");
            }
        }
        let e = be.spec("t2_kpd_16x8_8x4_4x2").unwrap().clone();
        assert_eq!(e.model, "mlp");
        assert_eq!(e.slots.len(), 3);
        assert_eq!(e.slots[0].m, 304);
        assert_eq!(e.slots[0].n, 784);
        assert_eq!(e.block_of("fc1"), Some((8, 16)));
        assert_eq!(e.block_of("fc3"), Some((2, 4)));
        // per-layer ‖S‖₁ metrics follow the whole-model one
        assert_eq!(e.metric_index("s_l1"), Some(3));
        assert_eq!(e.metric_index("s_l1_fc2"), Some(5));
        // factorized training params far below the dense stack (Table 2's
        // params column: "Ours" 6-23K vs 61K dense at LeNet scale)
        let dense = be.spec("t2_dense").unwrap();
        assert_eq!(dense.model, "mlp");
        assert!(
            e.params_total < dense.params_total / 4,
            "{} vs dense {}",
            e.params_total,
            dense.params_total
        );
        assert!(be.spec("t2_prune").is_ok());
    }

    #[test]
    fn mlp_config_validation() {
        // width chain must tile per-layer blocks
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(2, 3), (2, 2)], 2, 8)
            .validate()
            .is_ok());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(3, 3), (2, 2)], 2, 8)
            .validate()
            .is_err());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[(2, 5), (2, 2)], 2, 8)
            .validate()
            .is_err());
        assert!(SpecConfig::mlp("m", "kpd", &[12, 8, 4], &[], 0, 8).validate().is_err());
        assert!(SpecConfig::mlp("m", "pattern_kpd", &[12, 8, 4], &[], 1, 8)
            .validate()
            .is_err());
        // broken chain caught even when built by hand
        let mut cfg = SpecConfig::mlp("m", "dense", &[12, 8, 4], &[], 1, 8);
        cfg.layers[1].n = 6;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn init_is_seed_deterministic_and_s_starts_at_one() {
        let be = NativeBackend::with_default_specs();
        let a = be.init_state("qs_kpd", 7).unwrap();
        let b = be.init_state("qs_kpd", 7).unwrap();
        let c = be.init_state("qs_kpd", 8).unwrap();
        assert_eq!(a.param("fc.A").unwrap().data(), b.param("fc.A").unwrap().data());
        assert_ne!(a.param("fc.A").unwrap().data(), c.param("fc.A").unwrap().data());
        assert!(a.param("fc.S").unwrap().data().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn every_method_takes_a_finite_step() {
        let be = NativeBackend::with_default_specs();
        for spec in
            ["t1_kpd_b2x2", "t1_gl_b2x2", "t1_egl_b2x2", "t1_rigl_b2x2", "t1_prune", "t1_dense"]
        {
            let entry = be.spec(spec).unwrap().clone();
            let mut state = be.init_state(spec, 0).unwrap();
            let (x, y) = batch(16, 784, 10, 3);
            let hyper: Vec<f32> = entry
                .hyper
                .iter()
                .map(|h| match h.as_str() {
                    "lr" => 0.05,
                    "lambda2" => 1e-4,
                    _ => 0.01,
                })
                .collect();
            let m = be.train_step(&mut state, &x, &y, &hyper).unwrap();
            assert_eq!(m.len(), entry.metrics.len(), "{spec}");
            assert!(m.iter().all(|v| v.is_finite()), "{spec}: {m:?}");
            let e = be.eval_step(&state, &x, &y).unwrap();
            assert!(e[0].is_finite());
            assert!(e[1] >= 0.0 && e[1] <= 16.0);
        }
    }

    #[test]
    fn rigl_update_preserves_active_count() {
        let be = NativeBackend::with_default_specs();
        let mut state = be.init_state("t1_rigl_b2x2", 0).unwrap();
        let mask0 = state.param("fc.mask").unwrap().clone();
        let nnz0: f32 = mask0.data().iter().sum();
        let gnorm: Vec<f32> = (0..mask0.len()).map(|i| (i as f32 * 0.37 + 0.01) % 5.0).collect();
        be.rigl_update(&mut state, &gnorm, 0.3).unwrap();
        let mask1 = state.param("fc.mask").unwrap().clone();
        let nnz1: f32 = mask1.data().iter().sum();
        assert_eq!(nnz0, nnz1, "active block count changed");
        assert!(mask0.max_abs_diff(&mask1) > 0.0, "mask did not change");
    }

    #[test]
    fn prune_hits_exact_target() {
        let be = NativeBackend::with_default_specs();
        let mut state = be.init_state("t1_prune", 0).unwrap();
        be.prune(&mut state, 0.6).unwrap();
        let emask = state.param("fc.emask").unwrap().clone();
        let sparsity = crate::sparsity::mask_sparsity(&emask);
        assert!((sparsity - 0.6).abs() < 0.001, "sparsity {sparsity}");
        // pruned weights are zeroed
        let w = state.param("fc.W").unwrap();
        for (wv, mv) in w.data().iter().zip(emask.data()) {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0);
            }
        }
    }

    #[test]
    fn materialize_shapes_per_method() {
        let be = NativeBackend::with_default_specs();
        for spec in ["qs_kpd", "t1_gl_b2x2", "t1_rigl_b2x2", "t1_prune", "t1_dense"] {
            let state = be.init_state(spec, 1).unwrap();
            let ws = be.materialize(&state).unwrap();
            assert_eq!(ws.len(), 1);
            assert_eq!(ws[0].0, "fc");
            assert_eq!(ws[0].1.shape(), &[10, 784], "{spec}");
        }
    }

    #[test]
    fn pattern_spec_registered_with_fig3_layout() {
        let be = NativeBackend::with_default_specs();
        let e = be.spec("f3a_pattern").unwrap().clone();
        assert_eq!(e.method, "pattern_kpd");
        assert_eq!(e.num_patterns(), Some(4));
        // metrics: [loss, ce, acc, s_l1_p0..s_l1_p3]
        assert_eq!(e.metrics.len(), 7);
        assert_eq!(e.metric_index("s_l1_p3"), Some(6));
        assert_eq!(e.hyper, vec!["lambda".to_string(), "lr".to_string()]);
        // params_total = Σ_k candidate factorization params
        let cfg = SpecConfig::pattern(
            "x", 784, 10, &[(2, 2), (2, 4), (2, 8), (2, 16)], 1, 128,
        );
        let want: usize =
            cfg.pattern_dims().iter().map(|d| d.train_params() as usize).sum();
        assert_eq!(e.params_total, want);
    }

    #[test]
    fn pattern_spec_trains_evals_and_materializes() {
        let be = NativeBackend::with_default_specs();
        let e = be.spec("f3a_pattern").unwrap().clone();
        let mut state = be.init_state("f3a_pattern", 0).unwrap();
        let (x, y) = batch(16, 784, 10, 3);
        let m = be.train_step(&mut state, &x, &y, &[0.01, 0.05]).unwrap();
        assert_eq!(m.len(), e.metrics.len());
        assert!(m.iter().all(|v| v.is_finite()), "{m:?}");
        // the per-pattern eval layout Trainer::evaluate expects: 2K values
        let ev = be.eval_step(&state, &x, &y).unwrap();
        assert_eq!(ev.len(), 8);
        for p in 0..4 {
            assert!(ev[p] > 0.0, "ce_{p} must be positive");
            assert!((0.0..=16.0).contains(&ev[4 + p]), "correct_{p} out of range");
        }
        // survivor extraction: exactly one dense fc slot at the full shape
        let ws = be.materialize(&state).unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].0, "fc");
        assert_eq!(ws[0].1.shape(), &[10, 784]);
        // pattern probes read the p{k}.fc.S layout
        let norms = crate::coordinator::probe::pattern_s_norms(&e, &state).unwrap();
        assert_eq!(norms.len(), 4);
        assert!(norms.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn pattern_config_validation() {
        assert!(SpecConfig::pattern("p", 784, 10, &[], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(3, 2)], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 3)], 2, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 4)], 0, 64).validate().is_err());
        assert!(SpecConfig::pattern("p", 784, 10, &[(2, 4)], 2, 64).validate().is_ok());
        // candidates on a non-pattern method are rejected
        let mut cfg = SpecConfig::linear("q", "kpd", 784, 10, 2, 4, 2, 64);
        cfg.patterns = vec![(2, 4)];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn momentum_buffers_populate_after_one_step() {
        let cfg = SpecConfig::linear("mom", "dense", 8, 4, 1, 1, 1, 4);
        let be = NativeBackend::from_spec(cfg).unwrap();
        let mut state = be.init_state("mom", 0).unwrap();
        let (x, y) = batch(4, 8, 4, 11);
        be.train_step(&mut state, &x, &y, &[0.1]).unwrap();
        let v = &state.opt[0];
        assert!(v.data().iter().any(|&g| g != 0.0), "velocity stayed zero");
    }

    /// The fused optimizer sweeps must be *bit-identical* to the old
    /// two-sweep formulations they replaced — this is what keeps every
    /// golden-pinned run valid across the fusion refactor.
    #[test]
    fn fused_updates_match_two_sweep_reference() {
        let mut rng = Rng::new(77);
        let (m, n, m2, n2) = (6usize, 8usize, 2usize, 4usize);
        let len = m * n;
        let p0: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let v0: Vec<f32> = (0..len).map(|_| rng.normal() * 0.1).collect();
        let g: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let (lr, mu, lam2, t) = (0.07f32, 0.9f32, 1e-3f32, 0.05f32);

        // sgd_prox_l1 vs SGD sweep + the old standalone soft-threshold
        // sweep (prox of t·‖·‖₁)
        let mut fused = p0.clone();
        sgd_prox_l1(&mut fused, &g, lr, t);
        let mut reference = p0.clone();
        for (p, gi) in reference.iter_mut().zip(&g) {
            *p -= lr * gi;
        }
        for v in reference.iter_mut() {
            *v = v.signum() * (v.abs() - t).max(0.0);
        }
        assert_eq!(fused, reference, "sgd_prox_l1");
        // t = 0 degenerates to plain SGD
        let mut plain = p0.clone();
        sgd_prox_l1(&mut plain, &g, lr, 0.0);
        assert_eq!(plain, p0.iter().zip(&g).map(|(p, gi)| p - lr * gi).collect::<Vec<_>>());

        // sgd_momentum_l2 vs g += λ₂·w sweep + sgd_momentum
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_l2(&mut fp, &mut fv, &g, lr, mu, lam2);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let mut g2 = g.clone();
        for (gi, wv) in g2.iter_mut().zip(&p0) {
            *gi += lam2 * wv;
        }
        sgd_momentum(&mut rp, &mut rv, &g2, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_l2 params");
        assert_eq!(fv, rv, "sgd_momentum_l2 velocity");

        // sgd_momentum_masked vs g ⊙ mask sweep + sgd_momentum
        let emask: Vec<f32> = (0..len).map(|i| (i % 3 == 0) as u8 as f32).collect();
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_masked(&mut fp, &mut fv, &g, &emask, lr, mu);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let gm: Vec<f32> = g.iter().zip(&emask).map(|(gi, mv)| gi * mv).collect();
        sgd_momentum(&mut rp, &mut rv, &gm, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_masked params");
        assert_eq!(fv, rv, "sgd_momentum_masked velocity");

        // sgd_momentum_block_masked vs mul_expand_mask + sgd_momentum
        let mask: Vec<f32> = (0..(m / m2) * (n / n2)).map(|i| (i % 2) as f32).collect();
        let (mut fp, mut fv) = (p0.clone(), v0.clone());
        sgd_momentum_block_masked(&mut fp, &mut fv, &g, &mask, m, n, m2, n2, lr, mu);
        let (mut rp, mut rv) = (p0.clone(), v0.clone());
        let mut gb = g.clone();
        mul_expand_mask(&mut gb, &mask, m, n, m2, n2);
        sgd_momentum(&mut rp, &mut rv, &gb, lr, mu);
        assert_eq!(fp, rp, "sgd_momentum_block_masked params");
        assert_eq!(fv, rv, "sgd_momentum_block_masked velocity");
    }

    #[test]
    fn param_pair_mut_borrows_both_orders() {
        let mut params = vec![Tensor::full(&[2], 1.0), Tensor::full(&[2], 2.0)];
        {
            let (a, b) = param_pair_mut(&mut params, 0, 1);
            a.data_mut()[0] = 5.0;
            assert_eq!(b.data()[0], 2.0);
        }
        let (a, b) = param_pair_mut(&mut params, 1, 0);
        a.data_mut()[0] = 7.0;
        assert_eq!(b.data()[0], 5.0);
        assert_eq!(params[0].data()[0], 5.0);
        assert_eq!(params[1].data()[0], 7.0);
    }
}
