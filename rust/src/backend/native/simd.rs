//! Runtime-dispatched SIMD microkernels: the one place in the crate that
//! touches `std::arch`.
//!
//! Every matmul-family kernel (`linalg`, `kpd`, `infer::bsr`,
//! `infer::quant`) is written against a handful of tiny primitives —
//! [`dot`], [`dot4`], [`axpy`], [`axpy2`], and the int8-weight
//! [`dot_q8`] — each taking an explicit [`SimdKind`]. The kind is resolved **once per
//! kernel call** on the calling thread (see [`active`]) and captured into
//! the row closures, so every worker thread of a `par_rows` split runs the
//! same code path and each output element's accumulation order depends
//! only on the kernel config — never on thread count or replica count
//! (the PR-5 bit-identity contract).
//!
//! Dispatch policy, in precedence order:
//! 1. a process-wide pin installed by [`force`] (used by the golden /
//!    mirror-pinned test binaries to hold the scalar path);
//! 2. the `BS_NATIVE_SIMD` env knob (`0`/`off`/`scalar` pins scalar,
//!    `avx2`/`neon` request an ISA — downgraded to scalar when the CPU
//!    lacks it, `auto`/`1`/unset means detect);
//! 3. runtime feature detection: AVX2+FMA on x86_64, NEON on aarch64,
//!    scalar everywhere else.
//!
//! Determinism inside one kind: the vector bodies use a fixed number of
//! lane accumulators combined in a fixed order, and the sub-width tail is
//! always scalar, so a given (kind, length) pair always produces the same
//! bits. Scalar kind reproduces the pre-SIMD loops exactly, which is what
//! keeps the committed golden values valid under the pinned config.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which microkernel body to run. `Avx2` implies FMA; `Neon` is the
/// aarch64 baseline. All variants exist on every arch so env parsing and
/// tests are portable — dispatch falls back to scalar when the current
/// arch cannot execute the requested kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdKind {
    Scalar,
    Avx2,
    Neon,
}

impl SimdKind {
    /// Stable label used in BENCH_*.json artifacts and logs.
    pub fn label(self) -> &'static str {
        match self {
            SimdKind::Scalar => "scalar",
            SimdKind::Avx2 => "avx2",
            SimdKind::Neon => "neon",
        }
    }
}

/// Runtime feature detection for the current CPU, ignoring the env knob.
pub fn detect() -> SimdKind {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdKind::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdKind::Neon;
        }
    }
    SimdKind::Scalar
}

/// Downgrade a requested kind to scalar when this CPU cannot run it.
fn available(kind: SimdKind) -> SimdKind {
    match kind {
        SimdKind::Scalar => SimdKind::Scalar,
        k if k == detect() => k,
        _ => SimdKind::Scalar,
    }
}

/// The env-resolved kind (cached on first use): `BS_NATIVE_SIMD` pins or
/// requests, otherwise [`detect`]. This is what kernels run when no
/// process-wide [`force`] pin is installed.
pub fn dispatched() -> SimdKind {
    static CACHED: std::sync::OnceLock<SimdKind> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| match std::env::var("BS_NATIVE_SIMD").ok().as_deref() {
        Some("0") | Some("off") | Some("scalar") => SimdKind::Scalar,
        Some("avx2") => available(SimdKind::Avx2),
        Some("neon") => available(SimdKind::Neon),
        _ => detect(),
    })
}

/// Process-wide pin: 0 = none, otherwise `SimdKind` + 1. A plain atomic
/// (not a thread-local) so replica pool workers and scoped kernel workers
/// all see the same kind — a per-thread override would let two replicas
/// run different code paths and break bit-identity.
static FORCE: AtomicU8 = AtomicU8::new(0);

/// Pin the process to `kind` (downgraded to scalar if unavailable) until
/// [`unforce`]. Intended for test binaries whose committed expectations
/// assume one kind — call it at the top of every test in the binary, not
/// mid-run, since kernels resolve the pin per call.
pub fn force(kind: SimdKind) {
    let k = available(kind);
    FORCE.store(k as u8 + 1, Ordering::Relaxed);
}

/// Remove a [`force`] pin, returning dispatch to the env/detect policy.
pub fn unforce() {
    FORCE.store(0, Ordering::Relaxed);
}

/// The kind kernels should run right now: the [`force`] pin if installed,
/// else [`dispatched`].
pub fn active() -> SimdKind {
    match FORCE.load(Ordering::Relaxed) {
        1 => SimdKind::Scalar,
        2 => SimdKind::Avx2,
        3 => SimdKind::Neon,
        _ => dispatched(),
    }
}

// ------------------------------------------------------------ primitives

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (av, bv) in a.iter().zip(b) {
        acc += av * bv;
    }
    acc
}

fn dot_q8_scalar(q: &[i8], x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (qv, xv) in q.iter().zip(x) {
        acc += *qv as f32 * xv;
    }
    acc
}

fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (o, &xv) in y.iter_mut().zip(x) {
        *o += alpha * xv;
    }
}

fn axpy2_scalar(a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
    // per element the two adds land in k order — bit-identical to two
    // consecutive axpy sweeps, with half the y traffic
    for ((o, &v0), &v1) in y.iter_mut().zip(x0).zip(x1) {
        *o += a0 * v0;
        *o += a1 * v1;
    }
}

/// acc = Σ aᵢ·bᵢ. Slices must be equal length.
pub fn dot(kind: SimdKind, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match kind {
        #[cfg(target_arch = "x86_64")]
        SimdKind::Avx2 => unsafe { x86::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdKind::Neon => unsafe { arm::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// acc = Σ qᵢ·xᵢ with i8 weights widened to f32 in-register before the
/// FMA — the W8A32 inner product of the int8 BSR path (`infer::quant`).
/// Accumulation is f32 with the same fixed lane/tail structure as
/// [`dot`], so a given (kind, length) pair is bit-deterministic and the
/// only difference from an f32 dot over dequantized weights is which
/// side pays the widening.
pub fn dot_q8(kind: SimdKind, q: &[i8], x: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), x.len());
    match kind {
        #[cfg(target_arch = "x86_64")]
        SimdKind::Avx2 => unsafe { x86::dot_q8(q, x) },
        #[cfg(target_arch = "aarch64")]
        SimdKind::Neon => unsafe { arm::dot_q8(q, x) },
        _ => dot_q8_scalar(q, x),
    }
}

/// Four dot products of one `a` row against four `b` rows — the 1×4
/// register-blocked microkernel of the `A·Bᵀ` family: `a` is streamed once
/// per four outputs instead of once per output.
pub fn dot4(kind: SimdKind, a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    match kind {
        #[cfg(target_arch = "x86_64")]
        SimdKind::Avx2 => unsafe { x86::dot4(a, b0, b1, b2, b3) },
        #[cfg(target_arch = "aarch64")]
        SimdKind::Neon => unsafe { arm::dot4(a, b0, b1, b2, b3) },
        _ => [
            dot_scalar(a, b0),
            dot_scalar(a, b1),
            dot_scalar(a, b2),
            dot_scalar(a, b3),
        ],
    }
}

/// y += α·x. Slices must be equal length.
pub fn axpy(kind: SimdKind, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match kind {
        #[cfg(target_arch = "x86_64")]
        SimdKind::Avx2 => unsafe { x86::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        SimdKind::Neon => unsafe { arm::axpy(alpha, x, y) },
        _ => axpy_scalar(alpha, x, y),
    }
}

/// y += α₀·x₀ + α₁·x₁ — the 2-deep k-unrolled update of the `A·B` family,
/// halving the y read/write traffic versus two [`axpy`] sweeps.
pub fn axpy2(kind: SimdKind, a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
    debug_assert!(x0.len() == y.len() && x1.len() == y.len());
    match kind {
        #[cfg(target_arch = "x86_64")]
        SimdKind::Avx2 => unsafe { x86::axpy2(a0, x0, a1, x1, y) },
        #[cfg(target_arch = "aarch64")]
        SimdKind::Neon => unsafe { arm::axpy2(a0, x0, a1, x1, y) },
        _ => axpy2_scalar(a0, x0, a1, x1, y),
    }
}

// ------------------------------------------------------------ x86_64 body

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Horizontal sum of an 8-lane register, fixed reduction tree.
    #[inline]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        // two accumulators hide FMA latency; combined in a fixed order
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let mut out = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            out += a[i] * b[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_q8(q: &[i8], x: &[f32]) -> f32 {
        let n = q.len();
        let (qp, xp) = (q.as_ptr(), x.as_ptr());
        // widen 8 i8 → 8 i32 → 8 f32 per lane group; two accumulators
        // combined in a fixed order, scalar tail — same determinism
        // structure as `dot`
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let q0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(
                qp.add(i) as *const __m128i
            )));
            let q1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(
                qp.add(i + 8) as *const __m128i
            )));
            acc0 = _mm256_fmadd_ps(q0, _mm256_loadu_ps(xp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(q1, _mm256_loadu_ps(xp.add(i + 8)), acc1);
            i += 16;
        }
        if i + 8 <= n {
            let q0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(
                qp.add(i) as *const __m128i
            )));
            acc0 = _mm256_fmadd_ps(q0, _mm256_loadu_ps(xp.add(i)), acc0);
            i += 8;
        }
        let mut out = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            out += q[i] as f32 * x[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        let n = a.len();
        let ap = a.as_ptr();
        let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(ap.add(i));
            c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p0.add(i)), c0);
            c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p1.add(i)), c1);
            c2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p2.add(i)), c2);
            c3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p3.add(i)), c3);
            i += 8;
        }
        let mut out = [hsum(c0), hsum(c1), hsum(c2), hsum(c3)];
        while i < n {
            let av = a[i];
            out[0] += av * b0[i];
            out[1] += av * b1[i];
            out[2] += av * b2[i];
            out[3] += av * b3[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let yv = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), yv);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av0 = _mm256_set1_ps(a0);
        let av1 = _mm256_set1_ps(a1);
        let (p0, p1) = (x0.as_ptr(), x1.as_ptr());
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let mut yv = _mm256_loadu_ps(yp.add(i));
            yv = _mm256_fmadd_ps(av0, _mm256_loadu_ps(p0.add(i)), yv);
            yv = _mm256_fmadd_ps(av1, _mm256_loadu_ps(p1.add(i)), yv);
            _mm256_storeu_ps(yp.add(i), yv);
            i += 8;
        }
        while i < n {
            y[i] += a0 * x0[i];
            y[i] += a1 * x1[i];
            i += 1;
        }
    }
}

// ------------------------------------------------------------ aarch64 body

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
            i += 8;
        }
        if i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            i += 4;
        }
        let mut out = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            out += a[i] * b[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_q8(q: &[i8], x: &[f32]) -> f32 {
        let n = q.len();
        let (qp, xp) = (q.as_ptr(), x.as_ptr());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            // 8 i8 → 8 i16 → 2×4 i32 → 2×4 f32
            let q16 = vmovl_s8(vld1_s8(qp.add(i)));
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
            acc0 = vfmaq_f32(acc0, lo, vld1q_f32(xp.add(i)));
            acc1 = vfmaq_f32(acc1, hi, vld1q_f32(xp.add(i + 4)));
            i += 8;
        }
        let mut out = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            out += q[i] as f32 * x[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        let n = a.len();
        let ap = a.as_ptr();
        let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
        let mut c0 = vdupq_n_f32(0.0);
        let mut c1 = vdupq_n_f32(0.0);
        let mut c2 = vdupq_n_f32(0.0);
        let mut c3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let av = vld1q_f32(ap.add(i));
            c0 = vfmaq_f32(c0, av, vld1q_f32(p0.add(i)));
            c1 = vfmaq_f32(c1, av, vld1q_f32(p1.add(i)));
            c2 = vfmaq_f32(c2, av, vld1q_f32(p2.add(i)));
            c3 = vfmaq_f32(c3, av, vld1q_f32(p3.add(i)));
            i += 4;
        }
        let mut out = [vaddvq_f32(c0), vaddvq_f32(c1), vaddvq_f32(c2), vaddvq_f32(c3)];
        while i < n {
            let av = a[i];
            out[0] += av * b0[i];
            out[1] += av * b1[i];
            out[2] += av * b2[i];
            out[3] += av * b3[i];
            i += 1;
        }
        out
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av = vdupq_n_f32(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let yv = vfmaq_f32(vld1q_f32(yp.add(i)), av, vld1q_f32(xp.add(i)));
            vst1q_f32(yp.add(i), yv);
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy2(a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av0 = vdupq_n_f32(a0);
        let av1 = vdupq_n_f32(a1);
        let (p0, p1) = (x0.as_ptr(), x1.as_ptr());
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let mut yv = vld1q_f32(yp.add(i));
            yv = vfmaq_f32(yv, av0, vld1q_f32(p0.add(i)));
            yv = vfmaq_f32(yv, av1, vld1q_f32(p1.add(i)));
            vst1q_f32(yp.add(i), yv);
            i += 4;
        }
        while i < n {
            y[i] += a0 * x0[i];
            y[i] += a1 * x1[i];
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// Every available kind agrees with f64 scalar reference across ragged
    /// lengths (vector body + every tail width).
    #[test]
    fn primitives_match_f64_reference_on_ragged_lengths() {
        let mut rng = Rng::new(71);
        let kinds = [SimdKind::Scalar, detect()];
        for &len in &[0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 64, 130] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            for &k in &kinds {
                let got = dot(k, &a, &b);
                assert!(
                    close(got, want as f32, 1e-5),
                    "{k:?} dot len {len}: {got} vs {want}"
                );
            }
            // dot_q8 against an f64 reference over the widened weights
            let q: Vec<i8> = (0..len).map(|_| (rng.normal() * 40.0) as i8).collect();
            let want_q: f64 = q.iter().zip(&b).map(|(qv, x)| *qv as f64 * *x as f64).sum();
            for &k in &kinds {
                let got = dot_q8(k, &q, &b);
                assert!(
                    close(got, want_q as f32, 1e-5),
                    "{k:?} dot_q8 len {len}: {got} vs {want_q}"
                );
            }
            // dot4 against four independent dots
            let (b0, b1, b2, b3) = (
                rand_vec(&mut rng, len),
                rand_vec(&mut rng, len),
                rand_vec(&mut rng, len),
                rand_vec(&mut rng, len),
            );
            for &k in &kinds {
                let got = dot4(k, &a, &b0, &b1, &b2, &b3);
                for (g, bx) in got.iter().zip([&b0, &b1, &b2, &b3]) {
                    assert!(
                        close(*g, dot(k, &a, bx), 1e-5),
                        "{k:?} dot4 len {len} drifted from dot"
                    );
                }
            }
            // axpy / axpy2 against scalar
            for &k in &kinds {
                let mut y1 = rand_vec(&mut rng, len);
                let mut y2 = y1.clone();
                axpy(k, 0.37, &a, &mut y1);
                axpy_scalar(0.37, &a, &mut y2);
                for (g, w) in y1.iter().zip(&y2) {
                    assert!(close(*g, *w, 1e-6), "{k:?} axpy len {len}");
                }
                let mut y3 = y2.clone();
                let mut y4 = y2.clone();
                axpy2(k, 0.37, &a, -1.21, &b, &mut y3);
                axpy2_scalar(0.37, &a, -1.21, &b, &mut y4);
                for (g, w) in y3.iter().zip(&y4) {
                    assert!(close(*g, *w, 1e-6), "{k:?} axpy2 len {len}");
                }
            }
        }
    }

    /// A given kind must be a pure function of its inputs: repeated calls
    /// return identical bits (the determinism contract kernels build on).
    #[test]
    fn fixed_kind_is_bitwise_deterministic() {
        let mut rng = Rng::new(72);
        let a = rand_vec(&mut rng, 133);
        let b = rand_vec(&mut rng, 133);
        let q: Vec<i8> = (0..133).map(|i| ((i * 37) % 255) as i8).collect();
        for &k in &[SimdKind::Scalar, detect()] {
            let first = dot(k, &a, &b);
            let first_q = dot_q8(k, &q, &b);
            for _ in 0..5 {
                assert_eq!(first.to_bits(), dot(k, &a, &b).to_bits(), "{k:?}");
                assert_eq!(first_q.to_bits(), dot_q8(k, &q, &b).to_bits(), "{k:?} q8");
            }
        }
    }

    /// NaN/Inf propagate through every kind — 0·∞ must poison the result.
    #[test]
    fn non_finite_values_propagate() {
        let a = vec![0.0f32; 16];
        let mut b = vec![1.0f32; 16];
        b[9] = f32::INFINITY;
        for &k in &[SimdKind::Scalar, detect()] {
            assert!(dot(k, &a, &b).is_nan(), "{k:?}: 0·∞ did not poison the dot");
            let mut y = vec![0.0f32; 16];
            axpy(k, 0.0, &b, &mut y);
            assert!(y[9].is_nan(), "{k:?}: 0·∞ did not poison axpy");
        }
    }

    // NOTE: force/unforce semantics are pinned in tests/simd.rs (its own
    // process) — a toggle here would race the lib tests that bit-compare
    // kernels resolved through active().
}
