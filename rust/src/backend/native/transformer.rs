//! The transformer (`t3_*`) family: a causal encoder LM whose projection
//! and FFN matrices are slots of the shared layer graph.
//!
//! Architecture (pre-LN, one residual around each sub-block):
//!
//! ```text
//!   h₀ = E[token] + P[position]                       (dense extras)
//!   for each block i:
//!     h ← h + O( attn( Q(LN₁(h)), K(LN₁(h)), V(LN₁(h)) ) )
//!     h ← h + FC₂( relu( FC₁(LN₂(h)) ) )
//!   logits = LNf(h) · head_Wᵀ
//! ```
//!
//! Q/K/V/O (`d×d`) and FC₁/FC₂ (`d_ff×d`, `d×d_ff`) are [`super::LayerCfg`]
//! slots named `b{i}.q` … `b{i}.fc2`, running through
//! [`layers::linear_forward`] / [`layers::linear_backward`] /
//! [`layers::apply_slots`] — so every method of the paper (KPD
//! factorization with the ℓ1-on-S prox, group-lasso block shrink, RigL
//! block masks, dense) applies to the transformer's weight matrices with
//! zero transformer-specific update code. Embeddings, LayerNorm
//! gains/biases and the vocab head are *dense extras*: plain SGD/momentum
//! leaves appended after the slots in the flat gradient layout
//! ([`dense_extra_layout`]).
//!
//! Attention is exact causal softmax attention, computed head-by-head with
//! the runtime-dispatched SIMD dot/axpy microkernels; the SIMD kind is
//! resolved once per call so results depend only on (inputs, kind). The
//! attention/LayerNorm backbone is method-invariant — it cancels out of
//! every cross-method comparison Table 3 makes.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::backend::{GradOut, TrainState};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::{layers, layers::LinGrads, linalg, simd, Hyper, SpecConfig};

// ------------------------------------------------------------ state layout

/// The dense (non-slot) parameter leaves, in the canonical order they
/// follow the slot leaves in the flat gradient buffer: token + positional
/// embeddings, per-block LayerNorm gains/biases, final LayerNorm, vocab
/// head. Every entry also owns a `{name}.m` momentum buffer.
pub(super) fn dense_extra_layout(cfg: &SpecConfig) -> Vec<(String, usize)> {
    let d = cfg.d_model;
    let mut out = vec![
        ("emb.E".to_string(), cfg.out_dim * d),
        ("emb.P".to_string(), cfg.seq * d),
    ];
    for i in 0..cfg.depth {
        out.push((format!("b{i}.ln1.g"), d));
        out.push((format!("b{i}.ln1.b"), d));
        out.push((format!("b{i}.ln2.g"), d));
        out.push((format!("b{i}.ln2.b"), d));
    }
    out.push(("lnf.g".to_string(), d));
    out.push(("lnf.b".to_string(), d));
    out.push(("head.W".to_string(), cfg.out_dim * d));
    out
}

/// Fresh parameters + momentum for a transformer spec: the slot leaves
/// first (identical RNG order to an mlp over the same slots, through
/// [`layers::init_state_parts`]), then the dense extras — embeddings and
/// head at √(1/d) normal, gains at one, biases at zero.
pub(super) fn init_state_parts(
    cfg: &SpecConfig,
    rng: &mut Rng,
) -> (Vec<String>, Vec<Tensor>, Vec<String>, Vec<Tensor>) {
    let (mut pn, mut ps, mut on, mut os) = layers::init_state_parts(cfg, rng);
    let d = cfg.d_model;
    let std = (1.0 / d as f32).sqrt();
    for (name, _) in dense_extra_layout(cfg) {
        let t = match name.as_str() {
            "emb.E" | "head.W" => Tensor::from_fn(&[cfg.out_dim, d], |_| rng.normal() * std),
            "emb.P" => Tensor::from_fn(&[cfg.seq, d], |_| rng.normal() * std),
            _ if name.ends_with(".g") => Tensor::full(&[d], 1.0),
            _ => Tensor::zeros(&[d]),
        };
        on.push(format!("{name}.m"));
        os.push(Tensor::zeros(t.shape()));
        pn.push(name);
        ps.push(t);
    }
    (pn, ps, on, os)
}

// ---------------------------------------------------------------- forward

/// Per-encoder-block backward caches, one entry per block in depth order.
struct BlockCache {
    ln1_xhat: Vec<f32>,
    ln1_rstd: Vec<f32>,
    /// LN₁ output — the q/k/v slots' input activation
    u1: Vec<f32>,
    q_tp: Vec<Vec<f32>>,
    k_tp: Vec<Vec<f32>>,
    v_tp: Vec<Vec<f32>>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// post-softmax causal attention weights, `[nb, heads, seq, seq]`
    att: Vec<f32>,
    /// attention output (heads re-concatenated) — the o slot's input
    ao: Vec<f32>,
    o_tp: Vec<Vec<f32>>,
    ln2_xhat: Vec<f32>,
    ln2_rstd: Vec<f32>,
    /// LN₂ output — fc1's input activation
    u2: Vec<f32>,
    fc1_tp: Vec<Vec<f32>>,
    /// post-ReLU FFN hidden — fc2's input and the ReLU backward mask
    f: Vec<f32>,
    fc2_tp: Vec<Vec<f32>>,
}

struct FwdCache {
    blocks: Vec<BlockCache>,
    lnf_xhat: Vec<f32>,
    lnf_rstd: Vec<f32>,
    /// final LayerNorm output — the head matmul's input
    uf: Vec<f32>,
}

/// Causal multi-head attention forward: per (batch, head, query) row a
/// max-subtracted softmax over keys `t2 ≤ t1`, then the probability-weighted
/// sum of values. Returns the attention output (`[N, d]`, heads
/// concatenated) and the post-softmax weights (the backward cache).
fn attention_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nb: usize,
    seq: usize,
    heads: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let kind = simd::active();
    let mut att = vec![0.0f32; nb * heads * seq * seq];
    let mut ao = vec![0.0f32; nb * seq * d];
    for b in 0..nb {
        for hh in 0..heads {
            let hoff = hh * dh;
            for t1 in 0..seq {
                let r1 = b * seq + t1;
                let qrow = &q[r1 * d + hoff..r1 * d + hoff + dh];
                let arow = &mut att[((b * heads + hh) * seq + t1) * seq..][..seq];
                let mut amax = f32::NEG_INFINITY;
                for (t2, av) in arow.iter_mut().enumerate().take(t1 + 1) {
                    let r2 = b * seq + t2;
                    let s =
                        simd::dot(kind, qrow, &k[r2 * d + hoff..r2 * d + hoff + dh]) * scale;
                    *av = s;
                    if s > amax {
                        amax = s;
                    }
                }
                let mut esum = 0.0f32;
                for av in arow.iter_mut().take(t1 + 1) {
                    *av = (*av - amax).exp();
                    esum += *av;
                }
                let inv = 1.0 / esum;
                let aorow = &mut ao[r1 * d + hoff..r1 * d + hoff + dh];
                for t2 in 0..=t1 {
                    arow[t2] *= inv;
                    let r2 = b * seq + t2;
                    simd::axpy(kind, arow[t2], &v[r2 * d + hoff..r2 * d + hoff + dh], aorow);
                }
            }
        }
    }
    (ao, att)
}

/// Attention backward from the forward caches: d(loss)/d(attention output)
/// in, (dq, dk, dv) out. Chains through the softmax Jacobian
/// (ds = a ⊙ (da − ⟨da, a⟩)) and the 1/√d_h score scaling.
#[allow(clippy::too_many_arguments)]
fn attention_backward(
    dao: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    att: &[f32],
    nb: usize,
    seq: usize,
    heads: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let kind = simd::active();
    let mut dq = vec![0.0f32; q.len()];
    let mut dk = vec![0.0f32; k.len()];
    let mut dv = vec![0.0f32; v.len()];
    let mut datt = vec![0.0f32; seq];
    for b in 0..nb {
        for hh in 0..heads {
            let hoff = hh * dh;
            for t1 in 0..seq {
                let r1 = b * seq + t1;
                let daorow = &dao[r1 * d + hoff..r1 * d + hoff + dh];
                let arow = &att[((b * heads + hh) * seq + t1) * seq..][..seq];
                for t2 in 0..=t1 {
                    let r2 = b * seq + t2;
                    datt[t2] =
                        simd::dot(kind, daorow, &v[r2 * d + hoff..r2 * d + hoff + dh]);
                    simd::axpy(
                        kind,
                        arow[t2],
                        daorow,
                        &mut dv[r2 * d + hoff..r2 * d + hoff + dh],
                    );
                }
                let mut dot_sum = 0.0f32;
                for t2 in 0..=t1 {
                    dot_sum += datt[t2] * arow[t2];
                }
                for t2 in 0..=t1 {
                    let r2 = b * seq + t2;
                    let ds = arow[t2] * (datt[t2] - dot_sum) * scale;
                    simd::axpy(
                        kind,
                        ds,
                        &k[r2 * d + hoff..r2 * d + hoff + dh],
                        &mut dq[r1 * d + hoff..r1 * d + hoff + dh],
                    );
                    simd::axpy(
                        kind,
                        ds,
                        &q[r1 * d + hoff..r1 * d + hoff + dh],
                        &mut dk[r2 * d + hoff..r2 * d + hoff + dh],
                    );
                }
            }
        }
    }
    (dq, dk, dv)
}

fn run_forward(
    cfg: &SpecConfig,
    state: &TrainState,
    toks: &[i32],
    nb: usize,
) -> Result<(Vec<f32>, FwdCache)> {
    let (d, seq, vocab) = (cfg.d_model, cfg.seq, cfg.out_dim);
    let n = nb * seq;
    debug_assert_eq!(toks.len(), n);
    let e = state.param("emb.E")?;
    let pos = state.param("emb.P")?;
    let mut h = vec![0.0f32; n * d];
    for r in 0..n {
        let tok = toks[r];
        if tok < 0 || tok as usize >= vocab {
            bail!("token id {tok} outside vocabulary [0, {vocab})");
        }
        let erow = &e.data()[tok as usize * d..(tok as usize + 1) * d];
        let t = r % seq;
        let prow = &pos.data()[t * d..(t + 1) * d];
        let hrow = &mut h[r * d..(r + 1) * d];
        for ((hv, &ev), &pv) in hrow.iter_mut().zip(erow).zip(prow) {
            *hv = ev + pv;
        }
    }
    let mut blocks = Vec::with_capacity(cfg.depth);
    for i in 0..cfg.depth {
        let base = i * 6;
        let g1 = state.param(&format!("b{i}.ln1.g"))?;
        let b1 = state.param(&format!("b{i}.ln1.b"))?;
        let (u1, ln1_xhat, ln1_rstd) = linalg::layernorm(&h, g1.data(), b1.data(), n, d);
        let (q, q_tp) = layers::linear_forward(cfg, state, &cfg.layers[base], &u1, n)?;
        let (k, k_tp) = layers::linear_forward(cfg, state, &cfg.layers[base + 1], &u1, n)?;
        let (v, v_tp) = layers::linear_forward(cfg, state, &cfg.layers[base + 2], &u1, n)?;
        let (ao, att) = attention_forward(&q, &k, &v, nb, seq, cfg.heads, d);
        let (out, o_tp) = layers::linear_forward(cfg, state, &cfg.layers[base + 3], &ao, n)?;
        for (hv, ov) in h.iter_mut().zip(&out) {
            *hv += ov;
        }
        let g2 = state.param(&format!("b{i}.ln2.g"))?;
        let b2 = state.param(&format!("b{i}.ln2.b"))?;
        let (u2, ln2_xhat, ln2_rstd) = linalg::layernorm(&h, g2.data(), b2.data(), n, d);
        let (mut f, fc1_tp) =
            layers::linear_forward(cfg, state, &cfg.layers[base + 4], &u2, n)?;
        linalg::relu_inplace(&mut f);
        let (ff, fc2_tp) = layers::linear_forward(cfg, state, &cfg.layers[base + 5], &f, n)?;
        for (hv, fv) in h.iter_mut().zip(&ff) {
            *hv += fv;
        }
        blocks.push(BlockCache {
            ln1_xhat,
            ln1_rstd,
            u1,
            q_tp,
            k_tp,
            v_tp,
            q,
            k,
            v,
            att,
            ao,
            o_tp,
            ln2_xhat,
            ln2_rstd,
            u2,
            fc1_tp,
            f,
            fc2_tp,
        });
    }
    let gf = state.param("lnf.g")?;
    let bf = state.param("lnf.b")?;
    let (uf, lnf_xhat, lnf_rstd) = linalg::layernorm(&h, gf.data(), bf.data(), n, d);
    let head = state.param("head.W")?;
    let logits = linalg::matmul_nt(&uf, head.data(), n, d, vocab);
    Ok((logits, FwdCache { blocks, lnf_xhat, lnf_rstd, uf }))
}

// --------------------------------------------------------------- backward

/// Reverse walk from d(loss)/d(logits): per-slot gradients (layer order)
/// plus the dense-extra gradients ([`dense_extra_layout`] order).
fn run_backward(
    cfg: &SpecConfig,
    state: &TrainState,
    fc: &FwdCache,
    dz: &[f32],
    nb: usize,
    toks: &[i32],
) -> Result<(Vec<LinGrads>, Vec<Vec<f32>>)> {
    let (d, seq, vocab) = (cfg.d_model, cfg.seq, cfg.out_dim);
    let n = nb * seq;
    let head = state.param("head.W")?;
    let d_head = linalg::matmul_tn(dz, &fc.uf, n, vocab, d);
    let duf = linalg::matmul_nn(dz, head.data(), n, vocab, d);
    let gf = state.param("lnf.g")?;
    let (mut dh, dg_f, db_f) =
        linalg::layernorm_backward(&duf, &fc.lnf_xhat, &fc.lnf_rstd, gf.data(), n, d);
    let mut slot_grads: Vec<Option<LinGrads>> =
        (0..cfg.layers.len()).map(|_| None).collect();
    let mut extras: Vec<Vec<f32>> = vec![Vec::new(); 5 + 4 * cfg.depth];
    extras[2 + 4 * cfg.depth] = dg_f;
    extras[3 + 4 * cfg.depth] = db_f;
    extras[4 + 4 * cfg.depth] = d_head;
    for i in (0..cfg.depth).rev() {
        let base = i * 6;
        let bc = &fc.blocks[i];
        // FFN branch: dh feeds both the residual and fc2
        let (g_fc2, df) =
            layers::linear_backward(cfg, state, &cfg.layers[base + 5], &bc.f, &bc.fc2_tp, &dh, n, true)?;
        let mut df = df.expect("fc2 backward with need_dx");
        linalg::relu_backward(&mut df, &bc.f);
        let (g_fc1, du2) =
            layers::linear_backward(cfg, state, &cfg.layers[base + 4], &bc.u2, &bc.fc1_tp, &df, n, true)?;
        let du2 = du2.expect("fc1 backward with need_dx");
        let g2 = state.param(&format!("b{i}.ln2.g"))?;
        let (dx2, dg2, db2) =
            linalg::layernorm_backward(&du2, &bc.ln2_xhat, &bc.ln2_rstd, g2.data(), n, d);
        for (hv, xv) in dh.iter_mut().zip(&dx2) {
            *hv += xv;
        }
        // attention branch
        let (g_o, dao) =
            layers::linear_backward(cfg, state, &cfg.layers[base + 3], &bc.ao, &bc.o_tp, &dh, n, true)?;
        let dao = dao.expect("o backward with need_dx");
        let (dq, dk, dv) =
            attention_backward(&dao, &bc.q, &bc.k, &bc.v, &bc.att, nb, seq, cfg.heads, d);
        let (g_q, du1q) =
            layers::linear_backward(cfg, state, &cfg.layers[base], &bc.u1, &bc.q_tp, &dq, n, true)?;
        let (g_k, du1k) =
            layers::linear_backward(cfg, state, &cfg.layers[base + 1], &bc.u1, &bc.k_tp, &dk, n, true)?;
        let (g_v, du1v) =
            layers::linear_backward(cfg, state, &cfg.layers[base + 2], &bc.u1, &bc.v_tp, &dv, n, true)?;
        let mut du1 = du1q.expect("q backward with need_dx");
        let du1k = du1k.expect("k backward with need_dx");
        let du1v = du1v.expect("v backward with need_dx");
        for ((a, b), c) in du1.iter_mut().zip(&du1k).zip(&du1v) {
            *a += b + c;
        }
        let g1 = state.param(&format!("b{i}.ln1.g"))?;
        let (dx1, dg1, db1) =
            linalg::layernorm_backward(&du1, &bc.ln1_xhat, &bc.ln1_rstd, g1.data(), n, d);
        for (hv, xv) in dh.iter_mut().zip(&dx1) {
            *hv += xv;
        }
        slot_grads[base] = Some(g_q);
        slot_grads[base + 1] = Some(g_k);
        slot_grads[base + 2] = Some(g_v);
        slot_grads[base + 3] = Some(g_o);
        slot_grads[base + 4] = Some(g_fc1);
        slot_grads[base + 5] = Some(g_fc2);
        extras[2 + 4 * i] = dg1;
        extras[3 + 4 * i] = db1;
        extras[4 + 4 * i] = dg2;
        extras[5 + 4 * i] = db2;
    }
    // embedding scatter: each residual-stream row gradient accumulates
    // into its token's E row and its position's P row
    let mut de = vec![0.0f32; vocab * d];
    let mut dp = vec![0.0f32; seq * d];
    for r in 0..n {
        let tok = toks[r] as usize;
        let src = &dh[r * d..(r + 1) * d];
        let dst = &mut de[tok * d..(tok + 1) * d];
        for (dv, &sv) in dst.iter_mut().zip(src) {
            *dv += sv;
        }
    }
    for r in 0..n {
        let t = r % seq;
        let src = &dh[r * d..(r + 1) * d];
        let dst = &mut dp[t * d..(t + 1) * d];
        for (dv, &sv) in dst.iter_mut().zip(src) {
            *dv += sv;
        }
    }
    extras[0] = de;
    extras[1] = dp;
    Ok((layers::collect_grads(cfg, slot_grads)?, extras))
}

// ------------------------------------------------------------- step paths

/// The one copy of the transformer update: slot leaves through
/// [`layers::apply_slots`] (method-specific prox/mask updates, metric
/// assembly), then plain SGD/momentum on every dense extra.
fn apply(
    cfg: &SpecConfig,
    state: &mut TrainState,
    slots: Vec<LinGrads>,
    extras: &[Vec<f32>],
    ce_mean: f32,
    acc_frac: f32,
    h: &Hyper,
) -> Result<Vec<f32>> {
    let out = layers::apply_slots(cfg, state, slots, ce_mean, acc_frac, h)?;
    for ((name, len), g) in dense_extra_layout(cfg).iter().zip(extras) {
        debug_assert_eq!(g.len(), *len, "extra '{name}' gradient length");
        let pi = super::pidx(state, name)?;
        let vi = super::oidx(state, &format!("{name}.m"))?;
        super::sgd_momentum(
            state.params[pi].data_mut(),
            state.opt[vi].data_mut(),
            g,
            h.lr,
            cfg.momentum,
        );
    }
    Ok(out)
}

/// One fused training step on a token batch. Metrics follow the mlp
/// layout: `[loss, ce, acc]` (token-level, CE per token), KPD adds the
/// whole-model `s_l1` plus per-slot `s_l1_{slot}`, RigL the unnamed
/// gradient-norm tail.
pub(super) fn train_step(
    cfg: &SpecConfig,
    state: &mut TrainState,
    toks: &[i32],
    nb: usize,
    targets: &[i32],
    h: &Hyper,
) -> Result<Vec<f32>> {
    let (z, fc) = run_forward(cfg, state, toks, nb)?;
    let sm = linalg::softmax_ce(&z, targets, nb * cfg.seq, cfg.out_dim)?;
    let (slots, extras) = run_backward(cfg, state, &fc, &sm.dz, nb, toks)?;
    apply(cfg, state, slots, &extras, sm.ce_mean, sm.acc_frac, h)
}

/// Gradient half for data-parallel sharding: per-*sequence* gradient sums
/// (examples are sequences, matching the batch axis the shard planner
/// splits), flattened slots-then-extras. `correct` is reported in
/// fractional sequence-equivalents (`correct_tokens / seq`) so the
/// reducer's `correct / examples` is exactly token-level accuracy.
pub(super) fn grad_step(
    cfg: &SpecConfig,
    state: &TrainState,
    toks: &[i32],
    nb: usize,
    targets: &[i32],
) -> Result<GradOut> {
    let (z, fc) = run_forward(cfg, state, toks, nb)?;
    let mut sm = linalg::softmax_ce(&z, targets, nb * cfg.seq, cfg.out_dim)?;
    super::scale_to_sum(&mut sm.dz, nb);
    let (slots, extras) = run_backward(cfg, state, &fc, &sm.dz, nb, toks)?;
    let mut grad_sum = Vec::new();
    for g in slots {
        match g {
            LinGrads::Kpd(g) => {
                grad_sum.extend(g.gs);
                grad_sum.extend(g.ga);
                grad_sum.extend(g.gb);
            }
            LinGrads::Dense(gw) => grad_sum.extend(gw),
        }
    }
    for g in extras {
        grad_sum.extend(g);
    }
    Ok(GradOut {
        grad_sum,
        ce_sum: sm.ce_mean * nb as f32,
        correct: sm.correct / cfg.seq as f32,
        examples: nb,
    })
}

/// Update half for a reduced flat mean-gradient buffer: split at the slot
/// boundary, unflatten each side, run the shared [`apply`].
pub(super) fn apply_update(
    cfg: &SpecConfig,
    state: &mut TrainState,
    grad: &[f32],
    ce_mean: f32,
    acc_frac: f32,
    h: &Hyper,
) -> Result<Vec<f32>> {
    let slot_total: usize = layers::grad_layout(cfg).iter().map(|(_, l)| l).sum();
    if grad.len() < slot_total {
        bail!("transformer gradient buffer shorter than its slot section");
    }
    let (sg, eg) = grad.split_at(slot_total);
    let slots = layers::unflatten(cfg, sg)?;
    let mut extras = Vec::new();
    let mut off = 0usize;
    for (name, len) in dense_extra_layout(cfg) {
        if off + len > eg.len() {
            bail!("gradient buffer too short for extra '{name}'");
        }
        extras.push(eg[off..off + len].to_vec());
        off += len;
    }
    if off != eg.len() {
        bail!("gradient buffer has {} extra values, layout wants {off}", eg.len());
    }
    apply(cfg, state, slots, &extras, ce_mean, acc_frac, h)
}

/// `[per-token mean CE, correct token count]` — the trainer's evaluate
/// divides the count by examples·seq (the token axis) for accuracy.
pub(super) fn eval_step(
    cfg: &SpecConfig,
    state: &TrainState,
    toks: &[i32],
    nb: usize,
    targets: &[i32],
) -> Result<Vec<f32>> {
    let (z, _) = run_forward(cfg, state, toks, nb)?;
    let sm = linalg::softmax_ce(&z, targets, nb * cfg.seq, cfg.out_dim)?;
    Ok(vec![sm.ce_mean, sm.correct])
}

/// Next-token logits (`[nb·seq, vocab]`) of a token batch — the eval/FD
/// entry point.
pub fn forward_logits(
    cfg: &SpecConfig,
    state: &TrainState,
    toks: &[i32],
    nb: usize,
) -> Result<Vec<f32>> {
    Ok(run_forward(cfg, state, toks, nb)?.0)
}

/// Mean token CE and the raw analytic gradients of *every* leaf — slots
/// (`b0.q.S`/`b0.q.W`, ...) and dense extras (`emb.E`, `b0.ln1.g`,
/// `head.W`, ...) by name. Gradients are of the unregularized CE
/// objective, exactly what central differences of [`forward_logits`]+CE
/// measure; the property suite drives LayerNorm, attention and embedding
/// backward through this.
pub fn loss_and_grads(
    cfg: &SpecConfig,
    state: &TrainState,
    toks: &[i32],
    nb: usize,
    targets: &[i32],
) -> Result<(f32, BTreeMap<String, Vec<f32>>)> {
    let (z, fc) = run_forward(cfg, state, toks, nb)?;
    let sm = linalg::softmax_ce(&z, targets, nb * cfg.seq, cfg.out_dim)?;
    let (slots, extras) = run_backward(cfg, state, &fc, &sm.dz, nb, toks)?;
    let mut out = BTreeMap::new();
    for (lc, g) in cfg.layers.iter().zip(slots) {
        match g {
            LinGrads::Kpd(g) => {
                out.insert(layers::p(lc, "S"), g.gs);
                out.insert(layers::p(lc, "A"), g.ga);
                out.insert(layers::p(lc, "B"), g.gb);
            }
            LinGrads::Dense(gw) => {
                out.insert(layers::p(lc, "W"), gw);
            }
        }
    }
    for ((name, _), g) in dense_extra_layout(cfg).iter().zip(extras) {
        out.insert(name.clone(), g);
    }
    Ok((sm.ce_mean, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::backend::Backend;
    use crate::tensor::HostValue;

    fn tiny(method: &str) -> SpecConfig {
        // vocab 12, seq 4, d 8, 2 heads, d_ff 16, 2 blocks, 2×2 blocks
        SpecConfig::transformer("tt", "lm_tiny", method, 12, 4, 8, 2, 16, 2, 2, 2, 2, 4)
    }

    fn token_batch(cfg: &SpecConfig, nb: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let n = nb * cfg.seq;
        let toks: Vec<i32> =
            (0..n).map(|_| (rng.normal().abs() * 37.0) as i32 % cfg.out_dim as i32).collect();
        let targets: Vec<i32> = (0..n).map(|i| toks[(i + 1) % n]).collect();
        (toks, targets)
    }

    #[test]
    fn extra_layout_and_init_cover_every_dense_leaf() {
        let cfg = tiny("kpd");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let state = be.init_state("tt", 3).unwrap();
        for (name, len) in dense_extra_layout(&cfg) {
            let t = state.param(&name).unwrap();
            assert_eq!(t.len(), len, "{name}");
            assert!(state.opt_names.iter().any(|n| *n == format!("{name}.m")), "{name}.m");
        }
        // gains start at one, biases at zero, S at one
        assert!(state.param("b0.ln1.g").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(state.param("lnf.b").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(state.param("b0.q.S").unwrap().data().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn forward_is_causal() {
        // changing a future token must not change any earlier position's
        // logits (the causal mask is the whole point of the LM head)
        let cfg = tiny("dense");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let state = be.init_state("tt", 7).unwrap();
        let (mut toks, _) = token_batch(&cfg, 1, 11);
        let z0 = forward_logits(&cfg, &state, &toks, 1).unwrap();
        let last = cfg.seq - 1;
        toks[last] = (toks[last] + 1) % cfg.out_dim as i32;
        let z1 = forward_logits(&cfg, &state, &toks, 1).unwrap();
        let vocab = cfg.out_dim;
        assert_eq!(
            &z0[..last * vocab],
            &z1[..last * vocab],
            "future token leaked into earlier logits"
        );
        assert_ne!(&z0[last * vocab..], &z1[last * vocab..], "embedding had no effect");
    }

    #[test]
    fn every_method_steps_and_evals() {
        for method in ["kpd", "group_lasso", "elastic_gl", "rigl_block", "dense"] {
            let cfg = tiny(method);
            let be = NativeBackend::from_spec(cfg.clone()).unwrap();
            let entry = be.spec("tt").unwrap().clone();
            let mut state = be.init_state("tt", 0).unwrap();
            let (toks, targets) = token_batch(&cfg, 4, 5);
            let bx = HostValue::I32 { shape: vec![4, cfg.seq], data: toks };
            let by = HostValue::I32 { shape: vec![4, cfg.seq], data: targets };
            let hyper: Vec<f32> = entry
                .hyper
                .iter()
                .map(|h| match h.as_str() {
                    "lr" => 0.05,
                    "lambda2" => 1e-4,
                    _ => 0.01,
                })
                .collect();
            let m = be.train_step(&mut state, &bx, &by, &hyper).unwrap();
            let gn = be.gnorm_len("tt").unwrap();
            assert_eq!(m.len(), entry.metrics.len() + gn, "{method}");
            assert!(m.iter().all(|v| v.is_finite()), "{method}: {m:?}");
            let e = be.eval_step(&state, &bx, &by).unwrap();
            assert_eq!(e.len(), 2, "{method}");
            assert!(e[0].is_finite(), "{method}");
            assert!((0.0..=(4 * cfg.seq) as f32).contains(&e[1]), "{method}");
        }
    }

    #[test]
    fn grad_apply_matches_fused_step() {
        // one shard covering the whole (power-of-two) batch: grad_step's
        // ×nb sum then apply_update's ×1/nb mean are exact in f32, so the
        // separated path must land bit-identical to the fused step
        for method in ["dense", "kpd"] {
            let cfg = tiny(method);
            let be = NativeBackend::from_spec(cfg.clone()).unwrap();
            let entry = be.spec("tt").unwrap().clone();
            let (toks, targets) = token_batch(&cfg, 4, 9);
            let bx = HostValue::I32 { shape: vec![4, cfg.seq], data: toks };
            let by = HostValue::I32 { shape: vec![4, cfg.seq], data: targets };
            let hyper: Vec<f32> =
                entry.hyper.iter().map(|h| if h == "lr" { 0.05 } else { 0.01 }).collect();
            let mut fused = be.init_state("tt", 2).unwrap();
            let mf = be.train_step(&mut fused, &bx, &by, &hyper).unwrap();
            let mut split = be.init_state("tt", 2).unwrap();
            let go = be.grad_step(&split, &bx, &by).unwrap();
            assert_eq!(go.grad_sum.len(), be.grad_len("tt").unwrap(), "{method}");
            let inv = 1.0 / go.examples as f32;
            let grad: Vec<f32> = go.grad_sum.iter().map(|v| v * inv).collect();
            let ms = be
                .apply_update(&mut split, grad, go.ce_sum * inv, go.correct * inv, &hyper)
                .unwrap();
            assert_eq!(mf, ms, "{method}: metrics diverged");
            for (n, t) in fused.param_names.iter().zip(&fused.params) {
                assert_eq!(t.data(), split.param(n).unwrap().data(), "{method}: '{n}'");
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_a_fixed_batch() {
        let cfg = tiny("dense");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let mut state = be.init_state("tt", 1).unwrap();
        let (toks, targets) = token_batch(&cfg, 4, 3);
        let bx = HostValue::I32 { shape: vec![4, cfg.seq], data: toks };
        let by = HostValue::I32 { shape: vec![4, cfg.seq], data: targets };
        let first = be.train_step(&mut state, &bx, &by, &[0.1]).unwrap()[1];
        let mut last = first;
        for _ in 0..30 {
            last = be.train_step(&mut state, &bx, &by, &[0.1]).unwrap()[1];
        }
        assert!(
            last < first * 0.9,
            "30 steps did not reduce CE: {first} -> {last}"
        );
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let cfg = tiny("dense");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let state = be.init_state("tt", 0).unwrap();
        let mut toks = vec![0i32; cfg.seq];
        toks[1] = cfg.out_dim as i32; // one past the vocabulary
        assert!(forward_logits(&cfg, &state, &toks, 1).is_err());
        toks[1] = -1;
        assert!(forward_logits(&cfg, &state, &toks, 1).is_err());
    }
}
