//! The composable layer-graph core every native family runs on.
//!
//! A spec's [`LayerCfg`] list describes linear *slots*; this module owns
//! the per-slot primitives — [`linear_forward`] caching, [`linear_backward`]
//! chaining, [`apply_slots`] (fused SGD/momentum + prox), gradient
//! flattening/unflattening, RigL/prune hooks, and state init — and the
//! sequential ReLU [`stack`] the `linear`/`mlp` families run directly.
//! `pattern.rs` (per-candidate stacks) and `transformer.rs` (embedding +
//! attention + FFN graphs) are thin drivers over the same slot primitives,
//! so the fused, sharded and pattern paths cannot drift. Every method of
//! the original single-slot path runs unchanged on any slot:
//!
//! * `kpd`          — each slot holds its own (S, A, B) factorization; the
//!   hidden slots' backward chains dZ through [`kpd::backward_dx`];
//! * `group_lasso` / `elastic_gl` — dense per-slot W, per-slot block prox;
//! * `rigl_block`   — per-slot block masks, drop/grow *within* each slot
//!   (the concatenated gradient-norm layout keeps per-slot budgets);
//! * `iter_prune`   — per-slot element masks, *global* magnitude ranking;
//! * `dense`        — the baseline.
//!
//! The forward pass caches each slot's input activation (plus the KPD T′
//! buffers), so the backward pass is one reverse walk: softmax dZ → last
//! slot grads → dX → ReLU mask → ... → first slot grads. All matmuls are
//! the cache-blocked/threaded kernels in [`linalg`]; the per-slot updates
//! are the same SGD/momentum + proximal steps the single-slot path takes.
//!
//! Parameter naming is `{slot}.{leaf}` (`fc1.S`, `fc2.W`, `fc2.mask`, ...),
//! which is exactly the layout `coordinator::probe` reads per slot.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::backend::{GradOut, TrainState};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::{
    block_fro, block_prox, kpd, linalg, mul_expand_mask, oidx, param_pair_mut, pidx,
    sgd_momentum, sgd_momentum_block_masked, sgd_momentum_l2, sgd_momentum_masked,
    sgd_prox_l1, Hyper, LayerCfg, SpecConfig,
};

/// One step of the sequential stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    /// Input reshape marker — identity here (batches arrive flat), kept so
    /// stacks read like the architecture they implement.
    Flatten,
    /// Elementwise max(·, 0) between linear slots.
    Relu,
    /// Linear slot `cfg.layers[i]` under the spec's parameterization.
    Linear(usize),
}

/// The stack an `mlp` spec runs: flatten, then linear slots with ReLU
/// between consecutive slots (none after the logits).
pub fn stack(cfg: &SpecConfig) -> Vec<Layer> {
    let mut out = vec![Layer::Flatten];
    for i in 0..cfg.layers.len() {
        if i > 0 {
            out.push(Layer::Relu);
        }
        out.push(Layer::Linear(i));
    }
    out
}

/// Per-layer forward cache, aligned with [`stack`].
enum Cache {
    /// nothing to keep (flatten)
    Empty,
    /// post-activation y = max(x, 0) — the backward mask
    Relu(Vec<f32>),
    /// the slot's input activation + per-rank KPD T′ buffers (empty for
    /// non-factorized methods)
    Slot(Vec<f32>, Vec<Vec<f32>>),
}

/// Gradients of one linear slot.
pub(super) enum LinGrads {
    /// (gs, ga, gb) of a KPD-factorized slot
    Kpd(kpd::Grads),
    /// dense dW = dZᵀ·X (pre-masking — RigL reads its growth signal from
    /// this, the update step masks what is applied)
    Dense(Vec<f32>),
}

pub(super) fn p(lc: &LayerCfg, leaf: &str) -> String {
    format!("{}.{}", lc.name, leaf)
}

// --------------------------------------------------------------- forward

pub(super) fn linear_forward(
    cfg: &SpecConfig,
    state: &TrainState,
    lc: &LayerCfg,
    x: &[f32],
    nb: usize,
) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    debug_assert_eq!(x.len(), nb * lc.n);
    match cfg.method.as_str() {
        "kpd" | "pattern_kpd" => {
            let d = lc.dims(cfg.rank);
            let s = state.param(&p(lc, "S"))?;
            let a = state.param(&p(lc, "A"))?;
            let b = state.param(&p(lc, "B"))?;
            let (z, tp) = kpd::forward(x, nb, s.data(), a.data(), b.data(), d);
            Ok((z, tp))
        }
        "rigl_block" => {
            let w = state.param(&p(lc, "W"))?;
            let mask = state.param(&p(lc, "mask"))?;
            Ok((
                linalg::block_sparse_matmul_nt(
                    x,
                    w.data(),
                    mask.data(),
                    nb,
                    lc.m,
                    lc.n,
                    lc.m2,
                    lc.n2,
                )?,
                Vec::new(),
            ))
        }
        "iter_prune" => {
            let w = state.param(&p(lc, "W"))?;
            let emask = state.param(&p(lc, "emask"))?;
            let weff: Vec<f32> =
                w.data().iter().zip(emask.data()).map(|(a, b)| a * b).collect();
            Ok((linalg::matmul_nt(x, &weff, nb, lc.n, lc.m), Vec::new()))
        }
        _ => {
            let w = state.param(&p(lc, "W"))?;
            Ok((linalg::matmul_nt(x, w.data(), nb, lc.n, lc.m), Vec::new()))
        }
    }
}

fn run_forward(
    cfg: &SpecConfig,
    state: &TrainState,
    st: &[Layer],
    x: &[f32],
    nb: usize,
) -> Result<(Vec<f32>, Vec<Cache>)> {
    let mut cur = x.to_vec();
    let mut caches = Vec::with_capacity(st.len());
    for layer in st {
        match layer {
            Layer::Flatten => caches.push(Cache::Empty),
            Layer::Relu => {
                linalg::relu_inplace(&mut cur);
                // the clone duplicates the next Slot cache's input, but
                // keeps the backward walk free of cross-cache adjacency
                // assumptions; ~nb·width f32 per hidden layer is noise
                // next to the slot matmuls
                caches.push(Cache::Relu(cur.clone()));
            }
            Layer::Linear(i) => {
                let lc = &cfg.layers[*i];
                let (z, tp) = linear_forward(cfg, state, lc, &cur, nb)?;
                caches.push(Cache::Slot(std::mem::replace(&mut cur, z), tp));
            }
        }
    }
    Ok((cur, caches))
}

/// Logits of the full stack on a flat batch (N × in_dim).
pub fn forward_logits(
    cfg: &SpecConfig,
    state: &TrainState,
    x: &[f32],
    nb: usize,
) -> Result<Vec<f32>> {
    let st = stack(cfg);
    Ok(run_forward(cfg, state, &st, x, nb)?.0)
}

// -------------------------------------------------------------- backward

/// The slot's weight as the forward pass actually applied it (masked for
/// RigL/pruning) — what dX must chain through.
fn effective_w(cfg: &SpecConfig, state: &TrainState, lc: &LayerCfg) -> Result<Vec<f32>> {
    let w = state.param(&p(lc, "W"))?;
    match cfg.method.as_str() {
        "rigl_block" => {
            let mut weff = w.data().to_vec();
            let mask = state.param(&p(lc, "mask"))?;
            mul_expand_mask(&mut weff, mask.data(), lc.m, lc.n, lc.m2, lc.n2);
            Ok(weff)
        }
        "iter_prune" => {
            let emask = state.param(&p(lc, "emask"))?;
            Ok(w.data().iter().zip(emask.data()).map(|(a, b)| a * b).collect())
        }
        _ => Ok(w.data().to_vec()),
    }
}

#[allow(clippy::too_many_arguments)]
pub(super) fn linear_backward(
    cfg: &SpecConfig,
    state: &TrainState,
    lc: &LayerCfg,
    x: &[f32],
    tprimes: &[Vec<f32>],
    dz: &[f32],
    nb: usize,
    need_dx: bool,
) -> Result<(LinGrads, Option<Vec<f32>>)> {
    if cfg.method == "kpd" || cfg.method == "pattern_kpd" {
        let d = lc.dims(cfg.rank);
        let s = state.param(&p(lc, "S"))?;
        let a = state.param(&p(lc, "A"))?;
        if need_dx {
            let b = state.param(&p(lc, "B"))?;
            let (g, dx) =
                kpd::backward_dx(x, nb, s.data(), a.data(), b.data(), dz, tprimes, d);
            Ok((LinGrads::Kpd(g), Some(dx)))
        } else {
            let g = kpd::backward(x, nb, s.data(), a.data(), dz, tprimes, d);
            Ok((LinGrads::Kpd(g), None))
        }
    } else {
        let gw = linalg::matmul_tn(dz, x, nb, lc.m, lc.n);
        let dx = if !need_dx {
            None
        } else if cfg.method == "rigl_block" || cfg.method == "iter_prune" {
            let weff = effective_w(cfg, state, lc)?;
            Some(linalg::matmul_nn(dz, &weff, nb, lc.m, lc.n))
        } else {
            // unmasked methods chain through W directly — no copy
            let w = state.param(&p(lc, "W"))?;
            Some(linalg::matmul_nn(dz, w.data(), nb, lc.m, lc.n))
        };
        Ok((LinGrads::Dense(gw), dx))
    }
}

/// Reverse walk: dZ of the logits in, per-slot gradients out. The chain
/// stops at the first slot (its input gradient is never needed).
fn run_backward(
    cfg: &SpecConfig,
    state: &TrainState,
    st: &[Layer],
    caches: &[Cache],
    dz: Vec<f32>,
    nb: usize,
) -> Result<Vec<Option<LinGrads>>> {
    let mut grads: Vec<Option<LinGrads>> = (0..cfg.layers.len()).map(|_| None).collect();
    let mut dcur = dz;
    for (layer, cache) in st.iter().zip(caches.iter()).rev() {
        match (layer, cache) {
            (Layer::Flatten, Cache::Empty) => {}
            (Layer::Relu, Cache::Relu(y)) => linalg::relu_backward(&mut dcur, y),
            (Layer::Linear(i), Cache::Slot(x, tprimes)) => {
                let need_dx = *i > 0;
                let (g, dx) = linear_backward(
                    cfg,
                    state,
                    &cfg.layers[*i],
                    x,
                    tprimes,
                    &dcur,
                    nb,
                    need_dx,
                )?;
                grads[*i] = Some(g);
                match dx {
                    Some(dx) => dcur = dx,
                    None => break,
                }
            }
            _ => bail!("mlp backward: cache does not match the stack layout"),
        }
    }
    Ok(grads)
}

/// Mean softmax-CE loss and the raw analytic gradients of every slot leaf
/// (`fc1.S`/`fc1.A`/`fc1.B` for KPD specs, `fc{i}.W` otherwise) — the hook
/// the multi-layer finite-difference property test drives. Gradients are
/// of the *unregularized* CE objective, before any masking: exactly what
/// central differences of [`forward_logits`]+CE measure.
pub fn loss_and_grads(
    cfg: &SpecConfig,
    state: &TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
) -> Result<(f32, BTreeMap<String, Vec<f32>>)> {
    let st = stack(cfg);
    let (z, caches) = run_forward(cfg, state, &st, x, nb)?;
    let sm = linalg::softmax_ce(&z, y, nb, cfg.out_dim)?;
    let grads = run_backward(cfg, state, &st, &caches, sm.dz, nb)?;
    let mut out = BTreeMap::new();
    for (lc, g) in cfg.layers.iter().zip(grads) {
        match g {
            Some(LinGrads::Kpd(g)) => {
                out.insert(p(lc, "S"), g.gs);
                out.insert(p(lc, "A"), g.ga);
                out.insert(p(lc, "B"), g.gb);
            }
            Some(LinGrads::Dense(gw)) => {
                out.insert(p(lc, "W"), gw);
            }
            None => bail!("mlp backward left slot '{}' without gradients", lc.name),
        }
    }
    Ok((sm.ce_mean, out))
}

// ------------------------------------------------------------ train step

/// One training step of the stack. Metrics: `[loss, ce, acc]`, then for
/// KPD `s_l1` (whole model) plus, on multi-slot specs, one `s_l1_{slot}`
/// per layer (pre-update), then for RigL the concatenated per-slot
/// dense-gradient block norms (unnamed tail, length `gnorm_len`).
pub(super) fn train_step(
    cfg: &SpecConfig,
    state: &mut TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
    h: &Hyper,
) -> Result<Vec<f32>> {
    let st = stack(cfg);
    let (z, caches) = run_forward(cfg, state, &st, x, nb)?;
    let sm = linalg::softmax_ce(&z, y, nb, cfg.out_dim)?;
    let grads = collect_grads(cfg, run_backward(cfg, state, &st, &caches, sm.dz, nb)?)?;
    apply_slots(cfg, state, grads, sm.ce_mean, sm.acc_frac, h)
}

/// Gradient half of the stack ([`crate::backend::Backend::grad_step`]):
/// per-example gradient *sums* of every slot leaf, flattened in
/// [`grad_layout`] order, plus the shard's summed loss/accuracy stats.
/// The state is untouched; masking and regularizer terms are
/// state-dependent and belong to [`apply_update`].
pub(super) fn grad_step(
    cfg: &SpecConfig,
    state: &TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
) -> Result<GradOut> {
    let st = stack(cfg);
    let (z, caches) = run_forward(cfg, state, &st, x, nb)?;
    let mut sm = linalg::softmax_ce(&z, y, nb, cfg.out_dim)?;
    super::scale_to_sum(&mut sm.dz, nb);
    let grads = collect_grads(cfg, run_backward(cfg, state, &st, &caches, sm.dz, nb)?)?;
    let mut grad_sum = Vec::new();
    for g in grads {
        match g {
            LinGrads::Kpd(g) => {
                grad_sum.extend(g.gs);
                grad_sum.extend(g.ga);
                grad_sum.extend(g.gb);
            }
            LinGrads::Dense(gw) => grad_sum.extend(gw),
        }
    }
    Ok(GradOut {
        grad_sum,
        ce_sum: sm.ce_mean * nb as f32,
        correct: sm.correct,
        examples: nb,
    })
}

/// Update half for a reduced flat mean-gradient buffer: slice it back
/// into per-slot leaves and run the same per-slot update the fused step
/// runs.
pub(super) fn apply_update(
    cfg: &SpecConfig,
    state: &mut TrainState,
    grad: &[f32],
    ce_mean: f32,
    acc_frac: f32,
    h: &Hyper,
) -> Result<Vec<f32>> {
    apply_slots(cfg, state, unflatten(cfg, grad)?, ce_mean, acc_frac, h)
}

/// Flat gradient-buffer layout of the stack, slot by slot in layer order.
pub(super) fn grad_layout(cfg: &SpecConfig) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for lc in &cfg.layers {
        if cfg.method == "kpd" {
            let d = lc.dims(cfg.rank);
            out.push((p(lc, "S"), d.m1 * d.n1));
            out.push((p(lc, "A"), d.r * d.m1 * d.n1));
            out.push((p(lc, "B"), d.r * d.m2 * d.n2));
        } else {
            out.push((p(lc, "W"), lc.m * lc.n));
        }
    }
    out
}

pub(super) fn collect_grads(
    cfg: &SpecConfig,
    grads: Vec<Option<LinGrads>>,
) -> Result<Vec<LinGrads>> {
    cfg.layers
        .iter()
        .zip(grads)
        .map(|(lc, g)| {
            g.ok_or_else(|| anyhow!("mlp backward left slot '{}' without gradients", lc.name))
        })
        .collect()
}

pub(super) fn unflatten(cfg: &SpecConfig, grad: &[f32]) -> Result<Vec<LinGrads>> {
    let mut out = Vec::with_capacity(cfg.layers.len());
    let mut off = 0usize;
    for (name, len) in grad_layout(cfg) {
        if off + len > grad.len() {
            bail!("gradient buffer too short for leaf '{name}'");
        }
        let slice = grad[off..off + len].to_vec();
        off += len;
        if name.ends_with(".W") {
            out.push(LinGrads::Dense(slice));
        } else if name.ends_with(".S") {
            out.push(LinGrads::Kpd(kpd::Grads { gs: slice, ga: Vec::new(), gb: Vec::new() }));
        } else if let Some(LinGrads::Kpd(g)) = out.last_mut() {
            if name.ends_with(".A") {
                g.ga = slice;
            } else {
                g.gb = slice;
            }
        } else {
            bail!("gradient leaf '{name}' arrived out of order");
        }
    }
    if off != grad.len() {
        bail!("gradient buffer has {} values, layout wants {off}", grad.len());
    }
    Ok(out)
}

/// The per-slot optimizer/prox updates on mean gradients — the one copy
/// of the update math, shared by the fused [`train_step`], the
/// data-parallel [`apply_update`], and the transformer driver (which runs
/// it over its projection/FFN slots before updating its dense extras).
pub(super) fn apply_slots(
    cfg: &SpecConfig,
    state: &mut TrainState,
    grads: Vec<LinGrads>,
    ce_mean: f32,
    acc_frac: f32,
    h: &Hyper,
) -> Result<Vec<f32>> {
    let method = cfg.method.as_str();
    let mu = cfg.momentum;
    let mut reg = 0.0f32;
    let mut s_l1_per: Vec<f32> = Vec::new();
    let mut gnorm_tail: Vec<f32> = Vec::new();
    for (lc, g) in cfg.layers.iter().zip(grads) {
        match g {
            LinGrads::Kpd(g) => {
                let s_l1 = state.param(&p(lc, "S"))?.abs_sum();
                s_l1_per.push(s_l1);
                reg += h.lam * s_l1;
                let (ai, avi) = (pidx(state, &p(lc, "A"))?, oidx(state, &p(lc, "A.m"))?);
                sgd_momentum(
                    state.params[ai].data_mut(),
                    state.opt[avi].data_mut(),
                    &g.ga,
                    h.lr,
                    mu,
                );
                let (bi, bvi) = (pidx(state, &p(lc, "B"))?, oidx(state, &p(lc, "B.m"))?);
                sgd_momentum(
                    state.params[bi].data_mut(),
                    state.opt[bvi].data_mut(),
                    &g.gb,
                    h.lr,
                    mu,
                );
                // S: plain SGD fused with the ℓ1 prox → exact zeros kill
                // whole blocks
                let si = pidx(state, &p(lc, "S"))?;
                sgd_prox_l1(state.params[si].data_mut(), &g.gs, h.lr, h.lr * h.lam);
            }
            LinGrads::Dense(gw) => {
                let (m, n, m2, n2) = (lc.m, lc.n, lc.m2, lc.n2);
                // regularizer terms from the pre-update W via a shared
                // borrow; masking/ridge sweeps are fused into the single
                // momentum pass below (no W/mask clones)
                {
                    let w = state.param(&p(lc, "W"))?.data();
                    if method == "elastic_gl" {
                        let wsq: f32 = w.iter().map(|v| v * v).sum();
                        reg += 0.5 * h.lam2 * wsq;
                    }
                    if method == "group_lasso" || method == "elastic_gl" {
                        let weight = h.lam * ((m2 * n2) as f32).sqrt();
                        reg += weight * block_fro(w, m, n, m2, n2).iter().sum::<f32>();
                    }
                }
                if method == "rigl_block" {
                    // dense-gradient norms (the growth signal) come from
                    // the unmasked gradient
                    gnorm_tail.extend(block_fro(&gw, m, n, m2, n2));
                }
                let (wi, wvi) = (pidx(state, &p(lc, "W"))?, oidx(state, &p(lc, "W.m"))?);
                match method {
                    "elastic_gl" => sgd_momentum_l2(
                        state.params[wi].data_mut(),
                        state.opt[wvi].data_mut(),
                        &gw,
                        h.lr,
                        mu,
                        h.lam2,
                    ),
                    "rigl_block" => {
                        let mi = pidx(state, &p(lc, "mask"))?;
                        let (wt, mt) = param_pair_mut(&mut state.params, wi, mi);
                        sgd_momentum_block_masked(
                            wt.data_mut(),
                            state.opt[wvi].data_mut(),
                            &gw,
                            mt.data(),
                            m,
                            n,
                            m2,
                            n2,
                            h.lr,
                            mu,
                        );
                    }
                    "iter_prune" => {
                        let ei = pidx(state, &p(lc, "emask"))?;
                        let (wt, et) = param_pair_mut(&mut state.params, wi, ei);
                        sgd_momentum_masked(
                            wt.data_mut(),
                            state.opt[wvi].data_mut(),
                            &gw,
                            et.data(),
                            h.lr,
                            mu,
                        );
                    }
                    _ => sgd_momentum(
                        state.params[wi].data_mut(),
                        state.opt[wvi].data_mut(),
                        &gw,
                        h.lr,
                        mu,
                    ),
                }
                if method == "group_lasso" || method == "elastic_gl" {
                    let kappa = h.lr * h.lam * ((m2 * n2) as f32).sqrt();
                    block_prox(state.params[wi].data_mut(), m, n, m2, n2, kappa);
                }
            }
        }
    }

    let mut out = vec![ce_mean + reg, ce_mean, acc_frac];
    if method == "kpd" {
        out.push(s_l1_per.iter().sum());
        // single-slot specs keep their original `[loss, ce, acc, s_l1]`
        // layout; the per-slot breakdown only exists when there is more
        // than one slot to break down
        if cfg.layers.len() > 1 {
            out.extend(&s_l1_per);
        }
    }
    out.extend(gnorm_tail);
    Ok(out)
}

// ------------------------------------------------------------ state init

/// Fresh parameter + optimizer tensors for the stack, slot by slot in
/// layer order (each slot mirrors the single-slot init exactly: S at ones,
/// A/B at the factorized scaling, W at √(1/n), RigL masks at the spec
/// density with inactive blocks zeroed).
pub(super) fn init_state_parts(
    cfg: &SpecConfig,
    rng: &mut Rng,
) -> (Vec<String>, Vec<Tensor>, Vec<String>, Vec<Tensor>) {
    let mut param_names = Vec::new();
    let mut params = Vec::new();
    let mut opt_names = Vec::new();
    let mut opt = Vec::new();
    for lc in &cfg.layers {
        if cfg.method == "kpd" {
            let d = lc.dims(cfg.rank);
            let a_std = (1.0 / (d.r * d.n1) as f32).sqrt();
            let b_std = (1.0 / d.n2 as f32).sqrt();
            param_names.push(p(lc, "S"));
            params.push(Tensor::full(&[d.m1, d.n1], 1.0));
            param_names.push(p(lc, "A"));
            params.push(Tensor::from_fn(&[d.r, d.m1, d.n1], |_| rng.normal() * a_std));
            param_names.push(p(lc, "B"));
            params.push(Tensor::from_fn(&[d.r, d.m2, d.n2], |_| rng.normal() * b_std));
            opt_names.push(p(lc, "A.m"));
            opt.push(Tensor::zeros(&[d.r, d.m1, d.n1]));
            opt_names.push(p(lc, "B.m"));
            opt.push(Tensor::zeros(&[d.r, d.m2, d.n2]));
        } else {
            let w_std = (1.0 / lc.n as f32).sqrt();
            param_names.push(p(lc, "W"));
            params.push(Tensor::from_fn(&[lc.m, lc.n], |_| rng.normal() * w_std));
            if cfg.method == "rigl_block" {
                let (m1, n1) = lc.grid();
                let total = m1 * n1;
                let k = ((cfg.rigl_density * total as f64).round() as usize).clamp(1, total);
                let chosen = rng.choose(total, k);
                let mut mask = vec![0.0f32; total];
                for i in chosen {
                    mask[i] = 1.0;
                }
                // inactive blocks start (and later grow) from exactly zero
                let wi = params.len() - 1;
                mul_expand_mask(params[wi].data_mut(), &mask, lc.m, lc.n, lc.m2, lc.n2);
                param_names.push(p(lc, "mask"));
                params.push(Tensor::new(&[m1, n1], mask).expect("mask dims"));
            } else if cfg.method == "iter_prune" {
                param_names.push(p(lc, "emask"));
                params.push(Tensor::full(&[lc.m, lc.n], 1.0));
            }
            opt_names.push(p(lc, "W.m"));
            opt.push(Tensor::zeros(&[lc.m, lc.n]));
        }
    }
    (param_names, params, opt_names, opt)
}

// ----------------------------------------------------------- controllers

/// Dense (block-wise sparse) W of every slot, in layer order.
pub(super) fn materialize(cfg: &SpecConfig, state: &TrainState) -> Result<Vec<(String, Tensor)>> {
    let mut out = Vec::with_capacity(cfg.layers.len());
    for lc in &cfg.layers {
        let w = match cfg.method.as_str() {
            "kpd" => {
                let s = state.param(&p(lc, "S"))?;
                let a = state.param(&p(lc, "A"))?;
                let b = state.param(&p(lc, "B"))?;
                Tensor::kpd_reconstruct(s, a, b)?
            }
            "rigl_block" | "iter_prune" => {
                Tensor::new(&[lc.m, lc.n], effective_w(cfg, state, lc)?)?
            }
            _ => state.param(&p(lc, "W"))?.clone(),
        };
        out.push((lc.name.clone(), w));
    }
    Ok(out)
}

/// Blockwise-RigL drop/grow on one slot: drop the k lowest-‖W‖ active
/// blocks, grow the k highest-gradient-norm inactive ones; dropped blocks
/// and their velocity restart from exactly zero.
#[allow(clippy::too_many_arguments)]
pub(super) fn rigl_update_slot(
    state: &mut TrainState,
    slot: &str,
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
    gnorm: &[f32],
    alpha: f32,
) -> Result<()> {
    let n1 = n / n2;
    let mi = pidx(state, &format!("{slot}.mask"))?;
    let wi = pidx(state, &format!("{slot}.W"))?;
    let vi = oidx(state, &format!("{slot}.W.m"))?;
    let mask = state.params[mi].data().to_vec();
    let active: Vec<usize> = (0..mask.len()).filter(|&i| mask[i] != 0.0).collect();
    let inactive: Vec<usize> = (0..mask.len()).filter(|&i| mask[i] == 0.0).collect();
    let k = ((alpha as f64 * active.len() as f64).floor() as usize).min(inactive.len());
    if k == 0 {
        return Ok(());
    }
    let wnorms = block_fro(state.params[wi].data(), m, n, m2, n2);
    let mut drop = active;
    drop.sort_by(|&a, &b| wnorms[a].total_cmp(&wnorms[b]));
    drop.truncate(k);
    let mut grow = inactive;
    grow.sort_by(|&a, &b| gnorm[b].total_cmp(&gnorm[a]));
    grow.truncate(k);

    let mask_data = state.params[mi].data_mut();
    for &blk in &drop {
        mask_data[blk] = 0.0;
    }
    for &blk in &grow {
        mask_data[blk] = 1.0;
    }
    // dropped weights and their velocity restart from zero (RigL grows
    // new blocks at zero, so W need only be cleared on the drop set)
    for &blk in &drop {
        let (i1, j1) = (blk / n1, blk % n1);
        for i2 in 0..m2 {
            let row = (i1 * m2 + i2) * n;
            for j2 in 0..n2 {
                state.params[wi].data_mut()[row + j1 * n2 + j2] = 0.0;
                state.opt[vi].data_mut()[row + j1 * n2 + j2] = 0.0;
            }
        }
    }
    Ok(())
}

/// Multi-slot RigL update: `gnorm` is the per-slot block norms concatenated
/// in layer order (the layout `train_step` emits); each slot's active
/// budget is preserved independently.
pub(super) fn rigl_update(
    cfg: &SpecConfig,
    state: &mut TrainState,
    gnorm: &[f32],
    alpha: f32,
) -> Result<()> {
    let total = gnorm_len(cfg);
    if gnorm.len() != total {
        bail!("rigl_update wants {} block gradient norms, got {}", total, gnorm.len());
    }
    let mut off = 0usize;
    for lc in &cfg.layers {
        let (m1, n1) = lc.grid();
        let cnt = m1 * n1;
        rigl_update_slot(
            state,
            &lc.name,
            lc.m,
            lc.n,
            lc.m2,
            lc.n2,
            &gnorm[off..off + cnt],
            alpha,
        )?;
        off += cnt;
    }
    Ok(())
}

/// Length of the concatenated gradient-norm tail (RigL specs).
pub(super) fn gnorm_len(cfg: &SpecConfig) -> usize {
    cfg.layers
        .iter()
        .map(|l| {
            let (m1, n1) = l.grid();
            m1 * n1
        })
        .sum()
}

/// Global magnitude pruning across every slot to one whole-model sparsity
/// target: rank all |w| together, keep the top `total · (1 − target)`,
/// rebuild per-slot element masks, zero pruned weights and velocity.
pub(super) fn prune(cfg: &SpecConfig, state: &mut TrainState, target: f32) -> Result<()> {
    let sizes: Vec<usize> = cfg.layers.iter().map(|l| l.m * l.n).collect();
    let total: usize = sizes.iter().sum();
    let keep = total - ((target as f64) * total as f64).round() as usize;
    let mut vals = Vec::with_capacity(total);
    for lc in &cfg.layers {
        vals.extend(state.param(&p(lc, "W"))?.data().iter().map(|v| v.abs()));
    }
    let mut order: Vec<usize> = (0..total).collect();
    order.sort_by(|&a, &b| vals[b].total_cmp(&vals[a]));
    let mut keep_mask = vec![false; total];
    for &i in &order[..keep] {
        keep_mask[i] = true;
    }
    let mut off = 0usize;
    for (lc, &sz) in cfg.layers.iter().zip(&sizes) {
        let wi = pidx(state, &p(lc, "W"))?;
        let vi = oidx(state, &p(lc, "W.m"))?;
        let ei = pidx(state, &p(lc, "emask"))?;
        let mut emask = vec![0.0f32; sz];
        for (j, em) in emask.iter_mut().enumerate() {
            if keep_mask[off + j] {
                *em = 1.0;
            } else {
                state.params[wi].data_mut()[j] = 0.0;
                state.opt[vi].data_mut()[j] = 0.0;
            }
        }
        state.params[ei] = Tensor::new(&[lc.m, lc.n], emask)?;
        off += sz;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::backend::Backend;
    use crate::tensor::HostValue;

    fn tiny_mlp(method: &str) -> SpecConfig {
        // 12→8→6→4 with per-layer blocks that tile every width
        SpecConfig::mlp("tiny", method, &[12, 8, 6, 4], &[(2, 3), (3, 2), (2, 2)], 2, 8)
    }

    fn batch(nb: usize, n: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..nb * n).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn stack_layout_interleaves_relu() {
        let cfg = tiny_mlp("dense");
        assert_eq!(
            stack(&cfg),
            vec![
                Layer::Flatten,
                Layer::Linear(0),
                Layer::Relu,
                Layer::Linear(1),
                Layer::Relu,
                Layer::Linear(2)
            ]
        );
    }

    #[test]
    fn kpd_forward_matches_materialized_dense_chain() {
        // the factorized stack must equal relu(relu(X·W1ᵀ)·W2ᵀ)·W3ᵀ with
        // every W reconstructed through Tensor::kpd_reconstruct
        let cfg = tiny_mlp("kpd");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let state = be.init_state("tiny", 3).unwrap();
        let (x, _) = batch(5, 12, 4, 17);
        let z = forward_logits(&cfg, &state, &x, 5).unwrap();
        let ws = materialize(&cfg, &state).unwrap();
        let mut cur = x.clone();
        let mut nfeat = 12usize;
        for (li, (_, w)) in ws.iter().enumerate() {
            let m = w.shape()[0];
            let mut next = vec![0.0f32; 5 * m];
            for bb in 0..5 {
                for i in 0..m {
                    let mut acc = 0.0f32;
                    for j in 0..nfeat {
                        acc += cur[bb * nfeat + j] * w.at2(i, j);
                    }
                    next[bb * m + i] = acc;
                }
            }
            if li + 1 < ws.len() {
                linalg::relu_inplace(&mut next);
            }
            cur = next;
            nfeat = m;
        }
        let diff = z
            .iter()
            .zip(&cur)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "factorized stack drifted from dense chain: {diff}");
    }

    #[test]
    fn every_method_steps_and_evals_on_the_stack() {
        for method in ["kpd", "group_lasso", "elastic_gl", "rigl_block", "iter_prune", "dense"]
        {
            let cfg = tiny_mlp(method);
            let be = NativeBackend::from_spec(cfg).unwrap();
            let entry = be.spec("tiny").unwrap().clone();
            let mut state = be.init_state("tiny", 0).unwrap();
            let (x, y) = batch(8, 12, 4, 5);
            let bx = HostValue::F32(Tensor::new(&[8, 12], x).unwrap());
            let by = HostValue::I32 { shape: vec![8], data: y };
            let hyper: Vec<f32> = entry
                .hyper
                .iter()
                .map(|h| match h.as_str() {
                    "lr" => 0.05,
                    "lambda2" => 1e-4,
                    _ => 0.01,
                })
                .collect();
            let m = be.train_step(&mut state, &bx, &by, &hyper).unwrap();
            let gn = be.gnorm_len("tiny").unwrap();
            assert_eq!(m.len(), entry.metrics.len() + gn, "{method}");
            assert!(m.iter().all(|v| v.is_finite()), "{method}: {m:?}");
            let e = be.eval_step(&state, &bx, &by).unwrap();
            assert_eq!(e.len(), 2, "{method}");
            assert!(e[0].is_finite() && (0.0..=8.0).contains(&e[1]), "{method}");
        }
    }

    #[test]
    fn per_slot_prox_produces_exact_zeros_per_layer() {
        let cfg = tiny_mlp("kpd");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let mut state = be.init_state("tiny", 1).unwrap();
        let (x, y) = batch(8, 12, 4, 9);
        let bx = HostValue::F32(Tensor::new(&[8, 12], x).unwrap());
        let by = HostValue::I32 { shape: vec![8], data: y };
        // huge λ: the prox threshold dwarfs the gradient, S → exact zeros
        for _ in 0..40 {
            be.train_step(&mut state, &bx, &by, &[2.0, 0.1]).unwrap();
        }
        for lc in &cfg.layers {
            let s = state.param(&p(lc, "S")).unwrap();
            assert!(
                s.data().iter().any(|&v| v == 0.0),
                "{}: prox never zeroed an S entry",
                lc.name
            );
        }
    }

    #[test]
    fn global_prune_hits_exact_whole_model_target() {
        let cfg = tiny_mlp("iter_prune");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let mut state = be.init_state("tiny", 0).unwrap();
        be.prune(&mut state, 0.5).unwrap();
        let total: usize = cfg.layers.iter().map(|l| l.m * l.n).sum();
        let mut zeros = 0usize;
        for lc in &cfg.layers {
            let em = state.param(&p(lc, "emask")).unwrap();
            zeros += em.data().iter().filter(|v| **v == 0.0).count();
            // pruned weights are zeroed in place
            let w = state.param(&p(lc, "W")).unwrap();
            for (wv, mv) in w.data().iter().zip(em.data()) {
                if *mv == 0.0 {
                    assert_eq!(*wv, 0.0);
                }
            }
        }
        assert_eq!(zeros, ((0.5 * total as f64).round()) as usize);
    }

    #[test]
    fn rigl_update_preserves_per_slot_budgets() {
        let cfg = tiny_mlp("rigl_block");
        let be = NativeBackend::from_spec(cfg.clone()).unwrap();
        let mut state = be.init_state("tiny", 0).unwrap();
        let before: Vec<f32> = cfg
            .layers
            .iter()
            .map(|lc| state.param(&p(lc, "mask")).unwrap().data().iter().sum())
            .collect();
        let gn = be.gnorm_len("tiny").unwrap();
        let gnorm: Vec<f32> = (0..gn).map(|i| (i as f32 * 0.37 + 0.01) % 5.0).collect();
        be.rigl_update(&mut state, &gnorm, 0.5).unwrap();
        let after: Vec<f32> = cfg
            .layers
            .iter()
            .map(|lc| state.param(&p(lc, "mask")).unwrap().data().iter().sum())
            .collect();
        assert_eq!(before, after, "per-slot active budgets drifted");
    }
}
