//! Multi-threaded f32 kernels for the native backend.
//!
//! Everything is row-major slices + explicit dims; parallelism is plain
//! `std::thread::scope` chunking over output rows (no rayon in the offline
//! cache). The inner loops are laid out so the streamed operand is read
//! contiguously (k-unrolled axpy for A·B, register-blocked 1×4 dot panels
//! for A·Bᵀ) and run through the runtime-dispatched SIMD microkernels in
//! [`super::simd`] — every public kernel has a `*_with(kind, ..)` twin
//! taking an explicit [`SimdKind`], used by the parity tests and the
//! scalar-vs-dispatched bench variants. The kind is resolved once per
//! call, so results depend only on (inputs, kind): never on thread count.

use anyhow::{bail, Result};

use super::simd::{self, SimdKind};

/// Work (in multiply-adds) below which threading is pure overhead: scoped
/// threads are spawned per call, so the cutoff sits well above the spawn
/// cost (a Table-1-sized step of ~1M MACs stays single-threaded).
const PAR_THRESHOLD: usize = 1 << 21;

fn max_threads() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        // the 1..=MAX_WORKERS bound is shared with BS_SERVE_WORKERS and
        // the pool defaults (crate::util): a stray huge value must not
        // spawn thousands of scoped threads per kernel call
        let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        crate::util::env_workers("BS_NATIVE_THREADS", auto)
    })
}

thread_local! {
    /// Per-thread cap on kernel worker threads. The data-parallel trainer
    /// pins this to 1 inside replica workers so the replica axis is the
    /// only parallelism — kernel row threading on top would just
    /// oversubscribe the cores.
    static THREAD_CAP: std::cell::Cell<usize> = std::cell::Cell::new(usize::MAX);
}

/// Run `f` with this thread's kernel threading capped at `cap` (1 = fully
/// single-threaded kernels). Restores the previous cap on exit, panic
/// included. The cap never changes any result: [`par_rows`] partitions
/// output rows, so each element's accumulation order is identical at every
/// thread count — only scheduling differs.
pub fn with_thread_cap<T>(cap: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_CAP.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(THREAD_CAP.with(|c| c.replace(cap.max(1))));
    f()
}

/// Run `f(row_index, row)` over every `cols`-wide row of `out`, splitting
/// the rows across up to `threads` scoped workers. Shared with the BSR
/// inference kernels (`crate::infer::bsr`), which parallelize over batch
/// rows the same way.
pub(crate) fn par_rows<F>(out: &mut [f32], rows: usize, cols: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        for (i, row) in out.chunks_mut(cols).enumerate() {
            f(i, row);
        }
        return;
    }
    let rows_per = (rows + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * cols).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, row) in chunk.chunks_mut(cols).enumerate() {
                    f(ci * rows_per + j, row);
                }
            });
        }
    });
}

/// Worker count for a kernel of `work` multiply-adds: 1 below the
/// threading threshold, the machine cap above it. The packed BSR serving
/// kernel (`crate::infer::bsr`) passes its *actual* occupied-block work so
/// a highly sparse layer is not taxed with thread-spawn overhead; the
/// masked training matmul below still passes the dense product (the mask
/// changes every RigL round, so its threading stays shape-stable).
pub(crate) fn threads_for(work: usize) -> usize {
    let cap = THREAD_CAP.with(|c| c.get());
    if cap <= 1 || work < PAR_THRESHOLD {
        1
    } else {
        max_threads().min(cap)
    }
}

/// C(m,n) = A(m,k) · B(k,n).
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_nn_with(simd::active(), a, b, m, k, n)
}

/// [`matmul_nn`] with an explicit SIMD kind. The k loop streams B rows
/// through 2-deep fused axpy sweeps — no zero-skip on `a[i,k]`: a zero
/// coefficient against a non-finite B entry must still produce NaN
/// (0·∞ = NaN), and the branch defeats vectorization anyway.
pub fn matmul_nn_with(
    kind: SimdKind,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads_for(m * k * n), |i, row| {
        let arow = &a[i * k..(i + 1) * k];
        let mut kk = 0;
        while kk + 2 <= k {
            simd::axpy2(
                kind,
                arow[kk],
                &b[kk * n..(kk + 1) * n],
                arow[kk + 1],
                &b[(kk + 1) * n..(kk + 2) * n],
                row,
            );
            kk += 2;
        }
        if kk < k {
            simd::axpy(kind, arow[kk], &b[kk * n..(kk + 1) * n], row);
        }
    });
    out
}

/// C(m,n) = A(m,k) · B(n,k)ᵀ — both operands read contiguously (dot form).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_nt_with(simd::active(), a, b, m, k, n)
}

/// [`matmul_nt`] with an explicit SIMD kind: 1×4 register-blocked dot
/// panels (one A-row load feeds four B-row accumulators), scalar-kind
/// bit-identical to four independent dots.
pub fn matmul_nt_with(
    kind: SimdKind,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads_for(m * k * n), |i, row| {
        let arow = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j + 4 <= n {
            let d = simd::dot4(
                kind,
                arow,
                &b[j * k..(j + 1) * k],
                &b[(j + 1) * k..(j + 2) * k],
                &b[(j + 2) * k..(j + 3) * k],
                &b[(j + 3) * k..(j + 4) * k],
            );
            row[j..j + 4].copy_from_slice(&d);
            j += 4;
        }
        while j < n {
            row[j] = simd::dot(kind, arow, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    });
    out
}

/// C(m,n) = A(k,m)ᵀ · B(k,n) — the gradient-shaped product (e.g. dW = dZᵀX).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    matmul_tn_with(simd::active(), a, b, k, m, n)
}

/// [`matmul_tn`] with an explicit SIMD kind. Same fused-axpy core as
/// [`matmul_nn_with`] with strided A loads; the old `a == 0.0` skip is
/// gone for the same NaN-propagation reason.
pub fn matmul_tn_with(
    kind: SimdKind,
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, m, n, threads_for(m * k * n), |i, row| {
        let mut kk = 0;
        while kk + 2 <= k {
            simd::axpy2(
                kind,
                a[kk * m + i],
                &b[kk * n..(kk + 1) * n],
                a[(kk + 1) * m + i],
                &b[(kk + 1) * n..(kk + 2) * n],
                row,
            );
            kk += 2;
        }
        if kk < k {
            simd::axpy(kind, a[kk * m + i], &b[kk * n..(kk + 1) * n], row);
        }
    });
    out
}

/// Z(N,m) = X(N,n) · Wᵀ skipping whole (m2×n2) blocks where the (m1,n1)
/// `mask` is zero — the baselines' block-sparse inference/training matmul.
/// The mask skip is *semantic* (a masked block contributes exactly nothing,
/// whatever W holds there) and stays; shape validation is real, not
/// debug-only: a non-dividing block shape would silently mis-bin the mask.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse_matmul_nt(
    x: &[f32],
    w: &[f32],
    mask: &[f32],
    n_batch: usize,
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
) -> Result<Vec<f32>> {
    block_sparse_matmul_nt_with(simd::active(), x, w, mask, n_batch, m, n, m2, n2)
}

/// [`block_sparse_matmul_nt`] with an explicit SIMD kind: each surviving
/// block contributes one n2-wide dot, accumulated block-major per output
/// element (replica-count-independent by construction).
#[allow(clippy::too_many_arguments)]
pub fn block_sparse_matmul_nt_with(
    kind: SimdKind,
    x: &[f32],
    w: &[f32],
    mask: &[f32],
    n_batch: usize,
    m: usize,
    n: usize,
    m2: usize,
    n2: usize,
) -> Result<Vec<f32>> {
    if m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
        bail!("block shape ({m2},{n2}) does not tile weight ({m},{n})");
    }
    let (m1, n1) = (m / m2, n / n2);
    if x.len() != n_batch * n || w.len() != m * n || mask.len() != m1 * n1 {
        bail!(
            "block_sparse_matmul_nt shape mismatch: x {} (want {n_batch}·{n}), \
             w {} (want {m}·{n}), mask {} (want {m1}·{n1})",
            x.len(),
            w.len(),
            mask.len()
        );
    }
    let mut out = vec![0.0f32; n_batch * m];
    par_rows(&mut out, n_batch, m, threads_for(n_batch * m * n), |b, row| {
        let xrow = &x[b * n..(b + 1) * n];
        for (i, o) in row.iter_mut().enumerate() {
            let wrow = &w[i * n..(i + 1) * n];
            let mrow = &mask[(i / m2) * n1..(i / m2 + 1) * n1];
            let mut acc = 0.0f32;
            for (j1, &mv) in mrow.iter().enumerate() {
                if mv == 0.0 {
                    continue;
                }
                let lo = j1 * n2;
                acc += simd::dot(kind, &xrow[lo..lo + n2], &wrow[lo..lo + n2]);
            }
            *o = acc;
        }
    });
    Ok(out)
}

/// In-place ReLU: a ← max(a, 0). The multi-layer stack's activation.
pub fn relu_inplace(a: &mut [f32]) {
    for v in a.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero `da` wherever the *post*-activation `y` is zero
/// (y = max(x, 0), so y == 0 covers every non-positive pre-activation;
/// the subgradient at exactly 0 is taken as 0, matching JAX's
/// `jax.nn.relu` VJP).
pub fn relu_backward(da: &mut [f32], y: &[f32]) {
    debug_assert_eq!(da.len(), y.len());
    for (d, &yv) in da.iter_mut().zip(y) {
        if yv <= 0.0 {
            *d = 0.0;
        }
    }
}

/// LayerNorm variance floor — keeps rstd finite on constant rows.
pub const LAYERNORM_EPS: f32 = 1e-5;

/// Row-wise LayerNorm: y = g ⊙ (x − μ)/√(σ² + ε) + b over `d`-wide rows.
/// Returns `(y, xhat, rstd)` — the normalized activations plus the two
/// backward caches ([`layernorm_backward`] wants x̂ and 1/σ per row).
/// Rows run sequentially: at encoder widths (d ≤ a few hundred) a row is
/// a few hundred FLOPs and the scoped-thread spawn cost would dominate;
/// the variance reduction still runs through the SIMD dot microkernel.
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    layernorm_with(simd::active(), x, g, b, rows, d)
}

/// [`layernorm`] with an explicit SIMD kind (scalar pins + bench twins).
pub fn layernorm_with(
    kind: SimdKind,
    x: &[f32],
    g: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(b.len(), d);
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    let inv_d = 1.0f32 / d as f32;
    for r in 0..rows {
        let xrow = &x[r * d..(r + 1) * d];
        let mut mean = 0.0f32;
        for &v in xrow {
            mean += v;
        }
        mean *= inv_d;
        let hrow = &mut xhat[r * d..(r + 1) * d];
        for (h, &v) in hrow.iter_mut().zip(xrow) {
            *h = v - mean;
        }
        let var = simd::dot(kind, hrow, hrow) * inv_d;
        let rs = 1.0 / (var + LAYERNORM_EPS).sqrt();
        rstd[r] = rs;
        let yrow = &mut y[r * d..(r + 1) * d];
        for (j, (yv, h)) in yrow.iter_mut().zip(hrow.iter_mut()).enumerate() {
            *h *= rs;
            *yv = g[j] * *h + b[j];
        }
    }
    (y, xhat, rstd)
}

/// LayerNorm backward from the forward caches: given dY and the cached
/// (x̂, 1/σ), returns `(dx, dg, db)` where dg/db are the column sums
/// dg = Σ_rows dY ⊙ x̂ and db = Σ_rows dY, and
/// dx = rstd · (dx̂ − mean(dx̂) − x̂ · mean(dx̂ ⊙ x̂)) with dx̂ = dY ⊙ g.
pub fn layernorm_backward(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    layernorm_backward_with(simd::active(), dy, xhat, rstd, g, rows, d)
}

/// [`layernorm_backward`] with an explicit SIMD kind.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_backward_with(
    kind: SimdKind,
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), rows * d);
    debug_assert_eq!(xhat.len(), rows * d);
    debug_assert_eq!(rstd.len(), rows);
    debug_assert_eq!(g.len(), d);
    let mut dx = vec![0.0f32; rows * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    let inv_d = 1.0f32 / d as f32;
    let mut dxhat = vec![0.0f32; d];
    for r in 0..rows {
        let dyrow = &dy[r * d..(r + 1) * d];
        let hrow = &xhat[r * d..(r + 1) * d];
        for (j, ((dh, &dyv), &hv)) in dxhat.iter_mut().zip(dyrow).zip(hrow).enumerate() {
            *dh = dyv * g[j];
            dg[j] += dyv * hv;
            db[j] += dyv;
        }
        let mut h1 = 0.0f32;
        for &dh in dxhat.iter() {
            h1 += dh;
        }
        h1 *= inv_d;
        let h2 = simd::dot(kind, &dxhat, hrow) * inv_d;
        let rs = rstd[r];
        let dxrow = &mut dx[r * d..(r + 1) * d];
        for ((dxv, &dh), &hv) in dxrow.iter_mut().zip(&dxhat).zip(hrow) {
            *dxv = rs * (dh - h1 - hv * h2);
        }
    }
    (dx, dg, db)
}

/// Softmax cross-entropy over logits `z` (N × classes) with class ids `y`.
pub struct SoftmaxCe {
    /// mean CE over the batch
    pub ce_mean: f32,
    /// fraction of rows whose argmax equals the label
    pub acc_frac: f32,
    /// number of correct rows (what eval aggregation sums)
    pub correct: f32,
    /// d(mean CE)/dZ, same layout as `z`
    pub dz: Vec<f32>,
}

pub fn softmax_ce(z: &[f32], y: &[i32], n_batch: usize, classes: usize) -> Result<SoftmaxCe> {
    if z.len() != n_batch * classes || y.len() != n_batch {
        bail!(
            "softmax_ce shape mismatch: z {} vs {}x{}, y {}",
            z.len(),
            n_batch,
            classes,
            y.len()
        );
    }
    let mut dz = vec![0.0f32; z.len()];
    let mut ce_sum = 0.0f64;
    let mut correct = 0usize;
    let inv_n = 1.0f32 / n_batch as f32;
    for b in 0..n_batch {
        let yi = y[b];
        if yi < 0 || yi as usize >= classes {
            bail!("label {yi} out of range [0, {classes})");
        }
        let row = &z[b * classes..(b + 1) * classes];
        let mut zmax = f32::NEG_INFINITY;
        let mut amax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > zmax {
                zmax = v;
                amax = j;
            }
        }
        let mut esum = 0.0f32;
        let drow = &mut dz[b * classes..(b + 1) * classes];
        for (d, &v) in drow.iter_mut().zip(row) {
            let e = (v - zmax).exp();
            *d = e;
            esum += e;
        }
        ce_sum += (esum.ln() + zmax - row[yi as usize]) as f64;
        if amax == yi as usize {
            correct += 1;
        }
        for d in drow.iter_mut() {
            *d = *d / esum * inv_n;
        }
        drow[yi as usize] -= inv_n;
    }
    Ok(SoftmaxCe {
        ce_mean: (ce_sum / n_batch as f64) as f32,
        acc_frac: correct as f32 / n_batch as f32,
        correct: correct as f32,
        dz,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Shapes large enough that `threads_for` actually spawns workers.
    #[test]
    fn matmul_variants_match_naive_reference() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (160, 130, 160); // 3.3M MACs > PAR_THRESHOLD
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let ta = Tensor::new(&[m, k], a.clone()).unwrap();
        let tb = Tensor::new(&[k, n], b.clone()).unwrap();
        let want = ta.matmul(&tb).unwrap();

        // tolerance covers f32 re-association over a k=130 reduction
        let tol = 1e-3;
        let nn = matmul_nn(&a, &b, m, k, n);
        assert!(max_diff(&nn, want.data()) < tol, "nn");

        // A·Bᵀ with B stored transposed must equal A·B
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let nt = matmul_nt(&a, &bt, m, k, n);
        assert!(max_diff(&nt, want.data()) < tol, "nt");

        // Aᵀ·B with A stored transposed must equal A·B
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let tn = matmul_tn(&at, &b, k, m, n);
        assert!(max_diff(&tn, want.data()) < tol, "tn");
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn block_sparse_skips_masked_blocks() {
        let mut rng = Rng::new(5);
        let (nb, m, n, m2, n2) = (6, 4, 8, 2, 4);
        let x = rand_vec(&mut rng, nb * n);
        let w = rand_vec(&mut rng, m * n);
        // zero block (0,1) and (1,0)
        let mask = vec![1.0, 0.0, 0.0, 1.0];
        let got = block_sparse_matmul_nt(&x, &w, &mask, nb, m, n, m2, n2).unwrap();
        // reference: explicitly mask W then dense-nt
        let mut wm = w.clone();
        for i in 0..m {
            for j in 0..n {
                if mask[(i / m2) * 2 + (j / n2)] == 0.0 {
                    wm[i * n + j] = 0.0;
                }
            }
        }
        let want = matmul_nt(&x, &wm, nb, n, m);
        assert!(max_diff(&got, &want) < 1e-5);
    }

    /// Regression for the old `if av == 0.0 { continue }` zero-skips: a
    /// zero coefficient against ∞ must produce NaN in the output, in
    /// every matmul variant (0·∞ = NaN — a diverged run must not be
    /// silently masked back to finite numbers).
    #[test]
    fn nan_propagates_through_all_matmul_variants() {
        let (m, k, n) = (3, 4, 5);
        // A has an explicit zero where B holds ∞ in the shared k slot.
        let mut a = vec![1.0f32; m * k];
        a[2] = 0.0; // A[0, 2] = 0
        let mut b = vec![1.0f32; k * n];
        b[2 * n] = f32::INFINITY; // B[2, 0] = ∞
        let nn = matmul_nn(&a, &b, m, k, n);
        assert!(nn[0].is_nan(), "nn: 0·∞ must be NaN, got {}", nn[0]);
        assert!(nn[1].is_finite(), "nn: untouched column stays finite");

        // nt: B stored (n, k); poison B[0, 2] so row 0 · col 0 hits 0·∞.
        let mut bt = vec![1.0f32; n * k];
        bt[2] = f32::INFINITY;
        let nt = matmul_nt(&a, &bt, m, k, n);
        assert!(nt[0].is_nan(), "nt: 0·∞ must be NaN, got {}", nt[0]);
        assert!(nt[1].is_finite(), "nt");

        // tn: A stored (k, m); A[2, 0] = 0 meets B[2, 0] = ∞.
        let mut at = vec![1.0f32; k * m];
        at[2 * m] = 0.0;
        let tn = matmul_tn(&at, &b, k, m, n);
        assert!(tn[0].is_nan(), "tn: 0·∞ must be NaN, got {}", tn[0]);
        assert!(tn[1].is_finite(), "tn");

        // block-sparse: an *unmasked* block with 0·∞ inside must go NaN
        // (the mask skip is semantic and may still drop whole blocks).
        let (nb, bm, bn, m2, n2) = (2usize, 2usize, 4usize, 1usize, 2usize);
        let mut x = vec![1.0f32; nb * bn];
        x[0] = 0.0;
        let mut w = vec![1.0f32; bm * bn];
        w[0] = f32::INFINITY;
        let mask = vec![1.0; (bm / m2) * (bn / n2)];
        let bs = block_sparse_matmul_nt(&x, &w, &mask, nb, bm, bn, m2, n2).unwrap();
        assert!(bs[0].is_nan(), "block_sparse: 0·∞ must be NaN, got {}", bs[0]);
        // ... but a masked block hides the ∞ entirely
        let mut mask2 = mask;
        mask2[0] = 0.0;
        let bs2 = block_sparse_matmul_nt(&x, &w, &mask2, nb, bm, bn, m2, n2).unwrap();
        assert!(bs2[0].is_finite(), "masked block must not leak its ∞");
    }

    /// The debug-only shape asserts are now real validation: non-dividing
    /// block shapes and mismatched buffer lengths must error in release
    /// builds instead of mis-binning the mask or indexing out of bounds.
    #[test]
    fn block_sparse_rejects_bad_shapes() {
        let x = vec![0.0f32; 2 * 8];
        let w = vec![0.0f32; 4 * 8];
        let mask = vec![1.0f32; 2 * 2];
        // m2 does not divide m
        assert!(block_sparse_matmul_nt(&x, &w, &mask, 2, 4, 8, 3, 4).is_err());
        // n2 does not divide n
        assert!(block_sparse_matmul_nt(&x, &w, &mask, 2, 4, 8, 2, 5).is_err());
        // zero block edge
        assert!(block_sparse_matmul_nt(&x, &w, &mask, 2, 4, 8, 0, 4).is_err());
        // wrong x / w / mask lengths
        assert!(block_sparse_matmul_nt(&x[..15], &w, &mask, 2, 4, 8, 2, 4).is_err());
        assert!(block_sparse_matmul_nt(&x, &w[..31], &mask, 2, 4, 8, 2, 4).is_err());
        assert!(block_sparse_matmul_nt(&x, &w, &mask[..3], 2, 4, 8, 2, 4).is_err());
        // and the happy path still goes through
        assert!(block_sparse_matmul_nt(&x, &w, &mask, 2, 4, 8, 2, 4).is_ok());
    }

    /// Explicit-kind wrappers agree with the dispatched entry points under
    /// tolerance (bitwise when the host dispatches scalar); exhaustive
    /// cross-kind parity lives in tests/simd.rs.
    #[test]
    fn explicit_kind_matches_dispatched() {
        let mut rng = Rng::new(21);
        let (m, k, n) = (7, 33, 9);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bt = rand_vec(&mut rng, n * k);
        let kind = simd::active();
        assert_eq!(matmul_nn(&a, &b, m, k, n), matmul_nn_with(kind, &a, &b, m, k, n));
        assert_eq!(matmul_nt(&a, &bt, m, k, n), matmul_nt_with(kind, &a, &bt, m, k, n));
        assert_eq!(matmul_tn(&b, &b, k, n, n), matmul_tn_with(kind, &b, &b, k, n, n));
    }

    #[test]
    fn softmax_ce_known_values() {
        // two rows, 3 classes; uniform logits → ce = ln 3, grad rows sum 0
        let z = vec![0.0; 6];
        let y = vec![1, 2];
        let out = softmax_ce(&z, &y, 2, 3).unwrap();
        assert!((out.ce_mean - 3.0f32.ln()).abs() < 1e-6);
        assert_eq!(out.correct, 0.0); // argmax ties resolve to class 0
        let row_sum: f32 = out.dz[..3].iter().sum();
        assert!(row_sum.abs() < 1e-6);
        // gradient at the true label is (p - 1)/N
        assert!((out.dz[1] - (1.0 / 3.0 - 1.0) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_ce_gradient_matches_finite_difference() {
        let mut rng = Rng::new(9);
        let (nb, c) = (4, 5);
        let z = rand_vec(&mut rng, nb * c);
        let y: Vec<i32> = (0..nb).map(|i| (i % c) as i32).collect();
        let base = softmax_ce(&z, &y, nb, c).unwrap();
        let h = 1e-3f32;
        for idx in [0usize, 7, 13, 19] {
            let mut zp = z.clone();
            zp[idx] += h;
            let mut zm = z.clone();
            zm[idx] -= h;
            let lp = softmax_ce(&zp, &y, nb, c).unwrap().ce_mean;
            let lm = softmax_ce(&zm, &y, nb, c).unwrap().ce_mean;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - base.dz[idx]).abs() < 2e-3,
                "idx {idx}: fd {fd} vs analytic {}",
                base.dz[idx]
            );
        }
    }

    #[test]
    fn softmax_ce_rejects_bad_labels() {
        assert!(softmax_ce(&[0.0, 0.0], &[2], 1, 2).is_err());
        assert!(softmax_ce(&[0.0, 0.0], &[-1], 1, 2).is_err());
    }

    #[test]
    fn thread_cap_pins_kernels_without_changing_results() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (160, 130, 160); // above PAR_THRESHOLD
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let threaded = matmul_nn(&a, &b, m, k, n);
        let capped = with_thread_cap(1, || {
            assert_eq!(threads_for(m * k * n), 1, "cap must force 1 worker");
            matmul_nn(&a, &b, m, k, n)
        });
        // cap restored after the scope
        assert!(threads_for(m * k * n) >= 1);
        assert_eq!(threaded, capped, "thread cap changed kernel results");
        // nested caps restore outward
        with_thread_cap(2, || {
            with_thread_cap(1, || assert_eq!(threads_for(usize::MAX / 2), 1));
            assert!(threads_for(usize::MAX / 2) <= 2);
        });
    }

    #[test]
    fn layernorm_normalizes_rows_and_applies_affine() {
        let mut rng = Rng::new(31);
        let (rows, d) = (6, 16);
        let x = rand_vec(&mut rng, rows * d);
        let g = vec![1.0f32; d];
        let b = vec![0.0f32; d];
        let (y, xhat, rstd) = layernorm(&x, &g, &b, rows, d);
        assert_eq!(y, xhat, "unit affine: y must equal x̂");
        for r in 0..rows {
            let row = &y[r * d..(r + 1) * d];
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
            assert!(rstd[r] > 0.0);
        }
        // non-trivial gain/bias shift the normalized row exactly
        let g2: Vec<f32> = (0..d).map(|j| 0.5 + j as f32 * 0.1).collect();
        let b2: Vec<f32> = (0..d).map(|j| j as f32 * 0.01 - 0.05).collect();
        let (y2, xhat2, _) = layernorm(&x, &g2, &b2, rows, d);
        for r in 0..rows {
            for j in 0..d {
                let want = g2[j] * xhat2[r * d + j] + b2[j];
                assert!((y2[r * d + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn layernorm_constant_row_stays_finite() {
        let x = vec![3.0f32; 8];
        let (y, _, rstd) = layernorm(&x, &[1.0; 8], &[0.0; 8], 1, 8);
        assert!(y.iter().all(|v| v.is_finite() && v.abs() < 1e-3));
        assert!(rstd[0].is_finite());
    }

    #[test]
    fn layernorm_backward_matches_finite_difference() {
        let mut rng = Rng::new(37);
        let (rows, d) = (3, 8);
        let x = rand_vec(&mut rng, rows * d);
        let g: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * j as f32).collect();
        let b: Vec<f32> = (0..d).map(|j| 0.02 * j as f32).collect();
        // scalar objective: L = Σ w ⊙ y with fixed random w
        let w = rand_vec(&mut rng, rows * d);
        let loss = |x: &[f32], g: &[f32], b: &[f32]| -> f32 {
            let (y, _, _) = layernorm(x, g, b, rows, d);
            y.iter().zip(&w).map(|(a, b)| a * b).sum()
        };
        let (_, xhat, rstd) = layernorm(&x, &g, &b, rows, d);
        let (dx, dg, db) = layernorm_backward(&w, &xhat, &rstd, &g, rows, d);
        let h = 1e-2f32;
        let probes = [0usize, 5, 11, 17, 23];
        for &i in &probes {
            let (mut xp, mut xm) = (x.clone(), x.clone());
            xp[i] += h;
            xm[i] -= h;
            let fd = (loss(&xp, &g, &b) - loss(&xm, &g, &b)) / (2.0 * h);
            assert!((fd - dx[i]).abs() < 5e-3, "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        for j in [0usize, 3, 7] {
            let (mut gp, mut gm) = (g.clone(), g.clone());
            gp[j] += h;
            gm[j] -= h;
            let fd = (loss(&x, &gp, &b) - loss(&x, &gm, &b)) / (2.0 * h);
            assert!((fd - dg[j]).abs() < 5e-3, "dg[{j}]: fd {fd} vs {}", dg[j]);
            let (mut bp, mut bm) = (b.clone(), b.clone());
            bp[j] += h;
            bm[j] -= h;
            let fd = (loss(&x, &g, &bp) - loss(&x, &g, &bm)) / (2.0 * h);
            assert!((fd - db[j]).abs() < 5e-3, "db[{j}]: fd {fd} vs {}", db[j]);
        }
    }

    #[test]
    fn layernorm_explicit_kind_matches_dispatched() {
        let mut rng = Rng::new(41);
        let (rows, d) = (5, 24);
        let x = rand_vec(&mut rng, rows * d);
        let g = rand_vec(&mut rng, d);
        let b = rand_vec(&mut rng, d);
        let kind = simd::active();
        let (y0, h0, r0) = layernorm(&x, &g, &b, rows, d);
        let (y1, h1, r1) = layernorm_with(kind, &x, &g, &b, rows, d);
        assert_eq!(y0, y1);
        assert_eq!(h0, h1);
        assert_eq!(r0, r1);
        let dy = rand_vec(&mut rng, rows * d);
        let (dx0, dg0, db0) = layernorm_backward(&dy, &h0, &r0, &g, rows, d);
        let (dx1, dg1, db1) = layernorm_backward_with(kind, &dy, &h0, &r0, &g, rows, d);
        assert_eq!(dx0, dx1);
        assert_eq!(dg0, dg1);
        assert_eq!(db0, db1);
    }

    #[test]
    fn relu_forward_backward_pair() {
        let mut a = vec![-1.5, 0.0, 2.0, -0.0, 3.5];
        relu_inplace(&mut a);
        assert_eq!(a, vec![0.0, 0.0, 2.0, 0.0, 3.5]);
        let mut da = vec![1.0, 1.0, 1.0, 1.0, 1.0];
        relu_backward(&mut da, &a);
        assert_eq!(da, vec![0.0, 0.0, 1.0, 0.0, 1.0]);
    }
}
