//! Joint multi-pattern KPD training: the paper's Eq. 7 / Figure 3 method,
//! natively.
//!
//! K candidate block sizes are trained **together** in one model: every
//! candidate k holds its own KPD factorization (S^(k), A^(k), B^(k)) of
//! the same m×n weight, the forward pass shares the input batch and *sums*
//! the candidate logits,
//!
//!     Z = Σ_k X · W^(k)ᵀ,   W^(k) = Σ_r (S^(k) ⊙ A^(k)_r) ⊗ B^(k)_r,
//!
//! and the backward pass reuses one dZ for every candidate (each pattern's
//! gradients are independent given dZ, so the joint objective costs K
//! factorized passes — not K training runs). Each S^(k) takes the ℓ1 prox
//! after its SGD step; under the staircase λ ramp the coordinator applies,
//! the candidates whose blocks don't match the data collapse to exact
//! zeros while (empirically, the paper's Figure 3) exactly one survives.
//!
//! **Gauge fixing.** W^(k) is invariant under S^(k) ↦ c·S^(k),
//! A^(k) ↦ A^(k)/c, so the raw parameterization lets the unregularized
//! factors absorb all magnitude while ℓ1 grinds every S to zero — the
//! Figure-3 ‖S^(k)‖₁ series would then measure nothing. This module
//! removes the gauge freedom: every A_r / B_r slice is held at a fixed
//! nominal Frobenius norm (√(m1/r) and √m2 — the norms the init targets),
//! so each candidate's *entire* magnitude lives in its S^(k) and the
//! per-pattern ‖S‖₁ trajectories are directly comparable. Because the
//! normalized factors attenuate the S gradient by their entry scale
//! (≈ 1/√(r·n)), the S step runs at lr·√(r·n) — the prox threshold
//! scales identically, so λ keeps its meaning in the objective.
//!
//! Parameter naming: `p{k}.fc.{S,A,B}` (+ optimizer slots `p{k}.fc.{A,B}.m`),
//! which is the layout `probe::pattern_s_norms` and the sparsity probe read.
//! Evaluation scores every candidate **individually** — the eval layout is
//! `[ce_0..ce_{K-1}, correct_0..correct_{K-1}]`, matching `Trainer::evaluate`.

use anyhow::{bail, Result};

use crate::backend::{GradOut, TrainState};
use crate::flops::KpdDims;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::layers::LinGrads;
use super::{kpd, layers, linalg, oidx, pidx, sgd_momentum, sgd_prox_l1, LayerCfg, SpecConfig};

/// λ calibration for the native gauge objective as `(base, ramp per
/// period)`: empirically chosen for the lr·√(r·n) S step. The paper's
/// λ = 0.01 (+0.002 per ramp period) applies to the original Eq. 7
/// objective that the AOT/PJRT path trains, not to this one. Every
/// native front-end (CLI `pattern`, fig3 bench, example) reads this
/// single constant.
pub const LAMBDA_CALIBRATION: (f64, f64) = (0.002, 0.0005);

/// Apply [`LAMBDA_CALIBRATION`] to a train config when the backend is the
/// native one; AOT/PJRT paths (which train the paper's original Eq. 7
/// objective) are left at their paper-scale values. The one λ-defaulting
/// path every pattern front-end shares.
pub fn calibrate_lambda(cfg: &mut crate::config::TrainConfig, backend_name: &str) {
    if backend_name.starts_with("native") {
        let (lam, ramp) = LAMBDA_CALIBRATION;
        cfg.lambda = lam;
        cfg.lambda2 = 0.0;
        cfg.lambda_ramp = ramp;
    }
}

/// Canonical parameter name for pattern `p`: `p{p}.fc.{leaf}`.
pub fn pname(p: usize, leaf: &str) -> String {
    format!("p{p}.fc.{leaf}")
}

/// Synthetic one-slot layer configs, one per candidate: slot `p{k}.fc`
/// over the shared m×n weight at that candidate's block size. This is the
/// bridge onto the layer-graph core — every candidate's forward/backward
/// runs through [`layers::linear_forward`] / [`layers::linear_backward`]
/// like any other slot (the `pattern_kpd` method takes the KPD path, and
/// `LayerCfg::dims` reproduces `SpecConfig::pattern_dims` exactly); only
/// the gauge-fixed update below stays pattern-specific.
fn slot_cfgs(cfg: &SpecConfig) -> Vec<LayerCfg> {
    cfg.patterns
        .iter()
        .enumerate()
        .map(|(p, &(m2, n2))| LayerCfg {
            name: format!("p{p}.fc"),
            m: cfg.out_dim,
            n: cfg.in_dim,
            m2,
            n2,
        })
        .collect()
}

/// Nominal per-rank Frobenius norms the gauge holds A_r and B_r at:
/// (√(m1/r), √m2) — what the `a_std`/`b_std` init scaling targets in
/// expectation, made exact.
fn gauge_norms(d: &KpdDims) -> (f64, f64) {
    ((d.m1 as f64 / d.r as f64).sqrt(), (d.m2 as f64).sqrt())
}

/// The S step multiplier compensating the normalized factors' ≈ 1/√(r·n)
/// gradient attenuation.
fn s_step_scale(d: &KpdDims) -> f32 {
    ((d.r * d.n1 * d.n2) as f32).sqrt()
}

/// Rescale one rank slice of a factor to Frobenius norm `target`.
fn renorm_slice(data: &mut [f32], target: f64) {
    let norm = data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    if norm > 0.0 {
        let scale = (target / norm) as f32;
        for v in data.iter_mut() {
            *v *= scale;
        }
    }
}

/// Fresh parameter + optimizer tensors for all K candidates. Every S^(k)
/// starts at all-ones (each block alive, ‖S^(k)(0)‖₁ = m1·n1); A/B are
/// drawn at the single-pattern KPD scaling and then snapped exactly onto
/// the gauge norms, so each candidate's reconstructed W starts at
/// ≈ √(1/n) entries and the gauge holds from step 0.
pub fn init_state_parts(
    dims: &[KpdDims],
    rng: &mut Rng,
) -> (Vec<String>, Vec<Tensor>, Vec<String>, Vec<Tensor>) {
    let mut param_names = Vec::new();
    let mut params = Vec::new();
    let mut opt_names = Vec::new();
    let mut opt = Vec::new();
    for (p, d) in dims.iter().enumerate() {
        let a_std = (1.0 / (d.r * d.n1) as f32).sqrt();
        let b_std = (1.0 / d.n2 as f32).sqrt();
        param_names.push(pname(p, "S"));
        params.push(Tensor::full(&[d.m1, d.n1], 1.0));
        let mut a = Tensor::from_fn(&[d.r, d.m1, d.n1], |_| rng.normal() * a_std);
        let mut b = Tensor::from_fn(&[d.r, d.m2, d.n2], |_| rng.normal() * b_std);
        let (na, nb) = gauge_norms(d);
        for r in 0..d.r {
            let (ga, gb) = (d.m1 * d.n1, d.m2 * d.n2);
            renorm_slice(&mut a.data_mut()[r * ga..(r + 1) * ga], na);
            renorm_slice(&mut b.data_mut()[r * gb..(r + 1) * gb], nb);
        }
        param_names.push(pname(p, "A"));
        params.push(a);
        param_names.push(pname(p, "B"));
        params.push(b);
        opt_names.push(pname(p, "A.m"));
        opt.push(Tensor::zeros(&[d.r, d.m1, d.n1]));
        opt_names.push(pname(p, "B.m"));
        opt.push(Tensor::zeros(&[d.r, d.m2, d.n2]));
    }
    (param_names, params, opt_names, opt)
}

/// One joint training step. Returns the metrics vector
/// `[loss, ce, acc, s_l1_p0 .. s_l1_p{K-1}]` (‖S‖₁ measured pre-update,
/// like the single-pattern path, so the loss reports the objective the
/// gradients were taken at).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    cfg: &SpecConfig,
    state: &mut TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
    lam: f32,
    lr: f32,
    mu: f32,
) -> Result<Vec<f32>> {
    let dims = cfg.pattern_dims();
    let slots = slot_cfgs(cfg);
    let m = cfg.out_dim;
    // forward: one summed-logit pass, keeping each pattern's T′ caches
    let mut z = vec![0.0f32; nb * m];
    let mut caches = Vec::with_capacity(slots.len());
    for lc in &slots {
        let (zp, tp) = layers::linear_forward(cfg, state, lc, x, nb)?;
        for (acc, v) in z.iter_mut().zip(&zp) {
            *acc += v;
        }
        caches.push(tp);
    }
    let sm = linalg::softmax_ce(&z, y, nb, m)?;

    // backward per pattern, all sharing dZ (each candidate's gradients
    // are independent given dZ — no dX chaining between candidates)
    let mut grads = Vec::with_capacity(slots.len());
    for (lc, tp) in slots.iter().zip(&caches) {
        match layers::linear_backward(cfg, state, lc, x, tp, &sm.dz, nb, false)?.0 {
            LinGrads::Kpd(g) => grads.push(g),
            LinGrads::Dense(_) => bail!("pattern_kpd slots are KPD-factorized"),
        }
    }
    apply(state, &dims, &grads, sm.ce_mean, sm.acc_frac, lam, lr, mu)
}

/// Gradient half of the joint step ([`crate::backend::Backend::grad_step`]):
/// every candidate's (gs, ga, gb) at the shared dZ, concatenated in
/// pattern order as per-example *sums*. State untouched.
pub fn grad_step(
    cfg: &SpecConfig,
    state: &TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
) -> Result<GradOut> {
    let slots = slot_cfgs(cfg);
    let m = cfg.out_dim;
    let mut z = vec![0.0f32; nb * m];
    let mut caches = Vec::with_capacity(slots.len());
    for lc in &slots {
        let (zp, tp) = layers::linear_forward(cfg, state, lc, x, nb)?;
        for (acc, v) in z.iter_mut().zip(&zp) {
            *acc += v;
        }
        caches.push(tp);
    }
    let mut sm = linalg::softmax_ce(&z, y, nb, m)?;
    super::scale_to_sum(&mut sm.dz, nb);
    let mut grad_sum = Vec::new();
    for (lc, tp) in slots.iter().zip(&caches) {
        match layers::linear_backward(cfg, state, lc, x, tp, &sm.dz, nb, false)?.0 {
            LinGrads::Kpd(g) => {
                grad_sum.extend(g.gs);
                grad_sum.extend(g.ga);
                grad_sum.extend(g.gb);
            }
            LinGrads::Dense(_) => bail!("pattern_kpd slots are KPD-factorized"),
        }
    }
    Ok(GradOut {
        grad_sum,
        ce_sum: sm.ce_mean * nb as f32,
        correct: sm.correct,
        examples: nb,
    })
}

/// Update half for a reduced flat mean-gradient buffer: slice it back
/// into per-candidate (gs, ga, gb) triples and run [`apply`].
#[allow(clippy::too_many_arguments)]
pub fn apply_update(
    state: &mut TrainState,
    grad: &[f32],
    dims: &[KpdDims],
    ce_mean: f32,
    acc_frac: f32,
    lam: f32,
    lr: f32,
    mu: f32,
) -> Result<Vec<f32>> {
    let mut grads = Vec::with_capacity(dims.len());
    let mut off = 0usize;
    for &d in dims {
        let (sl, al, bl) = (d.m1 * d.n1, d.r * d.m1 * d.n1, d.r * d.m2 * d.n2);
        if off + sl + al + bl > grad.len() {
            bail!("pattern gradient buffer too short");
        }
        let gs = grad[off..off + sl].to_vec();
        off += sl;
        let ga = grad[off..off + al].to_vec();
        off += al;
        let gb = grad[off..off + bl].to_vec();
        off += bl;
        grads.push(kpd::Grads { gs, ga, gb });
    }
    if off != grad.len() {
        bail!("pattern gradient buffer has {} values, layout wants {off}", grad.len());
    }
    apply(state, dims, &grads, ce_mean, acc_frac, lam, lr, mu)
}

/// Per-candidate optimizer + gauge + prox updates on mean gradients — the
/// one copy of the update math, shared by the fused [`train_step`] and
/// the data-parallel [`apply_update`]. Returns the metrics vector
/// `[loss, ce, acc, s_l1_p0 .. s_l1_p{K-1}]` with ‖S‖₁ read pre-update.
#[allow(clippy::too_many_arguments)]
fn apply(
    state: &mut TrainState,
    dims: &[KpdDims],
    grads: &[kpd::Grads],
    ce_mean: f32,
    acc_frac: f32,
    lam: f32,
    lr: f32,
    mu: f32,
) -> Result<Vec<f32>> {
    let mut metrics = vec![0.0, ce_mean, acc_frac];
    let mut total_l1 = 0.0f32;
    for (p, &d) in dims.iter().enumerate() {
        // pre-update ‖S‖₁ (this pattern's S has not been touched yet)
        let s_l1 = state.param(&pname(p, "S"))?.abs_sum();
        let g = &grads[p];
        let (ai, avi) = (pidx(state, &pname(p, "A"))?, oidx(state, &pname(p, "A.m"))?);
        sgd_momentum(state.params[ai].data_mut(), state.opt[avi].data_mut(), &g.ga, lr, mu);
        let (bi, bvi) = (pidx(state, &pname(p, "B"))?, oidx(state, &pname(p, "B.m"))?);
        sgd_momentum(state.params[bi].data_mut(), state.opt[bvi].data_mut(), &g.gb, lr, mu);
        // gauge: factors carry direction only — snap back to nominal norms
        let (na, nbn) = gauge_norms(&d);
        let (ga_len, gb_len) = (d.m1 * d.n1, d.m2 * d.n2);
        for r in 0..d.r {
            renorm_slice(&mut state.params[ai].data_mut()[r * ga_len..(r + 1) * ga_len], na);
            renorm_slice(&mut state.params[bi].data_mut()[r * gb_len..(r + 1) * gb_len], nbn);
        }
        // S^(k): plain SGD at the gauge-compensated step fused with the
        // ℓ1 prox (exact zeros kill whole blocks)
        let s_lr = lr * s_step_scale(&d);
        let si = pidx(state, &pname(p, "S"))?;
        sgd_prox_l1(state.params[si].data_mut(), &g.gs, s_lr, s_lr * lam);

        total_l1 += s_l1;
        metrics.push(s_l1);
    }
    metrics[0] = ce_mean + lam * total_l1;
    Ok(metrics)
}

/// Per-pattern evaluation: each candidate scored **alone** on its own
/// logits, so the Figure-3 claim ("the survivor matches the individually
/// best pattern") is measurable from one state. Layout:
/// `[ce_0..ce_{K-1}, correct_0..correct_{K-1}]`.
pub fn eval_step(
    cfg: &SpecConfig,
    state: &TrainState,
    x: &[f32],
    nb: usize,
    y: &[i32],
) -> Result<Vec<f32>> {
    let slots = slot_cfgs(cfg);
    let m = cfg.out_dim;
    let mut ces = Vec::with_capacity(slots.len());
    let mut corrects = Vec::with_capacity(slots.len());
    for lc in &slots {
        let (z, _) = layers::linear_forward(cfg, state, lc, x, nb)?;
        let sm = linalg::softmax_ce(&z, y, nb, m)?;
        ces.push(sm.ce_mean);
        corrects.push(sm.correct);
    }
    ces.extend(corrects);
    Ok(ces)
}

/// ‖S^(k)‖₁ / ‖S^(k)(0)‖₁ per pattern. S starts at all-ones, so the
/// initial norm is exactly the entry count — patterns of different block
/// sizes become comparable on this normalized scale (the way Figure 3
/// reads once normalized). Dims-based twin of
/// `coordinator::probe::pattern_retention` (which derives the same counts
/// from the spec's grid info); keep the two normalizations in agreement.
pub fn retention(state: &TrainState, dims: &[KpdDims]) -> Result<Vec<f64>> {
    dims.iter()
        .enumerate()
        .map(|(p, d)| {
            let s = state.param(&pname(p, "S"))?;
            Ok(s.abs_sum() as f64 / (d.m1 * d.n1) as f64)
        })
        .collect()
}

/// Index of the surviving pattern: max normalized retention, via the
/// shared [`crate::util::argmax`] — the same criterion
/// `coordinator::probe::pattern_survivor` applies, so the pattern
/// `materialize` extracts and the pattern the tools report cannot diverge.
pub fn survivor(state: &TrainState, dims: &[KpdDims]) -> Result<usize> {
    Ok(crate::util::argmax(&retention(state, dims)?))
}

/// Cost-aware survivor: blend normalized retention against modeled
/// serving latency. Both axes are min-max normalized over the candidate
/// set, then scored `(1−α)·retention̂ − α·latencŷ` — α = 0 recovers the
/// pure Figure-3 max-retention criterion, α = 1 picks the cheapest
/// candidate outright. The span guard keeps an all-equal axis from
/// dividing by zero (it then contributes nothing, which is the right
/// reading of "no signal on this axis"). Shared with
/// `coordinator::probe::pattern_survivor_cost_aware` and the `blockopt`
/// CLI, so every cost-aware selection in the repo scores identically.
pub fn survivor_cost_aware(retention: &[f64], latency_ms: &[f64], alpha: f64) -> Result<usize> {
    if retention.is_empty() {
        bail!("cost-aware survivor wants at least one candidate");
    }
    if retention.len() != latency_ms.len() {
        bail!(
            "cost-aware survivor: {} retentions but {} latencies",
            retention.len(),
            latency_ms.len()
        );
    }
    let alpha = alpha.clamp(0.0, 1.0);
    let span_of = |xs: &[f64]| -> (f64, f64) {
        let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        (lo, (hi - lo).max(f64::EPSILON))
    };
    let (rlo, rspan) = span_of(retention);
    let (llo, lspan) = span_of(latency_ms);
    let scores: Vec<f64> = retention
        .iter()
        .zip(latency_ms)
        .map(|(&r, &l)| (1.0 - alpha) * ((r - rlo) / rspan) - alpha * ((l - llo) / lspan))
        .collect();
    Ok(crate::util::argmax(&scores))
}

/// Survivor extraction: reconstruct the dense W of the max-retention
/// pattern (the model one would deploy after the joint run).
pub fn materialize_survivor(state: &TrainState, dims: &[KpdDims]) -> Result<(usize, Tensor)> {
    let p = survivor(state, dims)?;
    let s = state.param(&pname(p, "S"))?;
    let a = state.param(&pname(p, "A"))?;
    let b = state.param(&pname(p, "B"))?;
    Ok((p, Tensor::kpd_reconstruct(s, a, b)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims2() -> Vec<KpdDims> {
        // two candidates over the same 4×8 weight: blocks 2×2 and 2×4
        vec![KpdDims::from_block(4, 8, 2, 2, 2), KpdDims::from_block(4, 8, 2, 4, 2)]
    }

    /// The spec whose `pattern_dims()` equals [`dims2`].
    fn cfg2() -> SpecConfig {
        SpecConfig::pattern("pat_test", 8, 4, &[(2, 2), (2, 4)], 2, 8)
    }

    fn state_for(dims: &[KpdDims], seed: u64) -> TrainState {
        let mut rng = Rng::new(seed);
        let (param_names, params, opt_names, opt) = init_state_parts(dims, &mut rng);
        TrainState { spec: "pat_test".into(), param_names, opt_names, params, opt }
    }

    fn batch(nb: usize, n: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..nb * n).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn init_layout_and_all_ones_s() {
        let dims = dims2();
        let st = state_for(&dims, 1);
        assert_eq!(st.param_names.len(), 6);
        assert_eq!(st.opt_names.len(), 4);
        for p in 0..2 {
            let s = st.param(&pname(p, "S")).unwrap();
            assert!(s.data().iter().all(|&v| v == 1.0));
            assert_eq!(s.shape(), &[dims[p].m1, dims[p].n1]);
        }
        let r = retention(&st, &dims).unwrap();
        assert!(r.iter().all(|&v| (v - 1.0).abs() < 1e-6), "{r:?}");
    }

    #[test]
    fn summed_forward_matches_sum_of_reconstructions() {
        let dims = dims2();
        let st = state_for(&dims, 2);
        let (x, y) = batch(3, 8, 4, 7);
        // reference: Z = Σ_k X · W^(k)ᵀ with materialized W^(k)
        let mut zref = vec![0.0f32; 3 * 4];
        for p in 0..2 {
            let w = Tensor::kpd_reconstruct(
                st.param(&pname(p, "S")).unwrap(),
                st.param(&pname(p, "A")).unwrap(),
                st.param(&pname(p, "B")).unwrap(),
            )
            .unwrap();
            for bb in 0..3 {
                for i in 0..4 {
                    for j in 0..8 {
                        zref[bb * 4 + i] += x[bb * 8 + j] * w.at2(i, j);
                    }
                }
            }
        }
        // the joint step reports CE of the summed logits: recompute both ways
        let mut st2 = state_for(&dims, 2);
        let m = train_step(&cfg2(), &mut st2, &x, 3, &y, 0.0, 0.0, 0.0).unwrap();
        let sm = linalg::softmax_ce(&zref, &y, 3, 4).unwrap();
        assert!((m[1] - sm.ce_mean).abs() < 1e-4, "{} vs {}", m[1], sm.ce_mean);
    }

    #[test]
    fn train_step_metrics_layout_and_prox_thresholds() {
        let dims = dims2();
        let mut st = state_for(&dims, 3);
        let (x, y) = batch(6, 8, 4, 8);
        let m = train_step(&cfg2(), &mut st, &x, 6, &y, 0.05, 0.1, 0.9).unwrap();
        // [loss, ce, acc, s_l1_p0, s_l1_p1]
        assert_eq!(m.len(), 5);
        assert!(m.iter().all(|v| v.is_finite()), "{m:?}");
        // pre-update S is all-ones: s_l1_pk == entry count
        assert_eq!(m[3], (dims[0].m1 * dims[0].n1) as f32);
        assert_eq!(m[4], (dims[1].m1 * dims[1].n1) as f32);
        // loss = ce + λ·Σ‖S‖₁
        let want = m[1] + 0.05 * (m[3] + m[4]);
        assert!((m[0] - want).abs() < 1e-4);
        // a few steps of pure prox (λ≫grad) produce exact zeros
        for _ in 0..40 {
            train_step(&cfg2(), &mut st, &x, 6, &y, 2.0, 0.1, 0.9).unwrap();
        }
        let zeros = st
            .param(&pname(0, "S"))
            .unwrap()
            .data()
            .iter()
            .filter(|v| **v == 0.0)
            .count();
        assert!(zeros > 0, "prox never produced an exact zero");
    }

    #[test]
    fn eval_layout_is_ce_then_correct_per_pattern() {
        let dims = dims2();
        let st = state_for(&dims, 4);
        let (x, y) = batch(5, 8, 4, 9);
        let m = eval_step(&cfg2(), &st, &x, 5, &y).unwrap();
        assert_eq!(m.len(), 4);
        assert!(m[0] > 0.0 && m[1] > 0.0, "ce must be positive: {m:?}");
        assert!(m[2] >= 0.0 && m[2] <= 5.0, "correct count in range: {m:?}");
        assert!(m[3] >= 0.0 && m[3] <= 5.0);
        assert_eq!(m[2].fract(), 0.0, "correct is a count");
    }

    #[test]
    fn survivor_extraction_follows_retention() {
        let dims = dims2();
        let mut st = state_for(&dims, 5);
        // zero out pattern 0's S entirely: pattern 1 must win
        let si = st.param_names.iter().position(|n| n == &pname(0, "S")).unwrap();
        for v in st.params[si].data_mut() {
            *v = 0.0;
        }
        assert_eq!(survivor(&st, &dims).unwrap(), 1);
        let (p, w) = materialize_survivor(&st, &dims).unwrap();
        assert_eq!(p, 1);
        assert_eq!(w.shape(), &[4, 8]);
    }

    #[test]
    fn cost_aware_survivor_blend() {
        let ret = [0.9, 0.5, 0.2];
        let lat = [3.0, 1.0, 0.5];
        // α = 0 is the pure Figure-3 criterion
        assert_eq!(survivor_cost_aware(&ret, &lat, 0.0).unwrap(), 0);
        // α = 1 picks the cheapest candidate outright
        assert_eq!(survivor_cost_aware(&ret, &lat, 1.0).unwrap(), 2);
        // α = 0.6: hand-computed normalized scores are
        // [0.4 − 0.6, 0.4·(0.3/0.7) − 0.6·0.2, 0.0] ≈ [−0.2, 0.051, 0.0]
        // — the middle candidate's trade-off wins
        assert_eq!(survivor_cost_aware(&ret, &lat, 0.6).unwrap(), 1);
        // out-of-range α clamps instead of flipping the objective
        assert_eq!(survivor_cost_aware(&ret, &lat, -3.0).unwrap(), 0);
        // an all-equal axis contributes nothing (no division blow-up)
        assert_eq!(survivor_cost_aware(&ret, &[2.0, 2.0, 2.0], 0.9).unwrap(), 0);
        // degenerate inputs are typed errors, not panics
        assert!(survivor_cost_aware(&[], &[], 0.5).is_err());
        assert!(survivor_cost_aware(&ret, &lat[..2], 0.5).is_err());
    }
}
