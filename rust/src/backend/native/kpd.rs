//! Factorized KPD linear map: forward/backward without materializing W.
//!
//! W = Σ_r (S ⊙ A_r) ⊗ B_r   (paper Eq. 3), applied to a batch X (N × n)
//! as Z = X·Wᵀ using the Kronecker identity
//!     ((C ⊗ B) x)[i1·m2+i2] = Σ_{j1} C[i1,j1] · Σ_{j2} B[i2,j2] x[j1·n2+j2]
//! so each rank costs two small matmuls (the paper's Eq. 18 operation
//! count) instead of the dense N·m·n contraction:
//!
//!   T  = X′ · Bᵀ          X′ = X viewed as (N·n1, n2)      → (N·n1, m2)
//!   Z += C · T′           T′ = T regrouped as (n1, N·m2)   → scatter (N, m)
//!
//! The backward pass reuses T′ per rank:
//!   dC = dZ′ · T′ᵀ,   U′ = Cᵀ · dZ′,   dB = U″ᵀ · X′
//! with dA = dC ⊙ S and dS = Σ_r dC_r ⊙ A_r.

use crate::flops::KpdDims;

use super::linalg;
use super::simd::{self, SimdKind};

/// Regroup T (N·n1, m2) → T′ (n1, N·m2).
fn regroup_t(t: &[f32], n_batch: usize, n1: usize, m2: usize) -> Vec<f32> {
    let mut tp = vec![0.0f32; n1 * n_batch * m2];
    for b in 0..n_batch {
        for j1 in 0..n1 {
            let src = &t[(b * n1 + j1) * m2..(b * n1 + j1 + 1) * m2];
            let dst = &mut tp[j1 * n_batch * m2 + b * m2..j1 * n_batch * m2 + (b + 1) * m2];
            dst.copy_from_slice(src);
        }
    }
    tp
}

/// Hadamard product of two equal-length slices.
fn had(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b).map(|(x, y)| x * y).collect()
}

/// Factorized forward: logits Z (N, m1·m2) plus the per-rank T′ caches
/// (n1, N·m2) that [`backward`] reuses.
///
/// Layouts: `x` (N, n1·n2), `s` (m1, n1), `a` (r, m1, n1), `b` (r, m2, n2),
/// all row-major.
pub fn forward(
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    b: &[f32],
    d: KpdDims,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    forward_with(simd::active(), x, n_batch, s, a, b, d)
}

/// [`forward`] with an explicit SIMD kind threaded through both per-rank
/// matmuls — the kind is resolved exactly once per KPD application.
#[allow(clippy::too_many_arguments)]
pub fn forward_with(
    kind: SimdKind,
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    b: &[f32],
    d: KpdDims,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let KpdDims { m1, n1, m2, n2, r } = d;
    let (m, n) = (m1 * m2, n1 * n2);
    debug_assert_eq!(x.len(), n_batch * n);
    debug_assert_eq!(s.len(), m1 * n1);
    debug_assert_eq!(a.len(), r * m1 * n1);
    debug_assert_eq!(b.len(), r * m2 * n2);
    let mut z = vec![0.0f32; n_batch * m];
    let mut caches = Vec::with_capacity(r);
    for i in 0..r {
        let bi = &b[i * m2 * n2..(i + 1) * m2 * n2];
        // X′ (N·n1, n2) is the same buffer as X — contiguous regrouping
        let t = linalg::matmul_nt_with(kind, x, bi, n_batch * n1, n2, m2);
        let tp = regroup_t(&t, n_batch, n1, m2);
        let c = had(s, &a[i * m1 * n1..(i + 1) * m1 * n1]);
        let zc = linalg::matmul_nn_with(kind, &c, &tp, m1, n1, n_batch * m2);
        for bb in 0..n_batch {
            for i1 in 0..m1 {
                let src = &zc[i1 * n_batch * m2 + bb * m2..i1 * n_batch * m2 + (bb + 1) * m2];
                let dst = &mut z[bb * m + i1 * m2..bb * m + (i1 + 1) * m2];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }
        caches.push(tp);
    }
    (z, caches)
}

/// Gradients of the factorized map wrt S, A and B.
pub struct Grads {
    /// (m1, n1)
    pub gs: Vec<f32>,
    /// (r, m1, n1)
    pub ga: Vec<f32>,
    /// (r, m2, n2)
    pub gb: Vec<f32>,
}

/// Backward pass. `dz` is d(loss)/dZ (N, m1·m2); `tprime` is the cache
/// returned by [`forward`] on the same inputs.
pub fn backward(
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    dz: &[f32],
    tprime: &[Vec<f32>],
    d: KpdDims,
) -> Grads {
    backward_impl(simd::active(), x, n_batch, s, a, None, dz, tprime, d).0
}

/// [`backward`] with an explicit SIMD kind (see [`forward_with`]).
#[allow(clippy::too_many_arguments)]
pub fn backward_with(
    kind: SimdKind,
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    dz: &[f32],
    tprime: &[Vec<f32>],
    d: KpdDims,
) -> Grads {
    backward_impl(kind, x, n_batch, s, a, None, dz, tprime, d).0
}

/// Backward pass that also returns dX = dZ · W (N, n1·n2) — what a
/// *hidden* KPD layer in a multi-layer stack must hand to the layer below.
/// Needs the B factor (r, m2, n2) to complete the chain; the per-rank U″
/// buffer the dB product already builds is reused, so dX costs one extra
/// (N·n1, m2)·(m2, n2) matmul per rank.
#[allow(clippy::too_many_arguments)]
pub fn backward_dx(
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    b: &[f32],
    dz: &[f32],
    tprime: &[Vec<f32>],
    d: KpdDims,
) -> (Grads, Vec<f32>) {
    let (g, dx) = backward_impl(simd::active(), x, n_batch, s, a, Some(b), dz, tprime, d);
    (g, dx.expect("dx requested"))
}

#[allow(clippy::too_many_arguments)]
fn backward_impl(
    kind: SimdKind,
    x: &[f32],
    n_batch: usize,
    s: &[f32],
    a: &[f32],
    b: Option<&[f32]>,
    dz: &[f32],
    tprime: &[Vec<f32>],
    d: KpdDims,
) -> (Grads, Option<Vec<f32>>) {
    let KpdDims { m1, n1, m2, n2, r } = d;
    let m = m1 * m2;
    debug_assert_eq!(dz.len(), n_batch * m);
    debug_assert_eq!(tprime.len(), r);
    // dZ′ (m1, N·m2)
    let mut dzp = vec![0.0f32; m1 * n_batch * m2];
    for bb in 0..n_batch {
        for i1 in 0..m1 {
            let src = &dz[bb * m + i1 * m2..bb * m + (i1 + 1) * m2];
            let dst = &mut dzp[i1 * n_batch * m2 + bb * m2..i1 * n_batch * m2 + (bb + 1) * m2];
            dst.copy_from_slice(src);
        }
    }
    let mut gs = vec![0.0f32; m1 * n1];
    let mut ga = vec![0.0f32; r * m1 * n1];
    let mut gb = vec![0.0f32; r * m2 * n2];
    let mut dx = b.map(|_| vec![0.0f32; n_batch * n1 * n2]);
    for i in 0..r {
        let ai = &a[i * m1 * n1..(i + 1) * m1 * n1];
        let c = had(s, ai);
        // dC (m1, n1) = dZ′ · T′ᵀ
        let dc = linalg::matmul_nt_with(kind, &dzp, &tprime[i], m1, n_batch * m2, n1);
        for j in 0..m1 * n1 {
            ga[i * m1 * n1 + j] = dc[j] * s[j];
            gs[j] += dc[j] * ai[j];
        }
        // U′ (n1, N·m2) = Cᵀ · dZ′
        let up = linalg::matmul_tn_with(kind, &c, &dzp, m1, n1, n_batch * m2);
        // U″ (N·n1, m2)
        let mut u2 = vec![0.0f32; n_batch * n1 * m2];
        for bb in 0..n_batch {
            for j1 in 0..n1 {
                let src = &up[j1 * n_batch * m2 + bb * m2..j1 * n_batch * m2 + (bb + 1) * m2];
                let dst = &mut u2[(bb * n1 + j1) * m2..(bb * n1 + j1 + 1) * m2];
                dst.copy_from_slice(src);
            }
        }
        // dB (m2, n2) = U″ᵀ · X′
        let dbi = linalg::matmul_tn_with(kind, &u2, x, n_batch * n1, m2, n2);
        gb[i * m2 * n2..(i + 1) * m2 * n2].copy_from_slice(&dbi);
        // dX′ (N·n1, n2) += U″ · B_i — same buffer layout as X (N, n)
        if let (Some(dx), Some(b)) = (dx.as_mut(), b) {
            let bi = &b[i * m2 * n2..(i + 1) * m2 * n2];
            let dxi = linalg::matmul_nn_with(kind, &u2, bi, n_batch * n1, m2, n2);
            for (o, v) in dx.iter_mut().zip(&dxi) {
                *o += v;
            }
        }
    }
    (Grads { gs, ga, gb }, dx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Dense reference: Z = X · Wᵀ with W = Σ_r (S⊙A_r) ⊗ B_r.
    fn dense_forward(
        x: &[f32],
        n_batch: usize,
        s: &[f32],
        a: &[f32],
        b: &[f32],
        d: KpdDims,
    ) -> Vec<f32> {
        let (m, n) = (d.m1 * d.m2, d.n1 * d.n2);
        let st = Tensor::new(&[d.m1, d.n1], s.to_vec()).unwrap();
        let at = Tensor::new(&[d.r, d.m1, d.n1], a.to_vec()).unwrap();
        let bt = Tensor::new(&[d.r, d.m2, d.n2], b.to_vec()).unwrap();
        let w = Tensor::kpd_reconstruct(&st, &at, &bt).unwrap();
        let mut z = vec![0.0f32; n_batch * m];
        for bb in 0..n_batch {
            for i in 0..m {
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += x[bb * n + j] * w.at2(i, j);
                }
                z[bb * m + i] = acc;
            }
        }
        z
    }

    #[test]
    fn forward_matches_materialized_kron() {
        let mut rng = Rng::new(21);
        for &(m1, n1, m2, n2, r, nb) in
            &[(2, 3, 2, 2, 1, 4), (3, 2, 2, 4, 2, 5), (1, 4, 3, 3, 3, 2)]
        {
            let d = KpdDims { m1, n1, m2, n2, r };
            let x = rand_vec(&mut rng, nb * n1 * n2);
            let s = rand_vec(&mut rng, m1 * n1);
            let a = rand_vec(&mut rng, r * m1 * n1);
            let b = rand_vec(&mut rng, r * m2 * n2);
            let (z, _) = forward(&x, nb, &s, &a, &b, d);
            let want = dense_forward(&x, nb, &s, &a, &b, d);
            let diff = z
                .iter()
                .zip(&want)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-4, "{d:?}: max diff {diff}");
        }
    }

    #[test]
    fn backward_matches_finite_differences_on_sum_loss() {
        // loss = Σ Z ⇒ dZ = 1; check dS, dA, dB against central differences
        let mut rng = Rng::new(22);
        let d = KpdDims { m1: 2, n1: 2, m2: 2, n2: 3, r: 2 };
        let nb = 3;
        let x = rand_vec(&mut rng, nb * d.n1 * d.n2);
        let s = rand_vec(&mut rng, d.m1 * d.n1);
        let a = rand_vec(&mut rng, d.r * d.m1 * d.n1);
        let b = rand_vec(&mut rng, d.r * d.m2 * d.n2);
        let loss = |s: &[f32], a: &[f32], b: &[f32]| -> f32 {
            forward(&x, nb, s, a, b, d).0.iter().sum()
        };
        let (_, tp) = forward(&x, nb, &s, &a, &b, d);
        let dz = vec![1.0f32; nb * d.m1 * d.m2];
        let g = backward(&x, nb, &s, &a, &dz, &tp, d);
        let h = 1e-2f32;
        for idx in 0..s.len() {
            let mut sp = s.clone();
            sp[idx] += h;
            let mut sm = s.clone();
            sm[idx] -= h;
            let fd = (loss(&sp, &a, &b) - loss(&sm, &a, &b)) / (2.0 * h);
            assert!((fd - g.gs[idx]).abs() < 1e-2, "gs[{idx}]: {fd} vs {}", g.gs[idx]);
        }
        for idx in 0..a.len() {
            let mut ap = a.clone();
            ap[idx] += h;
            let mut am = a.clone();
            am[idx] -= h;
            let fd = (loss(&s, &ap, &b) - loss(&s, &am, &b)) / (2.0 * h);
            assert!((fd - g.ga[idx]).abs() < 1e-2, "ga[{idx}]: {fd} vs {}", g.ga[idx]);
        }
        for idx in 0..b.len() {
            let mut bp = b.clone();
            bp[idx] += h;
            let mut bm = b.clone();
            bm[idx] -= h;
            let fd = (loss(&s, &a, &bp) - loss(&s, &a, &bm)) / (2.0 * h);
            assert!((fd - g.gb[idx]).abs() < 1e-2, "gb[{idx}]: {fd} vs {}", g.gb[idx]);
        }
    }

    #[test]
    fn backward_dx_matches_dense_chain_rule() {
        // dX of loss = Σ Z must equal the row-sum of W (dZ = 1 ⇒ dX = 1·W),
        // and the factor grads must be identical to the plain backward's.
        let mut rng = Rng::new(23);
        let d = KpdDims { m1: 2, n1: 3, m2: 2, n2: 2, r: 2 };
        let nb = 4;
        let (m, n) = (d.m1 * d.m2, d.n1 * d.n2);
        let x = rand_vec(&mut rng, nb * n);
        let s = rand_vec(&mut rng, d.m1 * d.n1);
        let a = rand_vec(&mut rng, d.r * d.m1 * d.n1);
        let b = rand_vec(&mut rng, d.r * d.m2 * d.n2);
        let (_, tp) = forward(&x, nb, &s, &a, &b, d);
        let dz = vec![1.0f32; nb * m];
        let plain = backward(&x, nb, &s, &a, &dz, &tp, d);
        let (g, dx) = backward_dx(&x, nb, &s, &a, &b, &dz, &tp, d);
        assert_eq!(g.gs, plain.gs);
        assert_eq!(g.ga, plain.ga);
        assert_eq!(g.gb, plain.gb);
        // dense reference: dX[b, j] = Σ_i W[i, j]
        let st = Tensor::new(&[d.m1, d.n1], s.clone()).unwrap();
        let at = Tensor::new(&[d.r, d.m1, d.n1], a.clone()).unwrap();
        let bt = Tensor::new(&[d.r, d.m2, d.n2], b.clone()).unwrap();
        let w = Tensor::kpd_reconstruct(&st, &at, &bt).unwrap();
        for bb in 0..nb {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| w.at2(i, j)).sum();
                let got = dx[bb * n + j];
                assert!((got - want).abs() < 1e-4, "dx[{bb},{j}]: {got} vs {want}");
            }
        }
    }
}
