//! PJRT adapter: the AOT/HLO execution path behind the `Backend` trait.
//!
//! Wraps `crate::runtime::Runtime` (compile cache + manifest) and marshals
//! the backend-agnostic `Tensor` state into `xla::Literal`s per call. This
//! re-marshalling trades a little hot-path cost for a literal-free default
//! build; the raw `Runtime` API remains available for zero-copy loops.

use anyhow::{bail, Result};

use crate::manifest::SpecEntry;
use crate::runtime::{Runtime, TrainState as LitState};
use crate::tensor::{HostValue, Tensor};

use super::{Backend, TrainState};

pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtBackend { rt: Runtime::new(artifact_dir)? })
    }

    /// Direct access to the underlying runtime (compile cache, manifest).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn to_literals(ts: &[Tensor]) -> Result<Vec<xla::Literal>> {
        ts.iter().map(|t| HostValue::F32(t.clone()).to_literal()).collect()
    }

    fn from_literals(lits: &[xla::Literal]) -> Result<Vec<Tensor>> {
        lits.iter()
            .map(|l| match HostValue::from_literal(l)? {
                HostValue::F32(t) => Ok(t),
                other => bail!("non-f32 state leaf ({:?})", other.dtype()),
            })
            .collect()
    }

    fn lit_state(&self, state: &TrainState) -> Result<LitState> {
        Ok(LitState {
            spec: state.spec.clone(),
            param_names: state.param_names.clone(),
            opt_names: state.opt_names.clone(),
            params: Self::to_literals(&state.params)?,
            opt: Self::to_literals(&state.opt)?,
        })
    }

    fn write_back(state: &mut TrainState, ls: &LitState) -> Result<()> {
        state.params = Self::from_literals(&ls.params)?;
        state.opt = Self::from_literals(&ls.opt)?;
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt:{}", self.rt.platform())
    }

    fn specs(&self) -> Vec<&SpecEntry> {
        self.rt.manifest.specs.values().collect()
    }

    fn spec(&self, key: &str) -> Result<&SpecEntry> {
        self.rt.spec(key)
    }

    fn init_state(&self, spec: &str, seed: u32) -> Result<TrainState> {
        let ls = self.rt.init_state(spec, seed)?;
        Ok(TrainState {
            spec: ls.spec.clone(),
            param_names: ls.param_names.clone(),
            opt_names: ls.opt_names.clone(),
            params: Self::from_literals(&ls.params)?,
            opt: Self::from_literals(&ls.opt)?,
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &HostValue,
        y: &HostValue,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let mut ls = self.lit_state(state)?;
        let metrics = self.rt.train_step(&mut ls, &x.to_literal()?, &y.to_literal()?, hyper)?;
        Self::write_back(state, &ls)?;
        Ok(metrics)
    }

    fn eval_step(&self, state: &TrainState, x: &HostValue, y: &HostValue) -> Result<Vec<f32>> {
        let ls = self.lit_state(state)?;
        self.rt.eval_step(&ls, &x.to_literal()?, &y.to_literal()?)
    }

    fn fixed_batch(&self) -> bool {
        // every AOT executable is lowered for the spec's exact batch shape
        true
    }

    fn materialize(&self, state: &TrainState) -> Result<Vec<(String, Tensor)>> {
        let ls = self.lit_state(state)?;
        self.rt.materialize(&ls)
    }

    fn rigl_update(&self, state: &mut TrainState, gnorm: &[f32], alpha: f32) -> Result<()> {
        let mut ls = self.lit_state(state)?;
        self.rt.rigl_update(&mut ls, gnorm, alpha)?;
        Self::write_back(state, &ls)
    }

    fn prune(&self, state: &mut TrainState, target: f32) -> Result<()> {
        let mut ls = self.lit_state(state)?;
        self.rt.prune(&mut ls, target)?;
        Self::write_back(state, &ls)
    }

    fn gnorm_len(&self, spec: &str) -> Result<usize> {
        // train_step metrics = [loss, ce, acc] ++ per-block gradient norms
        let e = self.rt.manifest.exec(spec, "train_step")?;
        let total: usize = e.outputs.last().map(|o| o.elements()).unwrap_or(3);
        Ok(total.saturating_sub(3))
    }
}
