//! Checkpoint substrate: a small versioned binary container for named f32
//! tensors (parameters and optimizer state), with a CRC32 integrity check.
//!
//! Format (little-endian):
//!   magic "BSCK" | u32 version | u32 count
//!   per entry: u32 name_len | name utf8 | u32 ndim | u64 dims[] | f32 data[]
//!   trailing u32 crc32 over everything after the magic
//!
//! Deliberately simple: no mmap, no compression — checkpoints here are at
//! most a few tens of MB and are written at eval boundaries only.
//!
//! The [`wire`] helpers (length-prefixed strings, fixed-width ints, f32
//! runs) and the trailing-[`crc32`] guard are shared with the BSR model
//! artifact (`crate::infer`), so both containers framed this way fail the
//! same loud way on truncation or corruption.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::TrainState;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"BSCK";
const VERSION: u32 = 1;

/// Little-endian framing primitives shared by the checkpoint container and
/// the BSR model artifact. Readers bounds-check and error on truncation, so
/// a short file fails before a garbage value is ever interpreted.
pub(crate) mod wire {
    use anyhow::{bail, Result};

    pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(buf: &mut Vec<u8>, s: &str) {
        put_u32(buf, s.len() as u32);
        buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
        for &v in xs {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
        for &v in xs {
            put_u32(buf, v);
        }
    }

    pub fn get_u32(b: &[u8], off: &mut usize) -> Result<u32> {
        if *off + 4 > b.len() {
            bail!("truncated container (u32)");
        }
        let v = u32::from_le_bytes(b[*off..*off + 4].try_into().unwrap());
        *off += 4;
        Ok(v)
    }

    pub fn get_u64(b: &[u8], off: &mut usize) -> Result<u64> {
        if *off + 8 > b.len() {
            bail!("truncated container (u64)");
        }
        let v = u64::from_le_bytes(b[*off..*off + 8].try_into().unwrap());
        *off += 8;
        Ok(v)
    }

    pub fn get_str(b: &[u8], off: &mut usize) -> Result<String> {
        let n = get_u32(b, off)? as usize;
        if *off + n > b.len() {
            bail!("truncated container (string)");
        }
        let s = String::from_utf8(b[*off..*off + n].to_vec())
            .map_err(|_| anyhow::anyhow!("container string is not utf8"))?;
        *off += n;
        Ok(s)
    }

    pub fn get_f32s(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
        if b.len().saturating_sub(*off) < 4 * n {
            bail!("truncated container (f32 run of {n})");
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(f32::from_le_bytes(
                b[*off + 4 * i..*off + 4 * i + 4].try_into().unwrap(),
            ));
        }
        *off += 4 * n;
        Ok(out)
    }

    pub fn get_u32s(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<u32>> {
        if b.len().saturating_sub(*off) < 4 * n {
            bail!("truncated container (u32 run of {n})");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(get_u32(b, off)?);
        }
        Ok(out)
    }
}

pub struct Checkpoint {
    pub entries: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn new(entries: Vec<(String, Tensor)>) -> Self {
        Self { entries }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Snapshot a backend [`TrainState`]: every parameter as `param:{name}`
    /// and every optimizer slot as `opt:{name}` (the manifest's IO-slot
    /// prefix convention), so mid-run training state — multi-layer stacks
    /// included — round-trips bit-exactly through the container.
    pub fn from_state(state: &TrainState) -> Self {
        let mut entries =
            Vec::with_capacity(state.params.len() + state.opt.len());
        for (n, t) in state.param_names.iter().zip(&state.params) {
            entries.push((format!("param:{n}"), t.clone()));
        }
        for (n, t) in state.opt_names.iter().zip(&state.opt) {
            entries.push((format!("opt:{n}"), t.clone()));
        }
        Checkpoint::new(entries)
    }

    /// Restore a [`Checkpoint::from_state`] snapshot into a compatibly
    /// shaped state (e.g. a fresh `Backend::init_state` of the same spec).
    /// Every param/opt slot must be present with its exact shape — a
    /// missing or reshaped entry is a spec mismatch, not a partial load.
    pub fn restore_state(&self, state: &mut TrainState) -> Result<()> {
        self.restore_slice("param", &state.param_names, &mut state.params)?;
        self.restore_slice("opt", &state.opt_names, &mut state.opt)
    }

    fn restore_slice(
        &self,
        prefix: &str,
        names: &[String],
        tensors: &mut [Tensor],
    ) -> Result<()> {
        for (n, t) in names.iter().zip(tensors.iter_mut()) {
            let key = format!("{prefix}:{n}");
            let e = self
                .get(&key)
                .with_context(|| format!("checkpoint has no '{key}'"))?;
            if e.shape() != t.shape() {
                bail!("checkpoint '{key}': shape {:?} != {:?}", e.shape(), t.shape());
            }
            *t = e.clone();
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut body = Vec::new();
        wire::put_u32(&mut body, VERSION);
        wire::put_u32(&mut body, self.entries.len() as u32);
        for (name, t) in &self.entries {
            wire::put_str(&mut body, name);
            wire::put_u32(&mut body, t.shape().len() as u32);
            for &d in t.shape() {
                wire::put_u64(&mut body, d as u64);
            }
            wire::put_f32s(&mut body, t.data());
        }
        let crc = crc32(&body);
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {path:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&body)?;
        f.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        if all.len() < 12 || &all[..4] != MAGIC {
            bail!("not a BSCK checkpoint");
        }
        let body = &all[4..all.len() - 4];
        let stored_crc = u32::from_le_bytes(all[all.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("checkpoint CRC mismatch (corrupt file)");
        }
        let mut off = 0usize;
        let version = wire::get_u32(body, &mut off).context("reading checkpoint")?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = wire::get_u32(body, &mut off)? as usize;
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let name = wire::get_str(body, &mut off).context("checkpoint entry name")?;
            let ndim = wire::get_u32(body, &mut off)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(wire::get_u64(body, &mut off).context("checkpoint dims")? as usize);
            }
            let n: usize = dims.iter().product();
            let data = wire::get_f32s(body, &mut off, n).context("checkpoint data")?;
            entries.push((name, Tensor::new(&dims, data)?));
        }
        Ok(Self { entries })
    }
}

/// CRC-32 (IEEE), table-less bitwise variant — integrity only, not perf.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bs_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.bsck");
        let ck = Checkpoint::new(vec![
            ("w".into(), Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap()),
            ("b".into(), Tensor::new(&[], vec![7.0]).unwrap()),
        ]);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.get("w").unwrap().shape(), &[2, 3]);
        assert_eq!(back.get("b").unwrap().data(), &[7.0]);
        assert!(back.get("nope").is_none());
    }

    #[test]
    fn corruption_detected_as_crc_mismatch() {
        // flipping any single body byte must fail *at the CRC guard* — not
        // parse garbage, not succeed with silently wrong tensor values
        let dir = std::env::temp_dir().join("bs_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bsck");
        Checkpoint::new(vec![("w".into(), Tensor::full(&[4], 1.0))])
            .save(&path)
            .unwrap();
        let clean = std::fs::read(&path).unwrap();
        for &pos in &[4usize, clean.len() / 2, clean.len() - 5] {
            let mut bytes = clean.clone();
            bytes[pos] ^= 0xFF;
            std::fs::write(&path, &bytes).unwrap();
            let err = Checkpoint::load(&path).unwrap_err();
            assert!(
                format!("{err:#}").contains("CRC"),
                "byte {pos}: wanted the CRC error, got: {err:#}"
            );
        }
    }

    #[test]
    fn wire_helpers_round_trip_and_reject_truncation() {
        let mut buf = Vec::new();
        wire::put_u32(&mut buf, 7);
        wire::put_u64(&mut buf, u64::MAX - 3);
        wire::put_str(&mut buf, "fc1.W");
        wire::put_u32s(&mut buf, &[1, 2, 3]);
        wire::put_f32s(&mut buf, &[0.5, -2.0]);
        let mut off = 0usize;
        assert_eq!(wire::get_u32(&buf, &mut off).unwrap(), 7);
        assert_eq!(wire::get_u64(&buf, &mut off).unwrap(), u64::MAX - 3);
        assert_eq!(wire::get_str(&buf, &mut off).unwrap(), "fc1.W");
        assert_eq!(wire::get_u32s(&buf, &mut off, 3).unwrap(), vec![1, 2, 3]);
        assert_eq!(wire::get_f32s(&buf, &mut off, 2).unwrap(), vec![0.5, -2.0]);
        assert_eq!(off, buf.len());
        // any further read is a loud truncation error
        assert!(wire::get_u32(&buf, &mut off).is_err());
        assert!(wire::get_f32s(&buf, &mut off, 1).is_err());
        // a string whose length prefix overruns the buffer is rejected
        let mut bad = Vec::new();
        wire::put_u32(&mut bad, 100);
        let mut boff = 0usize;
        assert!(wire::get_str(&bad, &mut boff).is_err());
    }

    #[test]
    fn crc_known_vector() {
        // CRC32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn state_snapshot_roundtrip_params_and_opt() {
        let dir = std::env::temp_dir().join("bs_ckpt_state");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bsck");
        let mut st = TrainState {
            spec: "t".into(),
            param_names: vec!["fc1.W".into(), "fc1.mask".into()],
            opt_names: vec!["fc1.W.m".into()],
            params: vec![Tensor::full(&[2, 2], 3.0), Tensor::full(&[1, 2], 1.0)],
            opt: vec![Tensor::full(&[2, 2], 0.5)],
        };
        Checkpoint::from_state(&st).save(&path).unwrap();
        // perturb everything, then restore the snapshot
        st.params[0] = Tensor::zeros(&[2, 2]);
        st.params[1] = Tensor::zeros(&[1, 2]);
        st.opt[0] = Tensor::zeros(&[2, 2]);
        let back = Checkpoint::load(&path).unwrap();
        back.restore_state(&mut st).unwrap();
        assert_eq!(st.params[0].data(), &[3.0; 4]);
        assert_eq!(st.params[1].data(), &[1.0; 2]);
        assert_eq!(st.opt[0].data(), &[0.5; 4]);
        // a state slot the snapshot lacks is a spec mismatch, not a skip
        st.param_names.push("fc2.W".into());
        st.params.push(Tensor::zeros(&[1]));
        assert!(back.restore_state(&mut st).is_err());
    }
}
