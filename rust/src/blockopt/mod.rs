//! Block-size optimization — the paper's Eq. 5 integer program.
//!
//!   min_{m1,n1,m2,n2}  2·m1·n1 + m2·n2   s.t.  m1·m2 = m, n1·n2 = n
//!
//! The continuous optimum is m1·n1 = sqrt(mn/2); because the feasible set
//! is the (finite) divisor grid we solve it exactly with branch-and-bound
//! over divisor pairs (with the sqrt bound used for pruning), and also
//! expose the §5 pattern enumeration (the "14 block sizes for a 10×10
//! matrix" counting).

use crate::flops::KpdDims;

/// All positive divisors, ascending.
pub fn divisors(x: usize) -> Vec<usize> {
    assert!(x > 0);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= x {
        if x % d == 0 {
            small.push(d);
            if d != x / d {
                large.push(x / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Eq. 5 objective for r = 1.
pub fn eq5_cost(m1: usize, n1: usize, m2: usize, n2: usize) -> u64 {
    2 * (m1 * n1) as u64 + (m2 * n2) as u64
}

/// Exact minimizer of Eq. 5 via branch-and-bound over the divisor grid.
///
/// Branching: fix m1 (divisor of m); bound: for fixed m1 the inner problem
/// over n1 has cost ≥ 2·sqrt(2·m1·(n·m/m1)) ... we use the simpler valid
/// bound cost ≥ m2·n2 ≥ m/m1 (n2 ≥ 1) plus 2·m1 (n1 ≥ 1) to prune branches
/// that cannot beat the incumbent.
pub fn optimal_block_r1(m: usize, n: usize) -> KpdDims {
    let mut best: Option<KpdDims> = None;
    let mut best_cost = u64::MAX;
    for &m1 in &divisors(m) {
        let m2 = m / m1;
        // lower bound over all n1 for this m1: 2·m1·1 + m2·1
        let lb = 2 * m1 as u64 + m2 as u64;
        if lb >= best_cost {
            continue;
        }
        for &n1 in &divisors(n) {
            let n2 = n / n1;
            let c = eq5_cost(m1, n1, m2, n2);
            if c < best_cost {
                best_cost = c;
                best = Some(KpdDims { m1, n1, m2, n2, r: 1 });
            }
        }
    }
    best.expect("non-empty divisor grid")
}

/// Brute-force reference (used by the property tests to validate pruning).
pub fn optimal_block_r1_brute(m: usize, n: usize) -> u64 {
    let mut best = u64::MAX;
    for &m1 in &divisors(m) {
        for &n1 in &divisors(n) {
            best = best.min(eq5_cost(m1, n1, m / m1, n / n1));
        }
    }
    best
}

/// §5 pattern enumeration: all (m2, n2) block sizes for an m×n matrix,
/// excluding the trivial 1×1 and m×n entries (matches the paper's count of
/// 14 for a 10×10 matrix).
pub fn enumerate_blocks(m: usize, n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for &m2 in &divisors(m) {
        for &n2 in &divisors(n) {
            if (m2, n2) == (1, 1) || (m2, n2) == (m, n) {
                continue;
            }
            out.push((m2, n2));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisor_basics() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(13), vec![1, 13]);
    }

    #[test]
    fn example1_optimum() {
        // Paper Example 1: m=8, n=256 → m1·n1 = sqrt(0.5·2048) = 32,
        // cost = 2·32 + 64 = 128.
        let d = optimal_block_r1(8, 256);
        assert_eq!(d.m1 * d.n1, 32);
        assert_eq!(eq5_cost(d.m1, d.n1, d.m2, d.n2), 128);
    }

    #[test]
    fn bnb_matches_brute_force() {
        for &(m, n) in &[(10, 784), (120, 400), (84, 120), (7, 13), (64, 64), (1, 100)] {
            let d = optimal_block_r1(m, n);
            assert_eq!(
                eq5_cost(d.m1, d.n1, d.m2, d.n2),
                optimal_block_r1_brute(m, n),
                "mismatch at ({m},{n})"
            );
            assert_eq!(d.m1 * d.m2, m);
            assert_eq!(d.n1 * d.n2, n);
        }
    }

    #[test]
    fn paper_pattern_count_10x10() {
        // §5: "if the size of W is 10 by 10, then there are 14 possible
        // block sizes" — divisor grid 4×4 = 16 minus the two trivial ones.
        assert_eq!(enumerate_blocks(10, 10).len(), 14);
    }

    #[test]
    fn optimum_beats_dense() {
        let d = optimal_block_r1(10, 784);
        assert!(eq5_cost(d.m1, d.n1, d.m2, d.n2) < 7840);
    }
}
