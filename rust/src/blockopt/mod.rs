//! Block-size optimization: from the paper's Eq. 5 integer program to a
//! hardware-in-the-loop search subsystem.
//!
//! This module root holds the analytic half — the Eq. 5 objective
//! (generalized to rank r)
//!
//!   min_{m1,n1,m2,n2}  2·r·m1·n1 + r·m2·n2   s.t.  m1·m2 = m, n1·n2 = n
//!
//! solved exactly by branch-and-bound over the divisor grid, plus the §5
//! pattern enumeration (the "14 block sizes for a 10×10 matrix" count).
//! The submodules close the loop against real hardware:
//!
//! * [`cost`]   — a per-block-shape latency model calibrated by timing
//!   the `infer::bsr` kernels, serialized to a versioned JSON artifact;
//! * [`sweep`]  — the search driver: one short joint `pattern_kpd`
//!   training run measures retention/accuracy/occupancy per candidate,
//!   then the cost model prices each and the Pareto front picks the
//!   survivor under a latency budget;
//! * [`pareto`] — deterministic dominance/front extraction shared by the
//!   sweep, the CLI and the `blockopt_sweep` bench.

pub mod cost;
pub mod pareto;
pub mod sweep;

use std::fmt;

use crate::flops::KpdDims;

/// Typed failure of the analytic solvers — a zero dimension or rank is a
/// caller bug worth a real error, not a panic inside a library call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockOptError {
    /// a matrix dimension (or divisor argument) was 0
    ZeroDim,
    /// the KPD rank was 0
    ZeroRank,
}

impl fmt::Display for BlockOptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BlockOptError::ZeroDim => write!(f, "block-size search wants dimensions ≥ 1"),
            BlockOptError::ZeroRank => write!(f, "block-size search wants rank ≥ 1"),
        }
    }
}

impl std::error::Error for BlockOptError {}

/// All positive divisors of `x`, ascending. `x = 0` has no divisors and
/// errors instead of looping or panicking.
pub fn divisors(x: usize) -> Result<Vec<usize>, BlockOptError> {
    if x == 0 {
        return Err(BlockOptError::ZeroDim);
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= x {
        if x % d == 0 {
            small.push(d);
            if d != x / d {
                large.push(x / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    Ok(small)
}

/// Eq. 5 objective at rank r: parameters of the rank-r KPD factorization,
/// 2·r·m1·n1 for the r dense (A, S) factor pairs plus r·m2·n2 for the B
/// factors. r = 1 recovers the paper's 2·m1·n1 + m2·n2.
pub fn eq5_cost_r(m1: usize, n1: usize, m2: usize, n2: usize, r: usize) -> u64 {
    2 * (r * m1 * n1) as u64 + (r * m2 * n2) as u64
}

/// Eq. 5 objective for r = 1 (the paper's stated form).
pub fn eq5_cost(m1: usize, n1: usize, m2: usize, n2: usize) -> u64 {
    eq5_cost_r(m1, n1, m2, n2, 1)
}

/// Exact minimizer of the rank-r Eq. 5 objective via branch-and-bound
/// over the divisor grid.
///
/// Branching: fix m1 (divisor of m); bound: for fixed m1 every n1 has
/// cost ≥ 2·r·m1·1 + r·(m/m1)·1 (n1 ≥ 1, n2 ≥ 1), which prunes branches
/// that cannot beat the incumbent. r scales both terms equally, so the
/// optimal *shape* is rank-invariant — but callers get the true rank-r
/// cost and a `KpdDims` carrying their r.
pub fn optimal_block(m: usize, n: usize, r: usize) -> Result<KpdDims, BlockOptError> {
    if r == 0 {
        return Err(BlockOptError::ZeroRank);
    }
    let n_divs = divisors(n)?;
    let mut best: Option<KpdDims> = None;
    let mut best_cost = u64::MAX;
    for &m1 in &divisors(m)? {
        let m2 = m / m1;
        let lb = 2 * (r * m1) as u64 + (r * m2) as u64;
        if lb >= best_cost {
            continue;
        }
        for &n1 in &n_divs {
            let n2 = n / n1;
            let c = eq5_cost_r(m1, n1, m2, n2, r);
            if c < best_cost {
                best_cost = c;
                best = Some(KpdDims { m1, n1, m2, n2, r });
            }
        }
    }
    Ok(best.expect("non-empty divisor grid"))
}

/// [`optimal_block`] at the paper's r = 1.
pub fn optimal_block_r1(m: usize, n: usize) -> Result<KpdDims, BlockOptError> {
    optimal_block(m, n, 1)
}

/// Brute-force reference (used by the property tests to validate pruning).
pub fn optimal_block_brute(m: usize, n: usize, r: usize) -> Result<u64, BlockOptError> {
    if r == 0 {
        return Err(BlockOptError::ZeroRank);
    }
    let mut best = u64::MAX;
    for &m1 in &divisors(m)? {
        for &n1 in &divisors(n)? {
            best = best.min(eq5_cost_r(m1, n1, m / m1, n / n1, r));
        }
    }
    Ok(best)
}

/// §5 pattern enumeration: all (m2, n2) block sizes for an m×n matrix,
/// excluding the trivial 1×1 and m×n entries (matches the paper's count of
/// 14 for a 10×10 matrix).
pub fn enumerate_blocks(m: usize, n: usize) -> Result<Vec<(usize, usize)>, BlockOptError> {
    let mut out = Vec::new();
    for &m2 in &divisors(m)? {
        for &n2 in &divisors(n)? {
            if (m2, n2) == (1, 1) || (m2, n2) == (m, n) {
                continue;
            }
            out.push((m2, n2));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisor_basics() {
        assert_eq!(divisors(12).unwrap(), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1).unwrap(), vec![1]);
        assert_eq!(divisors(13).unwrap(), vec![1, 13]);
    }

    #[test]
    fn zero_inputs_error_instead_of_panicking() {
        assert_eq!(divisors(0).unwrap_err(), BlockOptError::ZeroDim);
        assert_eq!(optimal_block(0, 5, 1).unwrap_err(), BlockOptError::ZeroDim);
        assert_eq!(optimal_block(5, 0, 1).unwrap_err(), BlockOptError::ZeroDim);
        assert_eq!(optimal_block(5, 5, 0).unwrap_err(), BlockOptError::ZeroRank);
        assert_eq!(optimal_block_brute(5, 5, 0).unwrap_err(), BlockOptError::ZeroRank);
        assert!(enumerate_blocks(0, 10).is_err());
        // the error is a real std error with a readable message
        let msg = format!("{}", BlockOptError::ZeroDim);
        assert!(msg.contains("≥ 1"), "{msg}");
    }

    #[test]
    fn prime_dims_have_only_trivial_factorizations() {
        // prime × prime: the divisor grid is {1, p} × {1, q}
        let d = optimal_block_r1(7, 13).unwrap();
        assert_eq!(
            eq5_cost(d.m1, d.n1, d.m2, d.n2),
            optimal_block_brute(7, 13, 1).unwrap()
        );
        assert_eq!(d.m1 * d.m2, 7);
        assert_eq!(d.n1 * d.n2, 13);
        // 2 prime divisors each → 4 grid points, 2 trivial → 2 patterns
        assert_eq!(enumerate_blocks(7, 13).unwrap().len(), 2);
    }

    #[test]
    fn unit_dims_are_legal() {
        // x = 1: a 1×n (or m×1) matrix still solves — m1 = m2 = 1
        let d = optimal_block_r1(1, 100).unwrap();
        assert_eq!((d.m1, d.m2), (1, 1));
        assert_eq!(d.n1 * d.n2, 100);
        let d = optimal_block_r1(1, 1).unwrap();
        assert_eq!(eq5_cost(d.m1, d.n1, d.m2, d.n2), 3); // 2·1·1 + 1·1
        // 1×1 has exactly one block size and it is the trivial one
        assert!(enumerate_blocks(1, 1).unwrap().is_empty());
    }

    #[test]
    fn example1_optimum() {
        // Paper Example 1: m=8, n=256 → m1·n1 = sqrt(0.5·2048) = 32,
        // cost = 2·32 + 64 = 128.
        let d = optimal_block_r1(8, 256).unwrap();
        assert_eq!(d.m1 * d.n1, 32);
        assert_eq!(eq5_cost(d.m1, d.n1, d.m2, d.n2), 128);
    }

    #[test]
    fn bnb_matches_brute_force() {
        for &(m, n) in &[(10, 784), (120, 400), (84, 120), (7, 13), (64, 64), (1, 100)] {
            for r in [1usize, 2, 4] {
                let d = optimal_block(m, n, r).unwrap();
                assert_eq!(
                    eq5_cost_r(d.m1, d.n1, d.m2, d.n2, r),
                    optimal_block_brute(m, n, r).unwrap(),
                    "mismatch at ({m},{n}) r={r}"
                );
                assert_eq!(d.m1 * d.m2, m);
                assert_eq!(d.n1 * d.n2, n);
                assert_eq!(d.r, r);
            }
        }
    }

    #[test]
    fn rank_scales_cost_but_not_shape() {
        // both Eq. 5 terms scale linearly in r, so the optimal shape is
        // rank-invariant while the optimal cost is exactly r× the r=1 one
        for &(m, n) in &[(8, 256), (10, 784), (84, 120)] {
            let d1 = optimal_block(m, n, 1).unwrap();
            for r in [2usize, 3, 8] {
                let dr = optimal_block(m, n, r).unwrap();
                assert_eq!((dr.m1, dr.n1, dr.m2, dr.n2), (d1.m1, d1.n1, d1.m2, d1.n2));
                assert_eq!(
                    eq5_cost_r(dr.m1, dr.n1, dr.m2, dr.n2, r),
                    r as u64 * eq5_cost(d1.m1, d1.n1, d1.m2, d1.n2)
                );
            }
        }
        // and the r-scaling identity holds pointwise, not just at the opt
        assert_eq!(eq5_cost_r(3, 4, 5, 6, 7), 7 * eq5_cost(3, 4, 5, 6));
    }

    #[test]
    fn paper_pattern_count_10x10() {
        // §5: "if the size of W is 10 by 10, then there are 14 possible
        // block sizes" — divisor grid 4×4 = 16 minus the two trivial ones.
        assert_eq!(enumerate_blocks(10, 10).unwrap().len(), 14);
    }

    #[test]
    fn optimum_beats_dense() {
        let d = optimal_block_r1(10, 784).unwrap();
        assert!(eq5_cost(d.m1, d.n1, d.m2, d.n2) < 7840);
    }
}
