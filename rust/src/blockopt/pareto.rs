//! Pareto dominance / front extraction for the block-size search.
//!
//! Candidates live in a two-objective space: `retention` (the Figure-3
//! ‖S‖₁ survival score — higher is better) against `latency_ms` (the cost
//! model's predicted serving time — lower is better). The front and the
//! recommendation are fully deterministic: ties resolve by latency, then
//! by the smallest candidate index, so results are reproducible under
//! shuffled candidate order and replica counts.

/// One candidate in (retention ↑, latency ↓) objective space. `index`
/// points back into the caller's candidate list and is carried through
/// the front so callers can map recommendations back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub retention: f64,
    pub latency_ms: f64,
    pub index: usize,
}

/// Weak Pareto dominance: `a` dominates `b` iff it is at least as good on
/// both axes and strictly better on at least one.
pub fn dominates(a: &Point, b: &Point) -> bool {
    (a.retention >= b.retention && a.latency_ms < b.latency_ms)
        || (a.retention > b.retention && a.latency_ms <= b.latency_ms)
}

/// The non-dominated subset, sorted by latency ascending (retention is
/// therefore strictly ascending along the front). Non-finite coordinates
/// are excluded up front — a NaN score must not poison the whole sweep.
/// Duplicate (retention, latency) pairs keep the smallest index, so the
/// result is independent of input order.
pub fn pareto_front(points: &[Point]) -> Vec<Point> {
    let mut sorted: Vec<Point> = points
        .iter()
        .filter(|p| p.retention.is_finite() && p.latency_ms.is_finite())
        .copied()
        .collect();
    sorted.sort_by(|a, b| {
        a.latency_ms
            .total_cmp(&b.latency_ms)
            .then(b.retention.total_cmp(&a.retention))
            .then(a.index.cmp(&b.index))
    });
    let mut front: Vec<Point> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.retention > best {
            front.push(p);
            best = p.retention;
        }
    }
    front
}

/// Pick the configuration to serve off the front. Unconstrained: the
/// max-retention point (ties: cheaper, then smaller index). With a
/// budget: the max-retention point whose latency fits; when nothing
/// fits, the cheapest front point — a non-empty front never yields an
/// empty recommendation.
pub fn recommend(front: &[Point], budget_ms: Option<f64>) -> Option<Point> {
    if front.is_empty() {
        return None;
    }
    let better = |a: &Point, b: &Point| -> bool {
        if a.retention != b.retention {
            return a.retention > b.retention;
        }
        if a.latency_ms != b.latency_ms {
            return a.latency_ms < b.latency_ms;
        }
        a.index < b.index
    };
    let mut pick: Option<Point> = None;
    for p in front {
        let within = match budget_ms {
            Some(b) => p.latency_ms <= b,
            None => true,
        };
        if !within {
            continue;
        }
        let take = match &pick {
            None => true,
            Some(cur) => better(p, cur),
        };
        if take {
            pick = Some(*p);
        }
    }
    pick.or_else(|| {
        front
            .iter()
            .copied()
            .min_by(|a, b| a.latency_ms.total_cmp(&b.latency_ms).then(a.index.cmp(&b.index)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testutil::prop_check;

    fn pt(retention: f64, latency_ms: f64, index: usize) -> Point {
        Point { retention, latency_ms, index }
    }

    #[test]
    fn dominance_cases() {
        let a = pt(0.9, 1.0, 0);
        assert!(dominates(&a, &pt(0.9, 2.0, 1))); // equal retention, slower
        assert!(dominates(&a, &pt(0.5, 1.0, 1))); // equal latency, lower retention
        assert!(dominates(&a, &pt(0.5, 2.0, 1))); // worse on both
        assert!(!dominates(&a, &a)); // never self-dominates
        assert!(!dominates(&a, &pt(0.95, 0.5, 1))); // better on both
        assert!(!dominates(&a, &pt(0.95, 2.0, 1))); // trade-off: incomparable
    }

    #[test]
    fn golden_two_candidate_front() {
        // hand-computed mini sweep: candidate 0 retains 0.9 at 2.0 ms,
        // candidate 1 retains 0.4 at 0.5 ms — a pure trade-off, so both
        // are on the front, sorted by latency
        let pts = [pt(0.9, 2.0, 0), pt(0.4, 0.5, 1)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 2);
        assert_eq!(front[0].index, 1);
        assert_eq!(front[1].index, 0);
        // unconstrained → max retention; a 1 ms budget → the cheap one
        assert_eq!(recommend(&front, None).unwrap().index, 0);
        assert_eq!(recommend(&front, Some(1.0)).unwrap().index, 1);
        // a budget below everything still recommends the cheapest point
        assert_eq!(recommend(&front, Some(0.1)).unwrap().index, 1);
    }

    #[test]
    fn dominated_points_dropped() {
        let pts = [pt(0.5, 1.0, 0), pt(0.9, 0.5, 1), pt(0.9, 0.7, 2)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].index, 1);
    }

    #[test]
    fn duplicates_keep_smallest_index() {
        let pts = [pt(0.7, 1.0, 3), pt(0.7, 1.0, 1), pt(0.7, 1.0, 2)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].index, 1);
    }

    #[test]
    fn non_finite_points_excluded() {
        let pts = [pt(f64::NAN, 1.0, 0), pt(0.5, f64::INFINITY, 1), pt(0.2, 1.0, 2)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].index, 2);
        assert!(pareto_front(&[pt(f64::NAN, 1.0, 0)]).is_empty());
        assert!(recommend(&[], None).is_none());
    }

    #[test]
    fn prop_front_has_no_dominated_point() {
        prop_check("pareto non-dominated", 200, |g| {
            let n = g.usize_in(1, 24);
            let pts: Vec<Point> = (0..n)
                .map(|i| pt(g.f32_in(0.0, 1.0) as f64, g.f32_in(0.1, 10.0) as f64, i))
                .collect();
            let front = pareto_front(&pts);
            prop_assert!(!front.is_empty(), "front empty for {n} finite points");
            for f in &front {
                for p in &pts {
                    prop_assert!(!dominates(p, f), "{p:?} dominates front member {f:?}");
                }
            }
            // completeness: every excluded candidate is dominated by some
            // front member (or is a duplicate of one)
            for p in &pts {
                if front.iter().any(|f| f.index == p.index) {
                    continue;
                }
                prop_assert!(
                    front.iter().any(|f| dominates(f, p)
                        || (f.retention == p.retention && f.latency_ms == p.latency_ms)),
                    "excluded {p:?} but no front member dominates it"
                );
            }
            // the front is monotone: latency strictly ascending implies
            // retention strictly ascending
            for w in front.windows(2) {
                prop_assert!(
                    w[0].latency_ms < w[1].latency_ms && w[0].retention < w[1].retention,
                    "front not monotone: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_front_deterministic_under_shuffle() {
        prop_check("pareto shuffle determinism", 150, |g| {
            let n = g.usize_in(1, 16);
            // quantized coordinates so exact duplicates actually occur
            let pts: Vec<Point> = (0..n)
                .map(|i| pt(g.usize_in(0, 5) as f64 / 5.0, g.usize_in(1, 5) as f64, i))
                .collect();
            let mut shuffled = pts.clone();
            for i in (1..shuffled.len()).rev() {
                let j = g.usize_in(0, i);
                shuffled.swap(i, j);
            }
            let a = pareto_front(&pts);
            let b = pareto_front(&shuffled);
            prop_assert!(a == b, "front depends on candidate order:\n{a:?}\n{b:?}");
            prop_assert!(
                recommend(&a, None) == recommend(&b, None)
                    && recommend(&a, Some(3.0)) == recommend(&b, Some(3.0)),
                "recommendation depends on candidate order"
            );
            Ok(())
        });
    }
}
