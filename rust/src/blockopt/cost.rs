//! Hardware cost model for BSR inference, calibrated from measurement.
//!
//! `calibrate()` runs the `infer::bsr` block-GEMM forward (via
//! [`crate::infer::bsr::time_layer`]) across a grid of block shapes ×
//! occupancies on synthetic weights, then fits, per shape, an affine
//! model of p50 latency in the *occupied work*
//!
//!   t_ns ≈ a_ns · (nb · nnz_blocks · m2 · n2) + c_ns
//!
//! — the slope is the per-MAC cost the kernel achieves at that block
//! shape (small blocks pay more per value: shorter dot products, more
//! index traffic), the intercept is the batch/dispatch overhead. Within
//! one shape the occupied work is proportional to nnz_blocks, so the
//! occupancy grid identifies exactly these two coefficients; anything
//! richer would be collinear.
//!
//! The fitted model serializes to a small versioned JSON artifact
//! (magic `"BSCM"`, same framing discipline as the binary containers:
//! magic + version checked before any field parsing, atomic
//! write-temp-then-rename publish) so a calibration run on the serving
//! hardware can be reused across sweeps without re-measuring.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::native::simd;
use crate::infer::{bsr, synth_block_sparse_weights, BsrLayer};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const COST_MODEL_MAGIC: &str = "BSCM";
/// v2 adds the `dtype` field ("f32" / "int8"): the kernel calibrated is
/// part of the measurement conditions — int8 per-MAC costs differ from
/// f32 and must not silently price an f32 sweep (or vice versa). v1
/// artifacts still load and mean dtype "f32" (the only kernel v1 had).
pub const COST_MODEL_VERSION: usize = 2;

/// Payload dtypes [`calibrate_dtype`] accepts.
pub const COST_MODEL_DTYPES: [&str; 2] = ["f32", "int8"];

/// Calibration macro-layers are (m2·CALIB_GRID) × (n2·CALIB_GRID): the
/// same 16×16 block grid for every shape, so per-shape measurements span
/// the same nnz range and the fits are comparable.
pub const CALIB_GRID: usize = 16;

/// Default occupancy levels: enough spread to identify slope + intercept
/// without turning calibration into a long bench run.
pub const DEFAULT_OCCUPANCIES: [f64; 3] = [1.0, 0.5, 0.25];

/// Default shape grid: the f3a candidate blocks plus a square and a
/// narrow shape, so `recommend` has coverage beyond one aspect ratio.
pub const DEFAULT_SHAPES: [(usize, usize); 6] =
    [(1, 4), (2, 2), (2, 4), (2, 8), (2, 16), (4, 4)];

/// Canonical per-shape key in the artifact: `"{m2}x{n2}"`.
pub fn shape_key(m2: usize, n2: usize) -> String {
    format!("{m2}x{n2}")
}

/// One measured (occupancy, latency) sample for a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibPoint {
    pub occupancy: f64,
    pub nnz_blocks: usize,
    /// occupied MAC volume of the timed forward: nb · nnz · m2 · n2
    pub work: u64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters: usize,
}

/// Fitted affine latency model for one block shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ShapeModel {
    pub m2: usize,
    pub n2: usize,
    /// ns per occupied MAC
    pub a_ns: f64,
    /// fixed per-call overhead, ns
    pub c_ns: f64,
    pub points: Vec<CalibPoint>,
}

/// The full calibrated model: per-shape fits plus the conditions they
/// were measured under (SIMD kind, payload dtype, grid, batch), so a
/// prediction made from a stale or foreign artifact is at least
/// attributable.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// SIMD kind active during calibration (`scalar`/`avx2`/`neon`)
    pub simd: String,
    /// payload dtype the timed kernel ran on (`f32`/`int8`)
    pub dtype: String,
    pub grid: usize,
    /// batch size the calibration forwards ran at
    pub batch: usize,
    pub entries: BTreeMap<String, ShapeModel>,
}

/// Least squares for `t ≈ a·work + c` over the occupancy levels, with
/// both coefficients clamped non-negative (a negative slope or intercept
/// is measurement noise, and would let `predict` report sparser = slower
/// or negative latency). Degenerate samples — a single occupancy level,
/// or noise driving a coefficient negative — fall back to the
/// through-origin fit `a = Σw·t / Σw²`.
fn fit(points: &[CalibPoint]) -> (f64, f64) {
    let n = points.len() as f64;
    let sw: f64 = points.iter().map(|p| p.work as f64).sum();
    let st: f64 = points.iter().map(|p| p.p50_ns).sum();
    let sww: f64 = points.iter().map(|p| (p.work as f64) * (p.work as f64)).sum();
    let swt: f64 = points.iter().map(|p| (p.work as f64) * p.p50_ns).sum();
    let denom = n * sww - sw * sw;
    if denom > 1e-9 * n * sww.max(1.0) {
        let a = (n * swt - sw * st) / denom;
        let c = (st - a * sw) / n;
        if a >= 0.0 && c >= 0.0 && a.is_finite() && c.is_finite() {
            return (a, c);
        }
    }
    let a = if sww > 0.0 { (swt / sww).max(0.0) } else { 0.0 };
    (a, 0.0)
}

/// Measure and fit every shape in `shapes` at every occupancy in
/// `occupancies`, batch `nb`, on the f32 kernel. Duplicate shapes are
/// measured once. Weights and inputs are seeded per shape, so calibration
/// is reproducible on a given host.
pub fn calibrate(shapes: &[(usize, usize)], occupancies: &[f64], nb: usize) -> Result<CostModel> {
    calibrate_dtype(shapes, occupancies, nb, "f32")
}

/// [`calibrate`] with an explicit payload dtype: `"f32"` times the
/// `bsr` forward, `"int8"` quantizes each synthetic layer and times the
/// W8A32 forward — the two kernels have genuinely different per-MAC
/// costs, so a sweep pricing int8 serving needs its own fits.
pub fn calibrate_dtype(
    shapes: &[(usize, usize)],
    occupancies: &[f64],
    nb: usize,
    dtype: &str,
) -> Result<CostModel> {
    if !COST_MODEL_DTYPES.contains(&dtype) {
        bail!("unsupported calibration dtype '{dtype}' (have: {COST_MODEL_DTYPES:?})");
    }
    if shapes.is_empty() {
        bail!("calibration wants at least one block shape");
    }
    if occupancies.is_empty() {
        bail!("calibration wants at least one occupancy level");
    }
    if nb == 0 {
        bail!("calibration batch must be ≥ 1");
    }
    let mut entries: BTreeMap<String, ShapeModel> = BTreeMap::new();
    for &(m2, n2) in shapes {
        if m2 == 0 || n2 == 0 {
            bail!("calibration shape {m2}x{n2} has a zero dimension");
        }
        let key = shape_key(m2, n2);
        if entries.contains_key(&key) {
            continue;
        }
        let (m, n) = (m2 * CALIB_GRID, n2 * CALIB_GRID);
        let mut rng = Rng::new(0xB10C0 ^ ((m2 as u64) << 16) ^ n2 as u64);
        let x: Vec<f32> = (0..nb * n).map(|_| rng.normal()).collect();
        let mut points = Vec::with_capacity(occupancies.len());
        for &occ in occupancies {
            if !(0.0..=1.0).contains(&occ) {
                bail!("calibration occupancy {occ} outside [0, 1]");
            }
            let (w, _) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, occ);
            let layer = BsrLayer::from_dense("calib", &w, m, n, m2, n2)?;
            let stats = if dtype == "int8" {
                let qlayer = crate::infer::quant::quantize_layer(&layer);
                crate::infer::quant::time_layer_q8(&x, nb, &qlayer)
                    .with_context(|| format!("calibrating shape {key} (int8)"))?
            } else {
                bsr::time_layer(&x, nb, &layer)
                    .with_context(|| format!("calibrating shape {key}"))?
            };
            points.push(CalibPoint {
                occupancy: occ,
                nnz_blocks: layer.nnz_blocks(),
                work: (nb * layer.nnz_blocks() * m2 * n2) as u64,
                p50_ns: stats.p50_ns,
                p95_ns: stats.p95_ns,
                iters: stats.iters,
            });
        }
        let (a_ns, c_ns) = fit(&points);
        entries.insert(key, ShapeModel { m2, n2, a_ns, c_ns, points });
    }
    Ok(CostModel {
        simd: simd::active().label().to_string(),
        dtype: dtype.to_string(),
        grid: CALIB_GRID,
        batch: nb,
        entries,
    })
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing numeric field '{key}'"))
}

impl CostModel {
    /// The fit for an exact shape, else the nearest calibrated shape by
    /// block area — a sweep over spec-declared blocks must not require
    /// every one to have been calibrated. BTreeMap iteration order makes
    /// the nearest-area tie-break deterministic.
    pub fn entry_for(&self, m2: usize, n2: usize) -> Result<&ShapeModel> {
        if let Some(e) = self.entries.get(&shape_key(m2, n2)) {
            return Ok(e);
        }
        let target = (m2 * n2) as i64;
        self.entries
            .values()
            .min_by_key(|e| ((e.m2 * e.n2) as i64 - target).abs())
            .ok_or_else(|| anyhow!("cost model has no calibrated shapes"))
    }

    /// Predicted forward latency (ns) of one (m×n) slot at block
    /// (m2×n2), batch `nb`, with `occupancy` of its blocks live — the
    /// same nnz rounding convention as `synth_block_sparse_weights`, so
    /// predictions line up with what the bench actually builds.
    pub fn predict_ns(
        &self,
        m: usize,
        n: usize,
        m2: usize,
        n2: usize,
        nb: usize,
        occupancy: f64,
    ) -> Result<f64> {
        if m == 0 || n == 0 || m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
            bail!("block ({m2},{n2}) does not tile ({m},{n})");
        }
        if nb == 0 {
            bail!("prediction batch must be ≥ 1");
        }
        if !(0.0..=1.0).contains(&occupancy) {
            bail!("occupancy {occupancy} outside [0, 1]");
        }
        let e = self.entry_for(m2, n2)?;
        let total = (m / m2) * (n / n2);
        let nnz = ((occupancy * total as f64).round() as usize).clamp(1, total);
        let work = (nb * nnz * m2 * n2) as f64;
        Ok(e.a_ns * work + e.c_ns)
    }

    pub fn predict_ms(
        &self,
        m: usize,
        n: usize,
        m2: usize,
        n2: usize,
        nb: usize,
        occupancy: f64,
    ) -> Result<f64> {
        self.predict_ns(m, n, m2, n2, nb, occupancy).map(|ns| ns / 1e6)
    }

    pub fn to_json(&self) -> Json {
        let mut entries = BTreeMap::new();
        for (k, e) in &self.entries {
            let mut pts = Vec::with_capacity(e.points.len());
            for p in &e.points {
                let mut o = BTreeMap::new();
                o.insert("occupancy".into(), Json::num_or_null(p.occupancy));
                o.insert("nnz_blocks".into(), Json::Num(p.nnz_blocks as f64));
                o.insert("work".into(), Json::Num(p.work as f64));
                o.insert("p50_ns".into(), Json::num_or_null(p.p50_ns));
                o.insert("p95_ns".into(), Json::num_or_null(p.p95_ns));
                o.insert("iters".into(), Json::Num(p.iters as f64));
                pts.push(Json::Obj(o));
            }
            let mut so = BTreeMap::new();
            so.insert("m2".into(), Json::Num(e.m2 as f64));
            so.insert("n2".into(), Json::Num(e.n2 as f64));
            so.insert("a_ns".into(), Json::num_or_null(e.a_ns));
            so.insert("c_ns".into(), Json::num_or_null(e.c_ns));
            so.insert("points".into(), Json::Arr(pts));
            entries.insert(k.clone(), Json::Obj(so));
        }
        let mut root = BTreeMap::new();
        root.insert("magic".into(), Json::Str(COST_MODEL_MAGIC.into()));
        root.insert("version".into(), Json::Num(COST_MODEL_VERSION as f64));
        root.insert("simd".into(), Json::Str(self.simd.clone()));
        root.insert("dtype".into(), Json::Str(self.dtype.clone()));
        root.insert("grid".into(), Json::Num(self.grid as f64));
        root.insert("batch".into(), Json::Num(self.batch as f64));
        root.insert("entries".into(), Json::Obj(entries));
        Json::Obj(root)
    }

    /// Magic and version are checked before any field parsing — the same
    /// guard order as the binary containers, so a foreign or future JSON
    /// fails with "not a cost model", never a confusing field error.
    pub fn from_json(j: &Json) -> Result<Self> {
        let magic = j.req_str("magic")?;
        if magic != COST_MODEL_MAGIC {
            bail!("not a {COST_MODEL_MAGIC} cost model (magic '{magic}')");
        }
        let version = j.req_usize("version")?;
        if version == 0 || version > COST_MODEL_VERSION {
            bail!("unsupported cost model version {version}");
        }
        let simd = j.req_str("simd")?.to_string();
        // v1 predates the dtype field: every v1 fit timed the f32 kernel
        let dtype = if version >= 2 {
            let d = j.req_str("dtype")?.to_string();
            if !COST_MODEL_DTYPES.contains(&d.as_str()) {
                bail!("unsupported cost model dtype '{d}'");
            }
            d
        } else {
            "f32".to_string()
        };
        let grid = j.req_usize("grid")?;
        let batch = j.req_usize("batch")?;
        let raw = j
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing object field 'entries'"))?;
        if raw.is_empty() {
            bail!("cost model has no calibrated shapes");
        }
        let mut entries = BTreeMap::new();
        for (k, e) in raw {
            let m2 = e.req_usize("m2")?;
            let n2 = e.req_usize("n2")?;
            if shape_key(m2, n2) != *k {
                bail!("entry '{k}' declares mismatched shape {m2}x{n2}");
            }
            let a_ns = req_f64(e, "a_ns")?;
            let c_ns = req_f64(e, "c_ns")?;
            if !a_ns.is_finite() || !c_ns.is_finite() || a_ns < 0.0 || c_ns < 0.0 {
                bail!("entry '{k}' has invalid coefficients a={a_ns} c={c_ns}");
            }
            let mut points = Vec::new();
            for p in e.req_arr("points")? {
                points.push(CalibPoint {
                    occupancy: req_f64(p, "occupancy")?,
                    nnz_blocks: p.req_usize("nnz_blocks")?,
                    work: p.req_usize("work")? as u64,
                    p50_ns: req_f64(p, "p50_ns")?,
                    p95_ns: req_f64(p, "p95_ns")?,
                    iters: p.req_usize("iters")?,
                });
            }
            entries.insert(k.clone(), ShapeModel { m2, n2, a_ns, c_ns, points });
        }
        Ok(CostModel { simd, dtype, grid, batch, entries })
    }

    /// Atomic publish: full write + fsync to a dot-prefixed temp sibling,
    /// then rename — the same discipline as `BsrModel::save`, so a reader
    /// re-loading the artifact mid-save never sees a torn file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if self.entries.is_empty() {
            bail!("refusing to save a cost model with no calibrated shapes");
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let file_name = path
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("cost_model.json");
        let tmp = path.with_file_name(format!(".{file_name}.{}.{seq}.tmp", std::process::id()));
        let publish = (|| -> Result<()> {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating cost model temp {tmp:?}"))?;
            f.write_all(self.to_json().to_string_pretty().as_bytes())?;
            f.write_all(b"\n")?;
            f.sync_all()?;
            drop(f);
            std::fs::rename(&tmp, path)
                .with_context(|| format!("publishing cost model {path:?}"))?;
            Ok(())
        })();
        if publish.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        publish
    }

    pub fn load(path: &Path) -> Result<Self> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("opening cost model {path:?}"))?;
        let j = Json::parse(&s).map_err(|e| anyhow!("parsing cost model {path:?}: {e}"))?;
        Self::from_json(&j).with_context(|| format!("loading cost model {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(work: u64, p50_ns: f64) -> CalibPoint {
        CalibPoint { occupancy: 1.0, nnz_blocks: work as usize, work, p50_ns, p95_ns: p50_ns, iters: 10 }
    }

    fn shape(m2: usize, n2: usize, a_ns: f64, c_ns: f64) -> ShapeModel {
        ShapeModel { m2, n2, a_ns, c_ns, points: vec![pt(100, a_ns * 100.0 + c_ns)] }
    }

    fn model(shapes: Vec<ShapeModel>) -> CostModel {
        CostModel {
            simd: "scalar".into(),
            dtype: "f32".into(),
            grid: CALIB_GRID,
            batch: 8,
            entries: shapes.into_iter().map(|s| (shape_key(s.m2, s.n2), s)).collect(),
        }
    }

    #[test]
    fn fit_recovers_exact_linear_coefficients() {
        // samples on t = 3·w + 50 exactly → the fit must return (3, 50)
        let pts: Vec<CalibPoint> = [100u64, 200, 400].iter().map(|&w| pt(w, 3.0 * w as f64 + 50.0)).collect();
        let (a, c) = fit(&pts);
        assert!((a - 3.0).abs() < 1e-9, "a = {a}");
        assert!((c - 50.0).abs() < 1e-6, "c = {c}");
    }

    #[test]
    fn fit_degenerate_falls_back_through_origin() {
        // one occupancy level: slope unidentifiable with an intercept
        let (a, c) = fit(&[pt(100, 250.0)]);
        assert!((a - 2.5).abs() < 1e-9, "a = {a}");
        assert_eq!(c, 0.0);
        // noise implying a negative intercept: clamped fallback, never < 0
        let pts = vec![pt(100, 50.0), pt(200, 250.0)];
        let (a, c) = fit(&pts);
        assert!(a >= 0.0 && c >= 0.0, "a = {a}, c = {c}");
    }

    #[test]
    fn json_round_trip_is_exact() {
        let m = model(vec![shape(2, 4, 1.25, 80.0), shape(2, 16, 0.75, 120.0)]);
        let back = CostModel::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    /// v1 artifacts (no dtype field) still load and mean dtype "f32" —
    /// a calibration run from before the version bump stays usable.
    #[test]
    fn v1_artifacts_load_as_f32() {
        let m = model(vec![shape(2, 4, 1.25, 80.0)]);
        let v1 = m
            .to_json()
            .to_string_pretty()
            .replace("\"version\": 2", "\"version\": 1")
            .replace("\"dtype\": \"f32\",\n", "")
            .replace("\"dtype\": \"f32\",", "");
        assert!(!v1.contains("dtype"), "v1 fixture must not carry the field: {v1}");
        let back = CostModel::from_json(&Json::parse(&v1).unwrap()).unwrap();
        assert_eq!(back.dtype, "f32");
        assert_eq!(back.entries, m.entries);
    }

    #[test]
    fn save_load_round_trip_and_rejection() {
        let m = model(vec![shape(2, 4, 1.25, 80.0)]);
        let dir = std::env::temp_dir().join("bs_cost_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cm.json");
        m.save(&path).unwrap();
        assert_eq!(CostModel::load(&path).unwrap(), m);
        // no temp litter after a successful publish
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "leftover temp files: {stray:?}");
        // wrong magic and future version both fail before field parsing
        let good = m.to_json().to_string_pretty();
        let err = CostModel::from_json(&Json::parse(&good.replace("BSCM", "XXXX")).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("not a BSCM"), "{err:#}");
        let err = CostModel::from_json(
            &Json::parse(&good.replace("\"version\": 2", "\"version\": 3")).unwrap(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // a foreign dtype is rejected, not silently priced as f32
        let err = CostModel::from_json(
            &Json::parse(&good.replace("\"dtype\": \"f32\"", "\"dtype\": \"fp4\"")).unwrap(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("dtype"), "{err:#}");
        // a corrupted entry key is caught by the shape cross-check
        let err = CostModel::from_json(&Json::parse(&good.replace("\"2x4\"", "\"3x4\"")).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("mismatched shape"), "{err:#}");
        std::fs::write(&path, "not json").unwrap();
        assert!(CostModel::load(&path).is_err());
    }

    #[test]
    fn nearest_shape_fallback_by_block_area() {
        let m = model(vec![shape(2, 2, 2.0, 10.0), shape(2, 16, 0.5, 10.0)]);
        // exact hit
        assert_eq!(m.entry_for(2, 2).unwrap().a_ns, 2.0);
        // 2x4 (area 8): nearer to 2x2 (area 4, diff 4) than 2x16 (area 32)
        assert_eq!(m.entry_for(2, 4).unwrap().a_ns, 2.0);
        // 4x8 (area 32): exact area match on the 2x16 entry
        assert_eq!(m.entry_for(4, 8).unwrap().a_ns, 0.5);
        let empty = CostModel {
            simd: "scalar".into(),
            dtype: "f32".into(),
            grid: CALIB_GRID,
            batch: 8,
            entries: BTreeMap::new(),
        };
        assert!(empty.entry_for(2, 2).is_err());
    }

    #[test]
    fn predict_validates_and_scales_with_occupancy() {
        let m = model(vec![shape(2, 4, 2.0, 100.0)]);
        // 8×16 at 2×4 → grid 4×4 = 16 blocks; occupancy 0.5 → 8 live
        // blocks → work = 8·8·2·4 = 512 → 2·512 + 100 = 1124 ns
        let half = m.predict_ns(8, 16, 2, 4, 8, 0.5).unwrap();
        assert!((half - 1124.0).abs() < 1e-9, "{half}");
        let full = m.predict_ns(8, 16, 2, 4, 8, 1.0).unwrap();
        assert!(full > half, "denser must predict slower: {full} vs {half}");
        let ms = m.predict_ms(8, 16, 2, 4, 8, 0.5).unwrap();
        assert!((ms - half / 1e6).abs() < 1e-15, "{ms}");
        // occupancy 0 still predicts ≥ one block of work plus overhead
        assert!(m.predict_ns(8, 16, 2, 4, 8, 0.0).unwrap() > 100.0);
        // validation: non-tiling block, zero batch, bad occupancy
        assert!(m.predict_ns(8, 15, 2, 4, 8, 0.5).is_err());
        assert!(m.predict_ns(8, 16, 3, 4, 8, 0.5).is_err());
        assert!(m.predict_ns(8, 16, 2, 4, 0, 0.5).is_err());
        assert!(m.predict_ns(8, 16, 2, 4, 8, 1.5).is_err());
    }

    #[test]
    fn calibrate_smoke_fits_a_real_shape() {
        // one shape × one occupancy: a single ~300 ms quick_bench
        let m = calibrate(&[(2, 4), (2, 4)], &[0.5], 8).unwrap();
        assert_eq!(m.dtype, "f32");
        assert_eq!(m.entries.len(), 1, "duplicate shapes must be measured once");
        let e = &m.entries[&shape_key(2, 4)];
        assert_eq!((e.m2, e.n2), (2, 4));
        assert!(e.a_ns >= 0.0 && e.c_ns >= 0.0);
        assert_eq!(e.points.len(), 1);
        assert!(e.points[0].p50_ns > 0.0);
        assert!(m.predict_ms(8, 16, 2, 4, 8, 0.5).unwrap() >= 0.0);
        // invalid grids are rejected up front
        assert!(calibrate(&[], &[0.5], 8).is_err());
        assert!(calibrate(&[(2, 4)], &[], 8).is_err());
        assert!(calibrate(&[(2, 4)], &[1.5], 8).is_err());
        assert!(calibrate(&[(0, 4)], &[0.5], 8).is_err());
        assert!(calibrate(&[(2, 4)], &[0.5], 0).is_err());
    }

    #[test]
    fn calibrate_int8_times_the_quantized_kernel() {
        let m = calibrate_dtype(&[(2, 4)], &[0.5], 8, "int8").unwrap();
        assert_eq!(m.dtype, "int8");
        let e = &m.entries[&shape_key(2, 4)];
        assert!(e.points[0].p50_ns > 0.0);
        assert!(m.predict_ns(8, 16, 2, 4, 8, 0.5).unwrap() > 0.0);
        // int8 fits survive the artifact round trip with their dtype
        let back =
            CostModel::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.dtype, "int8");
        assert!(calibrate_dtype(&[(2, 4)], &[0.5], 8, "fp4").is_err());
    }
}
