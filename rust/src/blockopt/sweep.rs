//! Block-size sweep driver: hardware-in-the-loop pattern selection.
//!
//! The Figure-3 reproduction trains K block-size candidates jointly
//! (Eq. 7) and keeps the max-‖S‖₁-retention survivor — a criterion that
//! knows nothing about what each block shape costs to *serve*. This
//! module closes the loop: [`measure_candidates`] runs one short
//! `pattern_kpd` training pass and reads, per candidate, the retention,
//! per-pattern accuracy and the measured S occupancy; [`score`] prices
//! each candidate's slot stack with a calibrated [`CostModel`] and
//! extracts the (retention ↑, predicted latency ↓) Pareto front plus a
//! recommendation under an optional latency budget. `measure` is the
//! only training-cost step — `score` is pure, so one measurement pass
//! can be re-scored against many cost models or budgets.

use anyhow::{anyhow, bail, Result};

use crate::backend::Backend;
use crate::blockopt::cost::CostModel;
use crate::blockopt::pareto::{self, Point};
use crate::config::TrainConfig;
use crate::coordinator::{self, probe, Trainer};
use crate::manifest::SpecEntry;
use crate::sparsity::{self, DEFAULT_EPS_REL};

/// What one training pass measured for one pattern candidate. `m2`/`n2`
/// are the first slot's block (the headline shape); `slots` carries the
/// full per-slot `(slot_m, slot_n, m2, n2)` stack for pricing.
#[derive(Clone, Debug, PartialEq)]
pub struct Measured {
    pub pattern: usize,
    pub m2: usize,
    pub n2: usize,
    pub rank: usize,
    /// ‖S‖₁ retention (final / initial) — the Figure-3 survival score
    pub retention: f64,
    /// per-pattern test accuracy, percent
    pub accuracy: f64,
    /// measured live fraction of the candidate's S entries
    pub occupancy: f64,
    pub slots: Vec<(usize, usize, usize, usize)>,
}

/// A measured candidate plus its modeled serving latency.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    pub pattern: usize,
    pub m2: usize,
    pub n2: usize,
    pub rank: usize,
    pub retention: f64,
    pub accuracy: f64,
    pub occupancy: f64,
    /// predicted forward latency of the full slot stack, ms
    pub pred_latency_ms: f64,
}

/// The sweep verdict: all scored candidates (pattern order), the Pareto
/// front (latency order), and two selections — `survivor` is the pure
/// Figure-3 max-retention pick, `recommended` is the front pick under
/// `budget_ms`. Unconstrained, the two agree whenever the max-retention
/// candidate is on the front (it always is: nothing dominates it on the
/// retention axis).
#[derive(Clone, Debug, PartialEq)]
pub struct SweepOutcome {
    pub candidates: Vec<Candidate>,
    pub front: Vec<Point>,
    /// pattern index picked off the front under the budget
    pub recommended: usize,
    /// pattern index of the max-retention (Figure-3) survivor
    pub survivor: usize,
    pub budget_ms: Option<f64>,
}

/// Per-pattern slot stacks `(slot_m, slot_n, m2, n2)` from the spec's
/// pattern grid — the same parse (and the same malformed-artifact bails)
/// as `probe::pattern_retention`.
pub fn pattern_slot_blocks(spec: &SpecEntry) -> Result<Vec<Vec<(usize, usize, usize, usize)>>> {
    let pats = spec
        .info
        .get("patterns")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| anyhow!("spec {} has no pattern grid info", spec.key))?;
    if pats.is_empty() {
        bail!("spec {} declares an empty pattern grid", spec.key);
    }
    let mut out = Vec::with_capacity(pats.len());
    for (p, pat) in pats.iter().enumerate() {
        let mut slots = Vec::with_capacity(spec.slots.len());
        for slot in &spec.slots {
            let b = pat
                .get(&slot.name)
                .and_then(|j| j.as_arr())
                .ok_or_else(|| {
                    anyhow!("pattern {p} of spec {} lacks slot '{}'", spec.key, slot.name)
                })?;
            let (m2, n2) = match (b.first().and_then(|v| v.as_usize()),
                                  b.get(1).and_then(|v| v.as_usize())) {
                (Some(m2), Some(n2)) if m2 > 0 && n2 > 0 => (m2, n2),
                _ => bail!(
                    "pattern {p} of spec {}: malformed block entry for slot '{}'",
                    spec.key,
                    slot.name
                ),
            };
            if slot.m % m2 != 0 || slot.n % n2 != 0 {
                bail!(
                    "pattern {p} of spec {}: block ({m2},{n2}) does not tile \
                     slot '{}' ({}x{})",
                    spec.key,
                    slot.name,
                    slot.m,
                    slot.n
                );
            }
            slots.push((slot.m, slot.n, m2, n2));
        }
        if slots.is_empty() {
            bail!("spec {} has no slots", spec.key);
        }
        out.push(slots);
    }
    Ok(out)
}

/// The unique block shapes a spec's pattern grid uses, in first-seen
/// order — what a calibration pass should measure before sweeping it.
pub fn candidate_shapes(spec: &SpecEntry) -> Result<Vec<(usize, usize)>> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    for slots in pattern_slot_blocks(spec)? {
        for (_, _, m2, n2) in slots {
            if !out.contains(&(m2, n2)) {
                out.push((m2, n2));
            }
        }
    }
    Ok(out)
}

/// One short joint training run on `cfg` (first seed only — a sweep
/// probe, not a paper table), then per-candidate retention, accuracy and
/// measured S occupancy. The expensive half of [`sweep`].
pub fn measure_candidates(be: &dyn Backend, cfg: &TrainConfig) -> Result<Vec<Measured>> {
    let spec = be.spec(&cfg.spec)?.clone();
    let k = spec
        .num_patterns()
        .ok_or_else(|| anyhow!("spec '{}' is not a pattern-selection spec", spec.key))?;
    let grids = pattern_slot_blocks(&spec)?;
    if grids.len() != k {
        bail!("spec '{}': {} pattern entries but num_patterns = {k}", spec.key, grids.len());
    }
    let (train, test) =
        coordinator::dataset_for(&spec, cfg.data_seed, cfg.train_examples, cfg.test_examples)?;
    let seed = cfg.seeds.first().copied().unwrap_or(0);
    let outcome = Trainer::new(be, cfg).run(seed, &train, &test)?;
    let retention = probe::pattern_retention_measured(&spec, &outcome.state, &outcome.history)?;
    let rank = spec.rank().unwrap_or(1);
    let mut out = Vec::with_capacity(k);
    for (p, slots) in grids.into_iter().enumerate() {
        let mut parts: Vec<(f64, usize)> = Vec::with_capacity(spec.slots.len());
        for slot in &spec.slots {
            let s = outcome.state.param_tensor(&format!("p{p}.{}.S", slot.name))?;
            parts.push((sparsity::element_sparsity(&s, DEFAULT_EPS_REL), s.len()));
        }
        let occupancy = (1.0 - sparsity::aggregate(&parts)).clamp(0.0, 1.0);
        let accuracy = outcome.pattern_accs.get(p).copied().unwrap_or(outcome.test_acc);
        let (m2, n2) = (slots[0].2, slots[0].3);
        out.push(Measured {
            pattern: p,
            m2,
            n2,
            rank,
            retention: retention[p],
            accuracy,
            occupancy,
            slots,
        });
    }
    Ok(out)
}

/// Price every measured candidate with the cost model at batch `nb` and
/// extract the front + recommendation. Pure — re-scoring against a
/// different model or budget costs nothing. Candidate order in the input
/// does not matter: everything is keyed by pattern index.
pub fn score(
    measured: &[Measured],
    model: &CostModel,
    nb: usize,
    budget_ms: Option<f64>,
) -> Result<SweepOutcome> {
    if measured.is_empty() {
        bail!("sweep has no measured candidates");
    }
    let mut candidates = Vec::with_capacity(measured.len());
    let mut points = Vec::with_capacity(measured.len());
    for m in measured {
        if m.slots.is_empty() {
            bail!("candidate {} has no slots to price", m.pattern);
        }
        let mut lat = 0.0;
        for &(sm, sn, m2, n2) in &m.slots {
            lat += model.predict_ms(sm, sn, m2, n2, nb, m.occupancy)?;
        }
        candidates.push(Candidate {
            pattern: m.pattern,
            m2: m.m2,
            n2: m.n2,
            rank: m.rank,
            retention: m.retention,
            accuracy: m.accuracy,
            occupancy: m.occupancy,
            pred_latency_ms: lat,
        });
        points.push(Point { retention: m.retention, latency_ms: lat, index: m.pattern });
    }
    candidates.sort_by_key(|c| c.pattern);
    if candidates.windows(2).any(|w| w[0].pattern == w[1].pattern) {
        bail!("duplicate pattern index in measured candidates");
    }
    let front = pareto::pareto_front(&points);
    let rec = pareto::recommend(&front, budget_ms)
        .ok_or_else(|| anyhow!("Pareto front is empty — every candidate scored non-finite"))?;
    // the Figure-3 survivor: max retention over candidates in pattern
    // order, through the same shared criterion as the CLI and benches
    let rets: Vec<f64> = candidates.iter().map(|c| c.retention).collect();
    let survivor = candidates[probe::pattern_survivor(&rets)].pattern;
    Ok(SweepOutcome { candidates, front, recommended: rec.index, survivor, budget_ms })
}

/// The full loop: measure once, score once.
pub fn sweep(
    be: &dyn Backend,
    cfg: &TrainConfig,
    model: &CostModel,
    nb: usize,
    budget_ms: Option<f64>,
) -> Result<SweepOutcome> {
    let measured = measure_candidates(be, cfg)?;
    score(&measured, model, nb, budget_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockopt::cost::{shape_key, ShapeModel, CALIB_GRID};
    use std::collections::BTreeMap;

    fn toy_model() -> CostModel {
        // hand-built coefficients, zero intercepts, so every prediction
        // below is hand-computable
        let mk = |m2: usize, n2: usize, a_ns: f64| ShapeModel {
            m2,
            n2,
            a_ns,
            c_ns: 0.0,
            points: vec![],
        };
        let mut entries = BTreeMap::new();
        entries.insert(shape_key(2, 2), mk(2, 2, 2.0));
        entries.insert(shape_key(2, 8), mk(2, 8, 0.5));
        CostModel { simd: "scalar".into(), dtype: "f32".into(), grid: CALIB_GRID, batch: 1, entries }
    }

    fn measured(pattern: usize, m2: usize, n2: usize, retention: f64, occupancy: f64) -> Measured {
        Measured {
            pattern,
            m2,
            n2,
            rank: 1,
            retention,
            accuracy: 90.0,
            occupancy,
            slots: vec![(8, 16, m2, n2)],
        }
    }

    #[test]
    fn golden_two_candidate_score() {
        // slot 8×16, nb = 1.
        // candidate 0: block 2×2, occupancy 1.0 → 32 blocks live,
        //   work = 32·4 = 128 MACs → 2.0·128 = 256 ns
        // candidate 1: block 2×8, occupancy 0.5 → grid 8, nnz 4,
        //   work = 4·16 = 64 MACs → 0.5·64 = 32 ns
        let ms = [measured(0, 2, 2, 0.9, 1.0), measured(1, 2, 8, 0.4, 0.5)];
        let out = score(&ms, &toy_model(), 1, None).unwrap();
        assert!((out.candidates[0].pred_latency_ms - 256.0 / 1e6).abs() < 1e-12);
        assert!((out.candidates[1].pred_latency_ms - 32.0 / 1e6).abs() < 1e-12);
        // pure trade-off: both on the front, latency ascending
        assert_eq!(out.front.len(), 2);
        assert_eq!(out.front[0].index, 1);
        assert_eq!(out.front[1].index, 0);
        // unconstrained, the recommendation IS the Figure-3 survivor
        assert_eq!(out.survivor, 0);
        assert_eq!(out.recommended, 0);
        // a 100 ns budget only fits candidate 1
        let tight = score(&ms, &toy_model(), 1, Some(100.0 / 1e6)).unwrap();
        assert_eq!(tight.recommended, 1);
        assert_eq!(tight.survivor, 0);
    }

    #[test]
    fn score_is_order_independent() {
        let ms = [measured(0, 2, 2, 0.9, 1.0), measured(1, 2, 8, 0.4, 0.5)];
        let swapped = [ms[1].clone(), ms[0].clone()];
        let a = score(&ms, &toy_model(), 1, None).unwrap();
        let b = score(&swapped, &toy_model(), 1, None).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn score_rejects_degenerate_input() {
        assert!(score(&[], &toy_model(), 1, None).is_err());
        let dup = [measured(0, 2, 2, 0.9, 1.0), measured(0, 2, 8, 0.4, 0.5)];
        assert!(score(&dup, &toy_model(), 1, None).is_err());
        let mut bad = measured(0, 2, 2, 0.9, 1.0);
        bad.slots.clear();
        assert!(score(&[bad], &toy_model(), 1, None).is_err());
        // a non-tiling slot block surfaces the predict error
        let mut bad = measured(0, 3, 5, 0.9, 1.0);
        bad.slots = vec![(8, 16, 3, 5)];
        assert!(score(&[bad], &toy_model(), 1, None).is_err());
    }
}
