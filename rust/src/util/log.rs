//! Leveled stderr logger (no `log`/`env_logger` feature-parity needed —
//! just timestamps, levels and a global verbosity gate set by the CLI).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info default

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    eprintln!("[{secs:.3} {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}
