//! Thread-pool substrate (no tokio/rayon in the offline cache).
//!
//! A fixed pool of std threads with a shared injector queue, plus a
//! `scope_for` parallel-for used by the data pipeline (batch assembly) and
//! the bench harness (multi-seed sweeps). Work items are boxed closures;
//! results come back over a channel in submission order.
//!
//! Shutdown has two shapes:
//! * dropping the pool is *graceful*: workers drain every queued job, then
//!   exit (fire-and-forget `submit` work is never lost);
//! * [`ThreadPool::shutdown_now`] is *immediate*: queued-but-unstarted
//!   jobs are dropped, workers exit after their current job, and any
//!   in-progress [`ThreadPool::map`]/[`ThreadPool::scoped_map`] call
//!   observes the dropped jobs as a clean [`PoolShutdown`] error instead
//!   of hanging or panicking with a misleading message.

use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// The queue and the shutdown flag live under ONE mutex: the worker loop
/// takes a single lock per iteration, so there is no lock-order hazard
/// between "is there work" and "are we shutting down" (the old layout
/// took a second `shutdown` mutex while holding the queue lock).
struct Inner {
    queue: std::collections::VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
}

/// A `map`/`scoped_map` call was interrupted by pool shutdown before all
/// of its jobs could run. Implements `std::error::Error`, so `?` converts
/// it into `anyhow::Error` at call sites that just propagate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolShutdown;

impl fmt::Display for PoolShutdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "thread pool shut down before all jobs completed")
    }
}

impl std::error::Error for PoolShutdown {}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queue: std::collections::VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the main thread,
    /// capped at the crate-wide `util::MAX_WORKERS`).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(crate::util::clamp_workers(n.saturating_sub(1)))
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, job: Job) {
        let rejected = {
            let mut inner = self.shared.inner.lock().unwrap();
            if inner.shutdown {
                // workers are gone (or leaving): queueing would strand the
                // job forever — drop it outside the lock instead
                Some(job)
            } else {
                inner.queue.push_back(job);
                None
            }
        };
        match rejected {
            Some(job) => {
                crate::warn_!("job submitted after pool shutdown was dropped");
                drop(job); // drops its result sender → waiters see disconnect
            }
            None => self.shared.cv.notify_one(),
        }
    }

    /// Immediate shutdown: drop every queued-but-unstarted job and tell
    /// workers to exit after their current job. In-progress `map` /
    /// `scoped_map` calls get a clean [`PoolShutdown`] error for the
    /// dropped jobs. Idempotent; `Drop` still joins the workers.
    pub fn shutdown_now(&self) {
        let dropped: Vec<Job> = {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.shutdown = true;
            inner.queue.drain(..).collect()
        };
        self.shared.cv.notify_all();
        // job closures (and the result senders they captured) drop outside
        // the lock: their Drop code must not be able to deadlock the pool
        drop(dropped);
    }

    /// Run `f(i)` for i in 0..n on the pool, returning results in order.
    ///
    /// If any job panics, the panic is re-raised *on the caller* with its
    /// original payload once all jobs have drained — the pool's workers
    /// survive (see `worker_loop`), so a panicking closure cannot shrink
    /// the pool for the rest of the process. If the pool is shut down
    /// before every job ran (see [`ThreadPool::shutdown_now`]), the call
    /// returns [`PoolShutdown`] instead of panicking on a missing result.
    pub fn map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>, PoolShutdown>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        type Panic = Box<dyn std::any::Any + Send + 'static>;
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, Result<T, Panic>)>();
        for i in 0..n {
            let f = f.clone();
            let tx = tx.clone();
            self.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<Panic> = None;
        for (i, v) in rx {
            match v {
                Ok(v) => results[i] = Some(v),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
        collect_or_shutdown(results)
    }

    /// Like [`ThreadPool::map`], but the closure — and its results — may
    /// borrow from the caller's stack (the data-parallel trainer runs
    /// `grad_step(&state, &shard)` on the pool this way, with no cloning
    /// and no per-step thread spawns).
    ///
    /// Panics in jobs propagate to the caller exactly like
    /// [`ThreadPool::map`]; pool shutdown mid-call surfaces as
    /// [`PoolShutdown`].
    pub fn scoped_map<'env, T, F>(&self, n: usize, f: F) -> Result<Vec<T>, PoolShutdown>
    where
        T: Send + 'env,
        F: Fn(usize) -> T + Sync + 'env,
    {
        type Panic = Box<dyn std::any::Any + Send + 'static>;
        if n == 0 {
            return Ok(Vec::new());
        }
        let (tx, rx) = mpsc::channel::<(usize, Result<T, Panic>)>();
        {
            let f = &f;
            for i in 0..n {
                let tx = tx.clone();
                let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                    // `tx.send` is the job's completion signal: nothing may
                    // touch `f` (or anything else borrowing 'env) after it —
                    // once the caller has received all n signals it may
                    // return and invalidate those borrows. The only 'env
                    // things alive past the send are the no-op drop of the
                    // `&F` capture and `tx` itself (whose channel state is
                    // Arc-owned and, post-receive, holds no 'env values).
                    let _ = tx.send((i, out));
                });
                // SAFETY: only the lifetime is erased. Every *use* of the
                // 'env borrows happens before the job's send (see above),
                // and this function does not return before the receive
                // loop below has observed all n sends (or, on pool
                // shutdown, the channel's disconnect after unexecuted job
                // closures were dropped), so no 'env borrow is dereferenced
                // after 'env ends.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
                };
                self.submit_boxed(job);
            }
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<Panic> = None;
        let mut pending = n;
        while pending > 0 {
            match rx.recv() {
                Ok((i, Ok(v))) => results[i] = Some(v),
                Ok((_, Err(payload))) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
                // all senders gone: every job is finished or was dropped
                Err(_) => break,
            }
            pending -= 1;
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
        collect_or_shutdown(results)
    }
}

/// All results present → the ordered vector; any hole means unexecuted
/// job closures were dropped by pool shutdown → the typed error (never
/// the old misleading "pool job dropped its result" panic).
fn collect_or_shutdown<T>(results: Vec<Option<T>>) -> Result<Vec<T>, PoolShutdown> {
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        match r {
            Some(v) => out.push(v),
            None => return Err(PoolShutdown),
        }
    }
    Ok(out)
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        // one lock per iteration: work and the shutdown flag live in the
        // same state, so there is no nested-lock window
        let job = {
            let mut inner = sh.inner.lock().unwrap();
            loop {
                if let Some(j) = inner.queue.pop_front() {
                    break Some(j);
                }
                if inner.shutdown {
                    break None;
                }
                inner = sh.cv.wait(inner).unwrap();
            }
        };
        match job {
            Some(j) => {
                // a panicking job must not take the worker down with it —
                // that would silently shrink the pool for the rest of the
                // process. `map` re-raises its own payload on the caller
                // side; for fire-and-forget `submit` jobs this log line is
                // the only trace, so don't swallow the message.
                if let Err(payload) = catch_unwind(AssertUnwindSafe(j)) {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .copied()
                        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
                        .unwrap_or("<non-string panic payload>");
                    crate::warn_!("thread-pool job panicked: {msg}");
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // graceful: flag only — workers drain the remaining queue first
        self.shared.inner.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn map_in_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i).unwrap();
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful join on drop: every queued job still runs
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_thread_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(10, |i| i + 1).unwrap();
        assert_eq!(out[9], 10);
    }

    #[test]
    fn map_surfaces_panic_payload_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map(8, |i| {
                if i == 3 {
                    panic!("job 3 exploded");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
            .unwrap_or("<non-string payload>");
        assert!(msg.contains("job 3 exploded"), "payload lost: {msg}");
        // the worker that ran the panicking job is still alive: a pool of 2
        // threads must still complete more jobs than 1 thread could block on
        let out = pool.map(32, |i| i * 2).unwrap();
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..64).collect(); // stack-owned, not 'static
        let doubled = pool.scoped_map(data.len(), |i| data[i] * 2).unwrap();
        assert_eq!(doubled, data.iter().map(|v| v * 2).collect::<Vec<_>>());
        // results may borrow too
        let refs = pool.scoped_map(4, |i| &data[i]).unwrap();
        assert_eq!(refs, vec![&0, &1, &2, &3]);
    }

    #[test]
    fn scoped_map_surfaces_panics_like_map() {
        let pool = ThreadPool::new(2);
        let data = vec![1u32, 2, 3, 4];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_map(data.len(), |i| {
                if i == 2 {
                    panic!("scoped job 2 exploded");
                }
                data[i]
            })
        }));
        assert!(caught.is_err(), "panic must propagate");
        // pool and borrows both survive
        let out = pool.scoped_map(data.len(), |i| data[i] + 1).unwrap();
        assert_eq!(out, vec![2, 3, 4, 5]);
    }

    #[test]
    fn panicking_submit_does_not_kill_worker() {
        let pool = ThreadPool::new(1);
        pool.submit(|| panic!("fire-and-forget panic"));
        // the sole worker must survive to run this
        let out = pool.map(4, |i| i + 1).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    /// Shutdown racing an in-progress `scoped_map` must surface the typed
    /// [`PoolShutdown`] error — not hang, and not die on the old
    /// misleading "pool job dropped its result" expect.
    #[test]
    fn shutdown_mid_scoped_map_is_a_clean_error() {
        let pool = ThreadPool::new(1);
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let go_rx = Mutex::new(go_rx); // Receiver is Send but not Sync
        let res = thread::scope(|s| {
            let pool = &pool;
            let go_rx = &go_rx;
            let h = s.spawn(move || {
                pool.scoped_map(4, |_| {
                    // the single worker parks in job 0 until the main
                    // thread has shut the pool down; jobs 1..=3 stay queued
                    let _ = go_rx.lock().unwrap().recv();
                    1u32
                })
            });
            // wait for the worker to actually be inside job 0 (queue len 3)
            loop {
                let queued = pool.shared.inner.lock().unwrap().queue.len();
                if queued <= 3 {
                    break;
                }
                thread::yield_now();
            }
            pool.shutdown_now(); // drops the 3 queued job closures
            go_tx.send(()).unwrap(); // release job 0
            h.join().unwrap()
        });
        assert_eq!(res, Err(PoolShutdown));
        assert_eq!(format!("{PoolShutdown}"), "thread pool shut down before all jobs completed");
    }

    /// After `shutdown_now`, new maps fail cleanly instead of hanging on a
    /// queue no worker will ever drain.
    #[test]
    fn map_after_shutdown_errors_instead_of_hanging() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.map(4, |i| i).unwrap(), vec![0, 1, 2, 3]);
        pool.shutdown_now();
        assert_eq!(pool.map(4, |i| i), Err(PoolShutdown));
        let data = vec![1, 2, 3];
        assert_eq!(pool.scoped_map(3, |i| data[i]), Err(PoolShutdown));
        // shutdown_now is idempotent
        pool.shutdown_now();
    }
}
