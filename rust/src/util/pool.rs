//! Thread-pool substrate (no tokio/rayon in the offline cache).
//!
//! A fixed pool of std threads with a shared injector queue, plus a
//! `scope_for` parallel-for used by the data pipeline (batch assembly) and
//! the bench harness (multi-seed sweeps). Work items are boxed closures;
//! results come back over a channel in submission order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    active: AtomicUsize,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            active: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the main thread).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Run `f(i)` for i in 0..n on the pool, returning results in order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let f = f.clone();
            let tx = tx.clone();
            self.submit(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            results[i] = Some(v);
        }
        results.into_iter().map(|v| v.expect("worker panicked")).collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => {
                sh.active.fetch_add(1, Ordering::SeqCst);
                j();
                sh.active.fetch_sub(1, Ordering::SeqCst);
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_in_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join on drop
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_thread_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out[9], 10);
    }
}
