//! Minimal JSON parser + writer substrate (no serde in the offline cache).
//!
//! Parses the AOT manifest (`artifacts/manifest.json`) and writes
//! experiment-result JSON. Full RFC 8259 value grammar, UTF-8 strings with
//! \uXXXX escapes; numbers are kept as f64 (manifest integers fit exactly).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers used all over the manifest loader.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    /// A number field that may legitimately be undefined: finite values
    /// become `Num`, NaN/±∞ become `Null`. RFC 8259 has no NaN literal —
    /// an empty `latency_summary` used to serialize its NaN fields as a
    /// bare `NaN`, producing unparseable BENCH_*.json.
    pub fn num_or_null(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // backstop for writers that bypass num_or_null: a
                // non-finite Num still must not emit an invalid literal
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"specs": [{"key": "t1", "batch": 128, "tags": ["table1"], "f": 0.5}]}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"αβ≥\"").unwrap();
        assert_eq!(j, Json::Str("αβ≥".into()));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::num_or_null(1.5), Json::Num(1.5));
        assert_eq!(Json::num_or_null(f64::NAN), Json::Null);
        assert_eq!(Json::num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(Json::num_or_null(f64::NEG_INFINITY), Json::Null);
        // the writer backstop: even a raw Num(NaN) must stay parseable
        let j = Json::Arr(vec![Json::Num(f64::NAN), Json::Num(2.0)]);
        let s = j.to_string_pretty();
        assert!(!s.contains("NaN"), "bare NaN literal in {s}");
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.as_arr().unwrap()[0], Json::Null);
        assert_eq!(back.as_arr().unwrap()[1], Json::Num(2.0));
    }
}
