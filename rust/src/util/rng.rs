//! Deterministic PRNG substrate (the crate cache has no `rand`).
//!
//! SplitMix64 for seeding, Xoshiro256** for the stream — the standard
//! pairing (Blackman & Vigna). Deterministic across platforms so every
//! table in EXPERIMENTS.md is exactly reproducible from its seed.

/// SplitMix64: used to expand a u64 seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (e.g. per data shard / per seed run).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits → exact uniform on the f32 grid
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; data generation is not on the hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.uniform()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(6);
        let picked = r.choose(50, 20);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(picked.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = Rng::new(9);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
