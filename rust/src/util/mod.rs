//! Shared substrates: PRNG, JSON, thread pool, logging, timing.

pub mod json;
pub mod log;
pub mod pool;
pub mod rng;

/// Hard cap on every worker/thread-count knob in the crate. Shared by the
/// kernel row-threading autodetect (`BS_NATIVE_THREADS`), the serving
/// engine's worker sizing (`BS_SERVE_WORKERS`) and the pool defaults, so
/// the clamps cannot drift apart per subsystem (they used to: the engine
/// capped at 8 while the kernels capped at 16).
pub const MAX_WORKERS: usize = 16;

/// Pull a worker count into the crate-wide 1..=[`MAX_WORKERS`] range.
pub fn clamp_workers(n: usize) -> usize {
    n.clamp(1, MAX_WORKERS)
}

/// Resolve a worker-count environment knob: a parseable value of `var`
/// wins, anything else falls back to `default`; both are clamped to
/// 1..=[`MAX_WORKERS`] so a stray huge value can never spawn that many
/// threads.
pub fn env_workers(var: &str, default: usize) -> usize {
    if let Ok(v) = std::env::var(var) {
        if let Ok(n) = v.trim().parse::<usize>() {
            return clamp_workers(n);
        }
    }
    clamp_workers(default)
}

/// Wall-clock stopwatch used by the coordinator and the bench harness.
#[derive(Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Index of the maximum element (last on ties, 0 for empty input). NaN
/// ranks below every number, so a diverged candidate can never win.
/// Shared by the pattern-selection survivor criterion on both sides of
/// the Backend boundary so their tie-breaks cannot diverge.
pub fn argmax(xs: &[f64]) -> usize {
    let key = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v };
    xs.iter()
        .enumerate()
        .max_by(|a, b| key(*a.1).total_cmp(&key(*b.1)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Mean and (sample) standard deviation — every table reports mean±std
/// over seeds, mirroring the paper's 5-run convention.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Human-readable counts: 7840 -> "7.84K", 2_160_000_000 -> "2.16G".
pub fn human_count(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!(m1, 5.0);
        assert_eq!(s1, 0.0);
    }

    #[test]
    fn worker_clamp_is_shared() {
        assert_eq!(clamp_workers(0), 1);
        assert_eq!(clamp_workers(7), 7);
        assert_eq!(clamp_workers(10_000), MAX_WORKERS);
        // unset / unparseable env values fall back to the clamped default
        assert_eq!(env_workers("BS_TEST_NO_SUCH_VAR", 4), 4);
        assert_eq!(env_workers("BS_TEST_NO_SUCH_VAR", 99), MAX_WORKERS);
    }

    #[test]
    fn human() {
        assert_eq!(human_count(7840.0), "7.84K");
        assert_eq!(human_count(2.16e9), "2.16G");
        assert_eq!(human_count(12.0), "12");
    }
}
