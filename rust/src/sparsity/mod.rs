//! Sparsity measurement — the "Sparsity Rate" column of every table.
//!
//! The paper reports the fraction of (effectively) zero weights of the
//! trained matrices. For our method zeros come from S entries driven to
//! ~0 by the ℓ1 penalty (whole blocks vanish); for group LASSO from block
//! norms driven to ~0; for RigL/pruning from explicit masks. We threshold
//! at `eps` relative to the matrix's RMS, so the measurement is scale-free.

use crate::tensor::Tensor;

/// Element-level sparsity: fraction of entries with |w| < eps_rel · rms(W).
pub fn element_sparsity(w: &Tensor, eps_rel: f32) -> f64 {
    let n = w.len();
    if n == 0 {
        return 0.0;
    }
    let rms =
        (w.data().iter().map(|x| (x * x) as f64).sum::<f64>() / n as f64).sqrt() as f32;
    let thr = eps_rel * rms.max(1e-20);
    let zeros = w.data().iter().filter(|x| x.abs() < thr).count();
    zeros as f64 / n as f64
}

/// Block-level sparsity: fraction of (m2×n2) blocks whose Frobenius norm is
/// below eps_rel · rms-block-norm. This is the rate that matters for the
/// paper's hardware argument (whole blocks skippable).
pub fn block_sparsity(w: &Tensor, m2: usize, n2: usize, eps_rel: f32) -> anyhow::Result<f64> {
    let norms = w.block_fro_norms(m2, n2)?;
    let nb = norms.len();
    let rms = (norms.data().iter().map(|x| (x * x) as f64).sum::<f64>() / nb as f64)
        .sqrt() as f32;
    let thr = eps_rel * rms.max(1e-20);
    let zeros = norms.data().iter().filter(|x| **x < thr).count();
    Ok(zeros as f64 / nb as f64)
}

/// Sparsity of an explicit {0,1} mask (RigL / pruning baselines).
pub fn mask_sparsity(mask: &Tensor) -> f64 {
    let n = mask.len();
    if n == 0 {
        return 0.0;
    }
    let zeros = mask.data().iter().filter(|x| **x == 0.0).count();
    zeros as f64 / n as f64
}

/// Weighted aggregate over layers: Σ zeros / Σ entries.
pub fn aggregate(parts: &[(f64, usize)]) -> f64 {
    let total: usize = parts.iter().map(|(_, n)| n).sum();
    if total == 0 {
        return 0.0;
    }
    parts.iter().map(|(rate, n)| rate * *n as f64).sum::<f64>() / total as f64
}

/// Default relative threshold used by all experiment drivers. Chosen so a
/// block whose S entry was ℓ1-shrunk to < 2% of the typical magnitude
/// counts as pruned — matches how the preliminary code thresholds before
/// reporting.
pub const DEFAULT_EPS_REL: f32 = 0.02;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_sparsity_counts_zeros() {
        let w = Tensor::new(&[2, 4], vec![0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 3.0, 0.0]).unwrap();
        let s = element_sparsity(&w, 0.01);
        assert!((s - 5.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn block_sparsity_whole_blocks() {
        // 4×4 matrix, 2×2 blocks: zero out one of the four blocks
        let mut w = Tensor::full(&[4, 4], 1.0);
        for i in 0..2 {
            for j in 0..2 {
                w.set2(i, j, 0.0);
            }
        }
        let s = block_sparsity(&w, 2, 2, 0.01).unwrap();
        assert!((s - 0.25).abs() < 1e-9);
    }

    #[test]
    fn mask_sparsity_exact() {
        let m = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(mask_sparsity(&m), 0.5);
    }

    #[test]
    fn aggregate_weights_by_size() {
        let agg = aggregate(&[(1.0, 10), (0.0, 30)]);
        assert!((agg - 0.25).abs() < 1e-9);
    }

    #[test]
    fn aggregate_edge_cases() {
        // empty part list and all-zero-sized parts both report 0, not NaN
        assert_eq!(aggregate(&[]), 0.0);
        assert_eq!(aggregate(&[(0.7, 0)]), 0.0);
        // zero-sized parts contribute nothing even next to real ones
        let agg = aggregate(&[(1.0, 5), (0.9, 0), (0.0, 15)]);
        assert!((agg - 0.25).abs() < 1e-9);
        // order cannot matter: Σ zeros / Σ entries is symmetric
        let a = aggregate(&[(0.25, 40), (0.75, 10), (0.5, 50)]);
        let b = aggregate(&[(0.5, 50), (0.25, 40), (0.75, 10)]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn block_sparsity_rejects_non_dividing_blocks() {
        let w = Tensor::full(&[4, 6], 1.0);
        assert!(block_sparsity(&w, 3, 2, 0.01).is_err(), "3 does not tile 4 rows");
        assert!(block_sparsity(&w, 2, 4, 0.01).is_err(), "4 does not tile 6 cols");
        assert!(block_sparsity(&w, 0, 2, 0.01).is_err(), "zero block rows");
        assert!(block_sparsity(&w, 2, 3, 0.01).is_ok());
    }

    #[test]
    fn all_zero_tensor_is_fully_sparse() {
        let w = Tensor::zeros(&[4, 8]);
        // rms is clamped away from 0, so every |0| entry still counts as
        // below threshold: the degenerate matrix reports exactly 1.0
        assert_eq!(element_sparsity(&w, DEFAULT_EPS_REL), 1.0);
        assert_eq!(block_sparsity(&w, 2, 4, DEFAULT_EPS_REL).unwrap(), 1.0);
        assert_eq!(mask_sparsity(&w), 1.0);
    }

    #[test]
    fn scale_free() {
        let w = Tensor::new(&[1, 4], vec![0.0, 5.0, 0.0, 5.0]).unwrap();
        let w_scaled = w.scale(1e-6);
        assert_eq!(element_sparsity(&w, 0.02), element_sparsity(&w_scaled, 0.02));
    }
}
