//! Host tensor substrate: a row-major f32 NDArray with exactly the ops the
//! coordinator needs (reshape, matmul, Kronecker product, block reductions).
//!
//! This is deliberately *not* a general tensor library: it backs sparsity
//! measurement, KPD reconstruction checks, dataset assembly and the
//! property tests. `Tensor`/`HostValue` are also the backend-agnostic
//! state/batch types crossing the `backend::Backend` boundary; the
//! `xla::Literal` conversions only exist under the `pjrt` feature.

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// 2-D accessor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Dense matmul (naive ikj loop; used only in tests/measurement).
    pub fn matmul(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || rhs.shape.len() != 2 || self.shape[1] != rhs.shape[0] {
            bail!("matmul shape mismatch {:?} x {:?}", self.shape, rhs.shape);
        }
        let (m, k, n) = (self.shape[0], self.shape[1], rhs.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * row[j];
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Kronecker product of two matrices (paper Eq. 2 building block).
    pub fn kron(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || rhs.shape.len() != 2 {
            bail!("kron needs 2-D operands");
        }
        let (m1, n1) = (self.shape[0], self.shape[1]);
        let (m2, n2) = (rhs.shape[0], rhs.shape[1]);
        let mut out = vec![0.0f32; m1 * m2 * n1 * n2];
        let (rows, cols) = (m1 * m2, n1 * n2);
        for i1 in 0..m1 {
            for j1 in 0..n1 {
                let a = self.at2(i1, j1);
                if a == 0.0 {
                    continue;
                }
                for i2 in 0..m2 {
                    for j2 in 0..n2 {
                        out[(i1 * m2 + i2) * cols + (j1 * n2 + j2)] = a * rhs.at2(i2, j2);
                    }
                }
            }
        }
        Tensor::new(&[rows, cols], out)
    }

    /// Elementwise product.
    pub fn hadamard(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.shape != rhs.shape {
            bail!("hadamard shape mismatch {:?} vs {:?}", self.shape, rhs.shape);
        }
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a * b).collect();
        Tensor::new(&self.shape, data)
    }

    pub fn add(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.shape != rhs.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, rhs.shape);
        }
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Tensor::new(&self.shape, data)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|x| x * s).collect() }
    }

    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    pub fn max_abs_diff(&self, rhs: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// KPD reconstruction W_r = Σ_i (S ⊙ A_i) ⊗ B_i (paper Eq. 3).
    /// s: (m1,n1); a: (r,m1,n1) flattened as r matrices; b: (r,m2,n2).
    pub fn kpd_reconstruct(s: &Tensor, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        if a.shape.len() != 3 || b.shape.len() != 3 || s.shape.len() != 2 {
            bail!("kpd_reconstruct wants s:2d a:3d b:3d");
        }
        let (r, m1, n1) = (a.shape[0], a.shape[1], a.shape[2]);
        let (rb, m2, n2) = (b.shape[0], b.shape[1], b.shape[2]);
        if rb != r || s.shape != [m1, n1] {
            bail!("kpd_reconstruct rank/shape mismatch");
        }
        let mut acc = Tensor::zeros(&[m1 * m2, n1 * n2]);
        for i in 0..r {
            let ai = Tensor::new(&[m1, n1], a.data[i * m1 * n1..(i + 1) * m1 * n1].to_vec())?;
            let bi = Tensor::new(&[m2, n2], b.data[i * m2 * n2..(i + 1) * m2 * n2].to_vec())?;
            let sa = s.hadamard(&ai)?;
            acc = acc.add(&sa.kron(&bi)?)?;
        }
        Ok(acc)
    }

    /// Per-block Frobenius norms of a 2-D matrix: (m1, n1) grid.
    pub fn block_fro_norms(&self, m2: usize, n2: usize) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("block norms need 2-D input");
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        if m2 == 0 || n2 == 0 || m % m2 != 0 || n % n2 != 0 {
            bail!("block ({m2},{n2}) does not tile ({m},{n})");
        }
        Tensor::new(&[m / m2, n / n2], block_fro_norms_slice(&self.data, m, n, m2, n2))
    }
}

/// Slice-level per-block Frobenius norms of a row-major (m×n) matrix on an
/// (m2×n2) grid, returned row-major (m1·n1). The single implementation
/// behind [`Tensor::block_fro_norms`] and the native backend's
/// gradient-norm / prox paths. Caller guarantees the block tiles the
/// matrix.
pub fn block_fro_norms_slice(w: &[f32], m: usize, n: usize, m2: usize, n2: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), m * n);
    debug_assert!(m % m2 == 0 && n % n2 == 0);
    let n1 = n / n2;
    let mut out = vec![0.0f32; (m / m2) * n1];
    for i in 0..m {
        let row = &w[i * n..(i + 1) * n];
        let orow = &mut out[(i / m2) * n1..(i / m2 + 1) * n1];
        for (j, &v) in row.iter().enumerate() {
            orow[j / n2] += v * v;
        }
    }
    for v in &mut out {
        *v = v.sqrt();
    }
    out
}

// ------------------------------------------------------------ host values

/// Dtypes crossing the backend boundary (mirrors the manifest's dtype
/// strings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => Err(anyhow!("unsupported dtype '{other}'")),
        }
    }
}

/// Host value crossing the backend boundary: f32 tensor or i32/u32 raw
/// data (class ids, token ids, seeds).
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32(Tensor::new(&[], vec![v]).unwrap())
    }

    pub fn scalar_u32(v: u32) -> Self {
        HostValue::U32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32 { shape, .. } => shape,
            HostValue::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostValue::F32(_) => DType::F32,
            HostValue::I32 { .. } => DType::I32,
            HostValue::U32 { .. } => DType::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected i32 value, got {:?}", other.dtype())),
        }
    }

    pub fn u32_data(&self) -> Result<&[u32]> {
        match self {
            HostValue::U32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected u32 value, got {:?}", other.dtype())),
        }
    }
}

// ----------------------------------------------------------- xla bridging

#[cfg(feature = "pjrt")]
impl HostValue {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostValue::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            HostValue::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostValue::U32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let v = lit.to_vec::<f32>()?;
                Ok(HostValue::F32(Tensor::new(&dims, v)?))
            }
            xla::ElementType::S32 => {
                Ok(HostValue::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            xla::ElementType::U32 => {
                Ok(HostValue::U32 { shape: dims, data: lit.to_vec::<u32>()? })
            }
            other => Err(anyhow!("unsupported literal type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn kron_known() {
        // [[1,2]] ⊗ [[0,1],[1,0]] = [[0,1,0,2],[1,0,2,0]]
        let a = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let k = a.kron(&b).unwrap();
        assert_eq!(k.shape(), &[2, 4]);
        assert_eq!(k.data(), &[0.0, 1.0, 0.0, 2.0, 1.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn kron_mixed_product_property() {
        // (A⊗B)(C⊗D) = (AC)⊗(BD) — classic Kronecker identity
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 0.0, 1.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![0.5, 0.0, 1.0, 2.0]).unwrap();
        let c = Tensor::new(&[2, 2], vec![1.0, 1.0, 2.0, 0.0]).unwrap();
        let d = Tensor::new(&[2, 2], vec![2.0, 1.0, 0.0, 1.0]).unwrap();
        let lhs = a.kron(&b).unwrap().matmul(&c.kron(&d).unwrap()).unwrap();
        let rhs = a.matmul(&c).unwrap().kron(&b.matmul(&d).unwrap()).unwrap();
        assert!(lhs.max_abs_diff(&rhs) < 1e-5);
    }

    #[test]
    fn kpd_reconstruct_single_block() {
        // S selects exactly one block: W must equal that block placed there
        let s = Tensor::new(&[2, 2], vec![0.0, 1.0, 0.0, 0.0]).unwrap();
        let a = Tensor::new(&[1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let b = Tensor::new(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::kpd_reconstruct(&s, &a, &b).unwrap();
        assert_eq!(w.shape(), &[4, 4]);
        assert_eq!(w.at2(0, 2), 1.0);
        assert_eq!(w.at2(0, 3), 2.0);
        assert_eq!(w.at2(1, 2), 3.0);
        assert_eq!(w.at2(0, 0), 0.0);
        assert_eq!(w.at2(2, 2), 0.0);
    }

    #[test]
    fn block_fro() {
        let w = Tensor::new(&[2, 4], vec![3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0]).unwrap();
        let norms = w.block_fro_norms(2, 2).unwrap();
        assert_eq!(norms.shape(), &[1, 2]);
        assert!((norms.data()[0] - 5.0).abs() < 1e-6);
        assert!((norms.data()[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reshape_errors() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn host_value_typed_accessors() {
        let f = HostValue::F32(Tensor::zeros(&[2]));
        let i = HostValue::I32 { shape: vec![3], data: vec![1, 2, 3] };
        let u = HostValue::U32 { shape: vec![1], data: vec![9] };
        assert!(f.as_f32().is_ok());
        assert!(f.i32_data().is_err());
        assert_eq!(i.i32_data().unwrap(), &[1, 2, 3]);
        assert_eq!(u.u32_data().unwrap(), &[9]);
        assert_eq!(i.dtype(), DType::I32);
        assert_eq!(HostValue::scalar_u32(5).shape(), &[] as &[usize]);
        assert_eq!(HostValue::scalar_f32(1.5).as_f32().unwrap().data(), &[1.5]);
    }
}
