//! CLI argument parser substrate (no clap in the offline cache).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`
//! with `--key=value` also accepted. Unknown flags error with usage help.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declarative spec for parsing + help text.
pub struct ArgSpec {
    /// (name, takes_value, help)
    pub options: Vec<(&'static str, bool, &'static str)>,
}

impl Args {
    pub fn parse(argv: &[String], spec: &ArgSpec, expect_subcommand: bool) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        if expect_subcommand {
            if argv.is_empty() || argv[0].starts_with('-') {
                bail!("expected a subcommand");
            }
            out.subcommand = Some(argv[0].clone());
            i = 1;
        }
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let known = spec
                    .options
                    .iter()
                    .find(|(n, _, _)| *n == name)
                    .ok_or_else(|| anyhow!("unknown option --{name}"))?;
                if known.1 {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("--{name} needs a value"))?
                        }
                    };
                    out.options.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        bail!("--{name} takes no value");
                    }
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Collect repeated `--set k=v` style overrides.
    pub fn overrides(&self) -> Vec<String> {
        // single-occurrence map suffices here; callers pass --set once per
        // key or use comma separation
        self.opt("set")
            .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
            .unwrap_or_default()
    }
}

pub fn render_usage(prog: &str, sub: &str, spec: &ArgSpec) -> String {
    let mut s = format!("usage: {prog} {sub} [options]\n\noptions:\n");
    for (name, takes, help) in &spec.options {
        let arg = if *takes { format!("--{name} <v>") } else { format!("--{name}") };
        s.push_str(&format!("  {arg:24} {help}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec {
            options: vec![
                ("spec", true, "spec key"),
                ("steps", true, "steps"),
                ("verbose", false, "verbose"),
                ("set", true, "overrides"),
            ],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn basic() {
        let a = Args::parse(
            &sv(&["train", "--spec", "t1", "--steps=5", "--verbose", "extra"]),
            &spec(),
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("spec"), Some("t1"));
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&sv(&["--spec", "x"]), &spec(), true).is_err());
        assert!(Args::parse(&sv(&["t", "--nope"]), &spec(), true).is_err());
        assert!(Args::parse(&sv(&["t", "--spec"]), &spec(), true).is_err());
        assert!(Args::parse(&sv(&["t", "--verbose=1"]), &spec(), true).is_err());
        let a = Args::parse(&sv(&["t", "--steps", "abc"]), &spec(), true).unwrap();
        assert!(a.opt_usize("steps", 0).is_err());
    }

    #[test]
    fn override_list() {
        let a = Args::parse(&sv(&["t", "--set", "a=1,b=2"]), &spec(), true).unwrap();
        assert_eq!(a.overrides(), vec!["a=1", "b=2"]);
    }
}
