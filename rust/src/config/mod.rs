//! Config substrate: a TOML-subset parser + typed experiment config.
//!
//! Supported grammar (everything the repo's configs use):
//!   [section] / [section.sub] headers, key = value pairs, where value is
//!   string "..." | integer | float | bool | array of scalars. Comments
//!   with '#'. No multi-line strings, no inline tables, no dates.
//!
//! `TrainConfig` is the typed view the coordinator consumes; defaults are
//! chosen to match the paper's §6 settings scaled to this CPU testbed.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat map with dotted keys: `[train] lr = 0.1` → "train.lr".
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, val);
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_i64).map(|v| v as usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Override entries from `k=v` CLI pairs (dotted keys).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for ov in overrides {
            let eq = ov
                .find('=')
                .ok_or_else(|| anyhow!("override '{ov}' is not key=value"))?;
            let key = ov[..eq].trim().to_string();
            let val = parse_value(ov[eq + 1..].trim())?;
            self.values.insert(key, val);
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            bail!("unterminated string: {s}");
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array: {s}");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

// ------------------------------------------------------------- typed view

/// Training-run configuration consumed by the coordinator.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub spec: String,
    pub seeds: Vec<u64>,
    pub steps: usize,
    pub eval_every: usize,
    pub lr: f64,
    pub lambda: f64,
    pub lambda2: f64,
    /// λ ramp per `ramp_every` steps (pattern selection / Fig. 3 schedule)
    pub lambda_ramp: f64,
    pub ramp_every: usize,
    pub train_examples: usize,
    pub test_examples: usize,
    /// RigL mask-update cadence and drop fraction
    pub rigl_every: usize,
    pub rigl_alpha: f64,
    pub rigl_alpha_decay: f64,
    /// iterative-pruning rounds and final sparsity target
    pub prune_rounds: usize,
    pub prune_target: f64,
    /// data-parallel gradient replicas: >1 shards every batch across this
    /// many workers with a deterministic reduction (`crate::train`); 1 is
    /// the fused single-replica step
    pub replicas: usize,
    pub data_seed: u64,
    pub out_dir: String,
}

impl TrainConfig {
    pub fn from_config(cfg: &Config, spec: &str) -> Self {
        let seeds = cfg
            .get("run.seeds")
            .and_then(|v| match v {
                Value::Arr(a) => {
                    Some(a.iter().filter_map(|x| x.as_i64().map(|i| i as u64)).collect())
                }
                _ => None,
            })
            .unwrap_or_else(|| vec![0, 1, 2]);
        TrainConfig {
            spec: spec.to_string(),
            seeds,
            steps: cfg.usize_or("train.steps", 800),
            eval_every: cfg.usize_or("train.eval_every", 200),
            lr: cfg.f64_or("train.lr", 0.05),
            lambda: cfg.f64_or("train.lambda", 0.01),
            lambda2: cfg.f64_or("train.lambda2", 1e-4),
            lambda_ramp: cfg.f64_or("train.lambda_ramp", 0.002),
            ramp_every: cfg.usize_or("train.ramp_every", 0),
            train_examples: cfg.usize_or("data.train_examples", 8192),
            test_examples: cfg.usize_or("data.test_examples", 2048),
            rigl_every: cfg.usize_or("rigl.every", 100),
            rigl_alpha: cfg.f64_or("rigl.alpha", 0.3),
            rigl_alpha_decay: cfg.f64_or("rigl.alpha_decay", 0.75),
            prune_rounds: cfg.usize_or("prune.rounds", 4),
            prune_target: cfg.f64_or("prune.target", 0.5),
            replicas: cfg.usize_or("train.replicas", 1).max(1),
            data_seed: cfg.usize_or("data.seed", 42) as u64,
            out_dir: cfg.str_or("run.out_dir", "runs").to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # top comment
            name = "exp"            # trailing comment
            [train]
            lr = 0.05
            steps = 800
            shuffle = true
            [run]
            seeds = [0, 1, 2]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str_or("name", ""), "exp");
        assert_eq!(cfg.f64_or("train.lr", 0.0), 0.05);
        assert_eq!(cfg.usize_or("train.steps", 0), 800);
        assert!(cfg.bool_or("train.shuffle", false));
        match cfg.get("run.seeds").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!("not array"),
        }
    }

    #[test]
    fn overrides() {
        let mut cfg = Config::parse("[train]\nlr = 0.1\n").unwrap();
        cfg.apply_overrides(&["train.lr=0.2".into(), "train.steps=5".into()]).unwrap();
        assert_eq!(cfg.f64_or("train.lr", 0.0), 0.2);
        assert_eq!(cfg.usize_or("train.steps", 0), 5);
        assert!(cfg.apply_overrides(&["nonsense".into()]).is_err());
    }

    #[test]
    fn errors() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("key\n").is_err());
        assert!(Config::parse("k = \"open\n").is_err());
        assert!(Config::parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn hash_inside_string() {
        let cfg = Config::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(cfg.str_or("k", ""), "a#b");
    }

    #[test]
    fn typed_defaults() {
        let cfg = Config::parse("").unwrap();
        let tc = TrainConfig::from_config(&cfg, "t1_kpd_b2x2");
        assert_eq!(tc.seeds, vec![0, 1, 2]);
        assert_eq!(tc.steps, 800);
        assert_eq!(tc.spec, "t1_kpd_b2x2");
        assert_eq!(tc.replicas, 1);
    }

    #[test]
    fn replicas_from_config_clamped_positive() {
        let cfg = Config::parse("[train]\nreplicas = 4\n").unwrap();
        assert_eq!(TrainConfig::from_config(&cfg, "x").replicas, 4);
        let cfg = Config::parse("[train]\nreplicas = 0\n").unwrap();
        assert_eq!(TrainConfig::from_config(&cfg, "x").replicas, 1);
    }
}
