//! Metrics substrate: step logging, loss curves, CSV/JSONL sinks.
//!
//! The coordinator streams a `Record` per step/eval; sinks write CSV (for
//! plotting the Figure-3 series) and JSONL (for EXPERIMENTS.md extraction).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One logged event: step index + named scalar values.
#[derive(Clone, Debug)]
pub struct Record {
    pub step: u64,
    pub values: BTreeMap<String, f64>,
}

impl Record {
    pub fn new(step: u64) -> Self {
        Self { step, values: BTreeMap::new() }
    }

    pub fn with(mut self, key: &str, v: f64) -> Self {
        self.values.insert(key.to_string(), v);
        self
    }
}

/// In-memory history with optional CSV mirroring; the benches read series
/// back out of it to print figure data.
pub struct History {
    pub records: Vec<Record>,
    csv: Option<std::fs::File>,
    csv_columns: Vec<String>,
}

impl History {
    pub fn new() -> Self {
        Self { records: Vec::new(), csv: None, csv_columns: Vec::new() }
    }

    /// Mirror every record to a CSV file with the given columns.
    pub fn with_csv(path: &Path, columns: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(f, "step,{}", columns.join(","))?;
        Ok(Self {
            records: Vec::new(),
            csv: Some(f),
            csv_columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    pub fn push(&mut self, rec: Record) -> Result<()> {
        if let Some(f) = &mut self.csv {
            let mut line = format!("{}", rec.step);
            for c in &self.csv_columns {
                line.push(',');
                if let Some(v) = rec.values.get(c) {
                    line.push_str(&format!("{v}"));
                }
            }
            writeln!(f, "{line}")?;
        }
        self.records.push(rec);
        Ok(())
    }

    /// Extract one named series as (step, value) pairs.
    pub fn series(&self, key: &str) -> Vec<(u64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.values.get(key).map(|v| (r.step, *v)))
            .collect()
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.values.get(key).copied())
    }

    /// Mean of the last `k` values of a series (smoothed loss reporting).
    pub fn tail_mean(&self, key: &str, k: usize) -> Option<f64> {
        let s = self.series(key);
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(k)..];
        Some(tail.iter().map(|(_, v)| v).sum::<f64>() / tail.len() as f64)
    }
}

impl Default for History {
    fn default() -> Self {
        Self::new()
    }
}

/// Append one JSON object per line (experiment results log).
pub struct JsonlWriter {
    file: std::fs::File,
}

impl JsonlWriter {
    pub fn append(path: &Path) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { file })
    }

    pub fn write(&mut self, obj: &Json) -> Result<()> {
        let mut s = obj.to_string_pretty();
        s = s.replace('\n', " ");
        writeln!(self.file, "{s}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_series() {
        let mut h = History::new();
        for i in 0..5 {
            h.push(Record::new(i).with("loss", 10.0 - i as f64)).unwrap();
        }
        let s = h.series("loss");
        assert_eq!(s.len(), 5);
        assert_eq!(s[4], (4, 6.0));
        assert_eq!(h.last("loss"), Some(6.0));
        assert_eq!(h.tail_mean("loss", 2), Some(6.5));
        assert_eq!(h.last("nope"), None);
    }

    #[test]
    fn csv_mirror() {
        let dir = std::env::temp_dir().join("bs_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("h.csv");
        {
            let mut h = History::with_csv(&path, &["a", "b"]).unwrap();
            h.push(Record::new(0).with("a", 1.0)).unwrap();
            h.push(Record::new(1).with("a", 2.0).with("b", 3.0)).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,2,3");
    }
}
