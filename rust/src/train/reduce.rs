//! Deterministic gradient reduction: the fixed-order pairwise tree.
//!
//! Shard gradients are per-example **sums** ([`GradOut`]), so combining
//! two shards is pure addition. The tree combines adjacent pairs level by
//! level — (0,1), (2,3), …, an odd tail passing through — until one
//! accumulator remains. The tree *shape* depends only on the shard count,
//! never on which replica produced a shard or in what order replicas
//! finished, so the reduced gradient is bit-identical for every replica
//! count R ≥ 1. (A naive left fold would work too; the pairwise tree
//! keeps the f32 accumulation error O(log S) instead of O(S) and is the
//! shape an actual multi-node all-reduce would use.)

use anyhow::{bail, Result};

use crate::backend::GradOut;

/// Merge `b` into `a`: elementwise gradient sums plus the summed stats.
fn accumulate(a: &mut GradOut, b: &GradOut) -> Result<()> {
    if a.grad_sum.len() != b.grad_sum.len() {
        bail!(
            "gradient shards disagree on layout: {} vs {} values",
            a.grad_sum.len(),
            b.grad_sum.len()
        );
    }
    for (x, y) in a.grad_sum.iter_mut().zip(&b.grad_sum) {
        *x += y;
    }
    a.ce_sum += b.ce_sum;
    a.correct += b.correct;
    a.examples += b.examples;
    Ok(())
}

/// Reduce shard gradients (in shard-index order) with the fixed-order
/// pairwise tree. The input order **is** the reduction order — callers
/// must pass shards in their plan order, which `ThreadPool::scoped_map`
/// preserves regardless of completion order.
pub fn tree_reduce(mut parts: Vec<GradOut>) -> Result<GradOut> {
    if parts.is_empty() {
        bail!("tree_reduce on zero shards");
    }
    while parts.len() > 1 {
        let mut next = Vec::with_capacity((parts.len() + 1) / 2);
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                accumulate(&mut a, &b)?;
            }
            next.push(a);
        }
        parts = next;
    }
    Ok(parts.pop().expect("nonempty after reduction"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(vals: &[f32], ce: f32, correct: f32, n: usize) -> GradOut {
        GradOut { grad_sum: vals.to_vec(), ce_sum: ce, correct, examples: n }
    }

    #[test]
    fn reduces_sums_and_stats() {
        for count in [1usize, 2, 3, 5, 8] {
            let parts: Vec<GradOut> = (0..count)
                .map(|i| shard(&[i as f32, 1.0], 0.5, 1.0, 4))
                .collect();
            let total = tree_reduce(parts).unwrap();
            let want: f32 = (0..count).map(|i| i as f32).sum();
            assert_eq!(total.grad_sum, vec![want, count as f32], "count {count}");
            assert_eq!(total.examples, 4 * count);
            assert_eq!(total.correct, count as f32);
            assert!((total.ce_sum - 0.5 * count as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn tree_shape_is_pairwise() {
        // pick f32 values whose sum exposes association order: with
        // a = 2^25, b = -2^25, c = 1, d = 1:
        //   pairwise  ((a+b) + (c+d)) = 2
        //   left fold (((a+b)+c)+d)   = 2 as well, but
        //   skewed    (a + (b+(c+d))) = 0 because b+2 rounds to b
        let (a, b, c, d) = (33554432.0f32, -33554432.0, 1.0, 1.0);
        let total =
            tree_reduce(vec![shard(&[a], 0.0, 0.0, 1), shard(&[b], 0.0, 0.0, 1),
                             shard(&[c], 0.0, 0.0, 1), shard(&[d], 0.0, 0.0, 1)])
                .unwrap();
        assert_eq!(total.grad_sum[0], (a + b) + (c + d));
        // the reduction is a pure function of the input order
        let again =
            tree_reduce(vec![shard(&[a], 0.0, 0.0, 1), shard(&[b], 0.0, 0.0, 1),
                             shard(&[c], 0.0, 0.0, 1), shard(&[d], 0.0, 0.0, 1)])
                .unwrap();
        assert_eq!(total.grad_sum, again.grad_sum);
    }

    #[test]
    fn layout_mismatch_and_empty_error() {
        assert!(tree_reduce(vec![]).is_err());
        let bad = vec![shard(&[1.0], 0.0, 0.0, 1), shard(&[1.0, 2.0], 0.0, 0.0, 1)];
        assert!(tree_reduce(bad).is_err());
    }
}
