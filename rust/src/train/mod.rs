//! Data-parallel sharded training: multi-worker gradient replicas with a
//! deterministic reduction.
//!
//! The paper's pitch is *efficient training*; this subsystem opens the
//! scale axis of it. A batch is split into fixed micro-shards
//! ([`crate::data::ShardPlan::SHARD`]-wide, replica-count-independent),
//! every shard's gradient is computed by `Backend::grad_step` on a pool of
//! R replica workers, the shard gradients are combined by the fixed-order
//! pairwise tree in [`reduce`], and one `Backend::apply_update` takes the
//! optimizer step — so a step is
//!
//! ```text
//!   shard₀ … shard_{S-1}  --grad_step-->  g₀ … g_{S-1}   (R workers)
//!   tree_reduce(g₀ … g_{S-1}) / N        --apply_update-->  θ'
//! ```
//!
//! **Determinism contract.** The shard boundaries, the reduction tree and
//! the final normalization depend only on (spec, batch, shard width) —
//! never on R, thread scheduling, or shard completion order — and kernel
//! row-threading never changes accumulation order. A run through this
//! driver is therefore a pure function of (spec, seed, data, hyper):
//! **R workers are bit-identical to 1 worker for any R**, including the
//! optimizer state, the metric stream and the RigL gradient-norm tail.
//! `tests/parallel.rs` pins this end-to-end.
//!
//! Replica workers cap kernel row-threading at host-cores / replicas
//! ([`crate::backend::native::linalg::with_thread_cap`]): the replica axis
//! is the primary parallelism, and unbounded row threads on top would
//! oversubscribe the cores — while a low replica count on a big machine
//! still gets to use the spare cores inside each worker. Backends without
//! a separable gradient path (AOT/PJRT
//! executables fuse gradient and update) report
//! `supports_grad_step == false` and the coordinator falls back to the
//! fused single-replica `train_step`.

pub mod reduce;

use anyhow::{bail, Result};

use crate::backend::native::linalg;
use crate::backend::{Backend, GradOut, TrainState};
use crate::data::{self, Batch};
use crate::tensor::{HostValue, Tensor};
use crate::util::pool::ThreadPool;

/// Data-parallel step driver: R replica workers on a [`ThreadPool`], one
/// optimizer step per batch. Construction fails on backends without a
/// separable gradient path — callers fall back to the fused step.
pub struct DataParallelTrainer<'a> {
    be: &'a dyn Backend,
    pool: ThreadPool,
    replicas: usize,
    shard: usize,
    /// kernel-thread cap inside each replica worker: host cores split
    /// across the replicas (≥ 1), so low replica counts on big machines
    /// still use the hardware without oversubscribing at high counts.
    /// Never affects results — row threading cannot change accumulation
    /// order — only scheduling.
    inner_cap: usize,
}

impl<'a> DataParallelTrainer<'a> {
    pub fn new(be: &'a dyn Backend, spec: &str, replicas: usize) -> Result<Self> {
        if replicas == 0 {
            bail!("data-parallel training wants >= 1 replica");
        }
        if !be.supports_grad_step(spec) {
            bail!(
                "backend '{}' has no separable gradient path for '{spec}' \
                 (AOT/PJRT executables fuse gradient and update into one \
                 program); train with --replicas 1",
                be.name()
            );
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Ok(Self {
            be,
            pool: ThreadPool::new(replicas),
            replicas,
            shard: data::ShardPlan::SHARD,
            inner_cap: (cores / replicas).max(1),
        })
    }

    /// Override the micro-shard width. Part of the run's definition (like
    /// the batch size): any fixed width stays bit-identical across R.
    pub fn with_shard_width(mut self, shard: usize) -> Self {
        assert!(shard > 0, "shard width must be positive");
        self.shard = shard;
        self
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn shard_width(&self) -> usize {
        self.shard
    }

    /// One data-parallel training step on a whole batch: split its rows
    /// into fixed micro-shards and run [`DataParallelTrainer::step_shards`].
    /// Returns the same metrics vector the fused `train_step` returns.
    pub fn step(
        &self,
        state: &mut TrainState,
        x: &HostValue,
        y: &HostValue,
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        let nb = match x.shape() {
            [rows, _] => *rows,
            other => bail!("data-parallel step wants a 2-D x batch, got {other:?}"),
        };
        let shards: Vec<Batch> = data::shard_ranges(nb, self.shard)
            .into_iter()
            .map(|(lo, len)| slice_batch(x, y, lo, len))
            .collect::<Result<_>>()?;
        self.step_shards(state, &shards, hyper)
    }

    /// Like [`DataParallelTrainer::step`] on pre-assembled shard batches
    /// (what the coordinator builds straight from a
    /// [`crate::data::ShardPlan`], skipping the full-batch assembly).
    /// Shards must arrive in plan order — that order is the reduction
    /// order.
    pub fn step_shards(
        &self,
        state: &mut TrainState,
        shards: &[Batch],
        hyper: &[f32],
    ) -> Result<Vec<f32>> {
        if shards.is_empty() {
            bail!("data-parallel step on zero shards");
        }
        let be = self.be;
        let cap = self.inner_cap;
        let snapshot: &TrainState = state;
        // `scoped_map` returns results in shard order no matter which
        // replica finishes first, so the reduction below is deterministic.
        // The pool is owned by this trainer, so the only way to see its
        // typed shutdown error here is a bug — propagate it loudly.
        let outs: Vec<Result<GradOut>> = self.pool.scoped_map(shards.len(), |i| {
            linalg::with_thread_cap(cap, || be.grad_step(snapshot, &shards[i].x, &shards[i].y))
        })?;
        let mut parts = Vec::with_capacity(outs.len());
        for o in outs {
            parts.push(o?);
        }
        let total = reduce::tree_reduce(parts)?;
        if total.examples == 0 {
            bail!("data-parallel step saw zero examples");
        }
        let inv = 1.0 / total.examples as f32;
        // scale the owned reduced buffer in place: no second allocation
        // of the full gradient on the hot loop
        let mut grad = total.grad_sum;
        for v in &mut grad {
            *v *= inv;
        }
        self.be.apply_update(state, grad, total.ce_sum * inv, total.correct * inv, hyper)
    }
}

/// Rows `[lo, lo + len)` of an `(x, y)` batch as an owned shard batch
/// (the `HostValue`-level twin of `data::assemble_batch` on contiguous
/// rows). Feature batches are f32 rows with i32 class ids; transformer
/// token batches are i32 `[rows, seq]` grids on both sides.
fn slice_batch(x: &HostValue, y: &HostValue, lo: usize, len: usize) -> Result<Batch> {
    if let HostValue::I32 { shape, data } = x {
        let seq = match shape.as_slice() {
            [_, seq] => *seq,
            other => bail!("shard slicing wants a 2-D token grid, got {other:?}"),
        };
        let xs = data[lo * seq..(lo + len) * seq].to_vec();
        let ys = match y {
            HostValue::I32 { shape, data } if shape.len() == 2 && shape[1] == seq => {
                data[lo * seq..(lo + len) * seq].to_vec()
            }
            _ => bail!("shard slicing wants i32 targets of shape [rows, {seq}]"),
        };
        return Ok(Batch {
            x: HostValue::I32 { shape: vec![len, seq], data: xs },
            y: HostValue::I32 { shape: vec![len, seq], data: ys },
            size: len,
        });
    }
    let xt = x.as_f32()?;
    let f = match xt.shape() {
        [_, cols] => *cols,
        other => bail!("shard slicing wants a 2-D f32 x batch, got {other:?}"),
    };
    let xs = xt.data()[lo * f..(lo + len) * f].to_vec();
    let ys = match y {
        HostValue::I32 { shape, data } if shape.len() == 1 => data[lo..lo + len].to_vec(),
        _ => bail!("shard slicing wants i32 class-id labels"),
    };
    Ok(Batch {
        x: HostValue::F32(Tensor::new(&[len, f], xs)?),
        y: HostValue::I32 { shape: vec![len], data: ys },
        size: len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, SpecConfig};
    use crate::util::rng::Rng;

    fn batch(nb: usize, n: usize, classes: usize, seed: u64) -> (HostValue, HostValue) {
        let mut rng = Rng::new(seed);
        let x = Tensor::from_fn(&[nb, n], |_| rng.normal());
        let y: Vec<i32> = (0..nb).map(|i| (i % classes) as i32).collect();
        (HostValue::F32(x), HostValue::I32 { shape: vec![nb], data: y })
    }

    #[test]
    fn slice_batch_rows() {
        let (x, y) = batch(10, 4, 3, 1);
        let b = slice_batch(&x, &y, 6, 3).unwrap();
        assert_eq!(b.size, 3);
        assert_eq!(b.x.shape(), &[3, 4]);
        let full = x.as_f32().unwrap();
        assert_eq!(b.x.as_f32().unwrap().data(), &full.data()[24..36]);
        assert_eq!(b.y.i32_data().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn new_rejects_zero_replicas_and_unknown_specs() {
        let be = NativeBackend::with_default_specs();
        assert!(DataParallelTrainer::new(&be, "qs_kpd", 0).is_err());
        assert!(DataParallelTrainer::new(&be, "no_such_spec", 2).is_err());
        assert!(DataParallelTrainer::new(&be, "qs_kpd", 2).is_ok());
    }

    #[test]
    fn step_metrics_match_layout_and_are_replica_invariant() {
        let cfg = SpecConfig::linear("dp_t", "kpd", 24, 6, 2, 4, 2, 16);
        let be = NativeBackend::from_spec(cfg).unwrap();
        let entry = be.spec("dp_t").unwrap().clone();
        let (x, y) = batch(16, 24, 6, 5);
        let run = |replicas: usize| {
            let dp = DataParallelTrainer::new(&be, "dp_t", replicas)
                .unwrap()
                .with_shard_width(5); // 16 = 5 + 5 + 5 + 1: tail shard
            let mut state = be.init_state("dp_t", 3).unwrap();
            let mut metrics = Vec::new();
            for _ in 0..4 {
                metrics = dp.step(&mut state, &x, &y, &[0.01, 0.1]).unwrap();
            }
            (state, metrics)
        };
        let (s1, m1) = run(1);
        let (s3, m3) = run(3);
        assert_eq!(m1.len(), entry.metrics.len());
        assert_eq!(m1, m3, "metrics diverged across replica counts");
        for (n, t) in s1.param_names.iter().zip(&s1.params) {
            assert_eq!(t.data(), s3.param(n).unwrap().data(), "param '{n}' diverged");
        }
        for (t1, t3) in s1.opt.iter().zip(&s3.opt) {
            assert_eq!(t1.data(), t3.data(), "optimizer state diverged");
        }
    }
}
