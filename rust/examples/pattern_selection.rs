//! Pattern selection (paper §5, Figure 3a): find the best block size in
//! ONE round of training instead of one training run per candidate.
//!
//! ```bash
//! cargo run --release --example pattern_selection -- --steps 1200
//! ```
//!
//! Trains the K=4 Table-1 block-size candidates jointly under the Eq. 7
//! objective with the staircase λ ramp, prints the per-pattern Σ‖S^(k)‖₁
//! trajectory, and verifies the surviving pattern is the one that wins an
//! individual accuracy comparison. Runs on the default native backend —
//! no AOT artifacts needed.

use blocksparse::config::{Config, TrainConfig};
use blocksparse::coordinator::{self, probe, Trainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200);

    let be = blocksparse::backend::open_default()?;
    let spec = be.spec("f3a_pattern")?.clone();
    let k = spec.num_patterns().unwrap();
    println!("jointly training {k} block-size candidates (Eq. 7), {steps} steps");
    println!("patterns: (2,2) (2,4) (2,8) (2,16)  [paper Table-1 grid]");

    let mut cfg = TrainConfig::from_config(&Config::default(), "f3a_pattern");
    cfg.steps = steps;
    // paper Eq. 7 schedule (λ1 = λ2 = 0.01, +0.002 per ramp period) for
    // AOT/PJRT backends; the native gauge objective swaps in its own
    // smaller calibration (see backend::native::pattern)
    cfg.lambda = 0.01;
    cfg.lambda2 = 0.01;
    cfg.lambda_ramp = 0.002;
    blocksparse::backend::native::pattern::calibrate_lambda(&mut cfg, &be.name());
    cfg.eval_every = 0;
    let (train, test) = coordinator::dataset_for(&spec, cfg.data_seed, 8192, 2048)?;

    let trainer = Trainer::new(be.as_ref(), &cfg);
    let outcome = trainer.run(0, &train, &test)?;

    println!("\nΣ‖S^(k)‖₁ trajectory (Figure 3a):");
    let series: Vec<Vec<(u64, f64)>> =
        (0..k).map(|p| outcome.history.series(&format!("s_l1_p{p}"))).collect();
    for i in (0..series[0].len()).step_by((steps / 15).max(1)) {
        print!("  step {:>5}:", series[0][i].0);
        for s in &series {
            print!(" {:>8.2}", s[i].1);
        }
        println!();
    }

    let finals = probe::pattern_s_norms(&spec, &outcome.state)?;
    // normalize by each pattern's initial norm (patterns have different S
    // sizes): survival = max retention, matching the paper's normalized read
    let retention =
        probe::pattern_retention_measured(&spec, &outcome.state, &outcome.history)?;
    let survivor = probe::pattern_survivor(&retention);
    let best_acc = blocksparse::util::argmax(&outcome.pattern_accs);
    println!("\nfinal ‖S^(k)‖₁     : {finals:?}");
    println!("per-pattern accuracy: {:?}", outcome.pattern_accs);
    println!("survivor k={survivor}, accuracy-winner k={best_acc} -> {}",
             if survivor == best_acc { "MATCH (paper's claim holds)" }
             else { "mismatch at this scale (raise --steps)" });
    Ok(())
}
