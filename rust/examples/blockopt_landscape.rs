//! Block-size optimization landscape (paper §4 Eq. 5 + §5 enumeration).
//!
//! ```bash
//! cargo run --release --offline --example blockopt_landscape
//! ```
//!
//! For every weight shape in the paper's models, solve the Eq. 5 integer
//! program for the parameter-minimal factorization, enumerate all legal
//! block sizes, and print the param/FLOP landscape — the design-space view
//! a user consults before picking a sparsity pattern.

use blocksparse::blockopt::{enumerate_blocks, eq5_cost, optimal_block_r1};
use blocksparse::flops::{dense_step_flops, kpd_step_flops, KpdDims};
use blocksparse::util::human_count;

fn main() {
    let shapes: &[(&str, usize, usize)] = &[
        ("paper Example-1", 8, 256),
        ("linear fc (MNIST)", 10, 784),
        ("LeNet fc1", 120, 400),
        ("LeNet fc2", 84, 120),
        ("LeNet fc3", 10, 84),
        ("ViT-t qkv", 576, 192),
        ("ViT-t mlp1", 768, 192),
    ];
    let nb = 128u64;
    for (name, m, n) in shapes {
        let opt = optimal_block_r1(*m, *n).expect("shape table has positive dims");
        let blocks = enumerate_blocks(*m, *n).expect("shape table has positive dims");
        println!("\n{name}: W {m}x{n} (dense params {})", human_count((m * n) as f64));
        println!("  Eq.5 optimum: grid {}x{} block {}x{} -> {} params",
                 opt.m1, opt.n1, opt.m2, opt.n2,
                 eq5_cost(opt.m1, opt.n1, opt.m2, opt.n2));
        println!("  legal non-trivial block sizes: {}", blocks.len());
        // show the r=2 cost landscape over a few blocks
        let mut samples: Vec<(usize, usize)> = blocks
            .iter()
            .copied()
            .filter(|(a, b)| [1usize, 2, 4, 8, 16].contains(a) && *b <= 32)
            .take(6)
            .collect();
        samples.dedup();
        for (m2, n2) in samples {
            let d = KpdDims::from_block(*m, *n, m2, n2, 2);
            println!(
                "    block {m2:>2}x{n2:<3} r=2: params {:>8}  step-flops {:>10} ({}x vs dense)",
                d.train_params(),
                human_count(kpd_step_flops(nb, d) as f64),
                (dense_step_flops(nb, *m as u64, *n as u64) / kpd_step_flops(nb, d).max(1))
            );
        }
    }
    println!("\n(the coordinator's `blocksparse blockopt --m M --n N` gives the same answer)");
}
