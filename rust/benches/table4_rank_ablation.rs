//! Regenerates **Table 4**: the rank ablation. Accuracy must rise
//! monotonically (modulo noise) with the decomposition rank while the
//! sparsity rate stays roughly flat; params/FLOPs grow linearly in r.

use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    let mut table = TableWriter::new(
        "Table 4 — impact of decomposition rank (paper: Table 4)",
        &ROW_HEADERS,
    );
    let paper_linear = ["48.40 ± 0.40", "66.79 ± 0.91", "84.58 ± 3.55", "88.19 ± 0.32"];
    let paper_vit = ["36.86 ± 2.41", "59.71 ± 2.63", "62.99 ± 0.73"];
    let paper_swin = ["58.46 ± 0.16", "68.22 ± 0.04", "77.54 ± 0.42"];

    let env_lin = BenchEnv::from_env(600, 2, 8192, 2048);
    let mut accs = Vec::new();
    let mut rows = 0usize;
    for (i, r) in [1usize, 2, 4, 6].iter().enumerate() {
        let Some(res) = driver::run_row_or_skip(be.as_ref(), &env_lin,
                                                &format!("t4_linear_r{r}"))? else {
            continue;
        };
        driver::record_row("table4", &format!("linear r={r}"), &res)?;
        accs.push(res.acc_mean);
        table.row(driver::cells(&format!("linear r={r}"), "kpd", &res,
                                Some(paper_linear[i])));
        rows += 1;
    }
    for (tag, paper, steps) in [("vit_t", &paper_vit, 150usize),
                                ("swin_t", &paper_swin, 100)] {
        let env = BenchEnv::from_env(steps, 1, 4096, 1024);
        for (i, r) in [1usize, 2, 4].iter().enumerate() {
            let Some(res) = driver::run_row_or_skip(be.as_ref(), &env,
                                                    &format!("t4_{tag}_r{r}"))? else {
                continue; // transformer rank specs need the AOT artifacts
            };
            driver::record_row("table4", &format!("{tag} r={r}"), &res)?;
            table.row(driver::cells(&format!("{tag} r={r}"), "kpd", &res,
                                    Some(paper[i])));
            rows += 1;
        }
    }
    table.print();
    // an all-SKIP run prints an empty table that scrolls past silently —
    // the count makes "nothing actually ran" visible in CI logs
    println!("rows emitted: {rows}");
    let monotone = accs.windows(2).filter(|w| w[1] >= w[0] - 1.0).count();
    println!("shape checks:");
    println!("  - linear accuracy rises with rank: {accs:?} ({monotone}/3 steps non-decreasing)");
    println!("  - params grow ~linearly in r (col 5)");
    Ok(())
}
