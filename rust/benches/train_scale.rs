//! Data-parallel training scaling: steps/sec at R ∈ {1, 2, 4, 8} replicas
//! on the coarse-block Table-2 MLP (`t2_kpd_16x8_8x4_4x2`), driven
//! through `train::DataParallelTrainer` with kernel threading pinned to 1
//! inside replica workers — so the replica axis is the only parallelism
//! being measured.
//!
//! Every replica count runs the *same* shard plan and reduction tree, so
//! besides throughput this bench verifies the determinism headline: the
//! final parameters at R = 2/4/8 are compared bitwise against R = 1.
//!
//! `--json <path>` writes BENCH_train.json with per-R steps/sec, speedup
//! and scaling efficiency plus a `gate` object the CI python gate checks
//! (R=4 speedup ≥ 1.6× on ≥4-core machines, monotone steps/sec,
//! bit_identical == true). Scale knob: BS_STEPS (timed steps per R).

use std::collections::BTreeMap;

use blocksparse::backend::Backend;
use blocksparse::bench::json_arg;
use blocksparse::coordinator::dataset_for;
use blocksparse::data::assemble_batch;
use blocksparse::tensor::Tensor;
use blocksparse::train::DataParallelTrainer;
use blocksparse::util::json::Json;
use blocksparse::util::Stopwatch;

const SPEC: &str = "t2_kpd_16x8_8x4_4x2";
const REPLICAS: [usize; 4] = [1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let be = blocksparse::backend::open_default()?;
    let Ok(spec) = be.spec(SPEC) else {
        println!("SKIP train_scale: {SPEC} not available on backend '{}'", be.name());
        return Ok(());
    };
    let spec = spec.clone();
    if !be.supports_grad_step(SPEC) {
        println!("SKIP train_scale: backend '{}' has no separable gradient path", be.name());
        return Ok(());
    }
    let steps: usize =
        std::env::var("BS_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(40);
    let warmup = 3usize;
    let threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // a fixed cycle of batches, shared by every replica count
    let (train, _test) = dataset_for(&spec, 7, spec.batch * 8, spec.batch)?;
    let batches: Vec<_> = (0..4)
        .map(|b| {
            let idx: Vec<usize> = (b * spec.batch..(b + 1) * spec.batch).collect();
            assemble_batch(&train, &idx)
        })
        .collect::<anyhow::Result<_>>()?;
    let hyper: Vec<f32> = spec
        .hyper
        .iter()
        .map(|h| match h.as_str() {
            "lr" => 0.05,
            _ => 0.008,
        })
        .collect();

    println!(
        "train_scale: {SPEC} batch {} on {} host threads, {steps} timed steps/R",
        spec.batch, threads
    );
    let mut rows = BTreeMap::new();
    let mut sps: Vec<f64> = Vec::new();
    let mut golden: Option<Vec<Tensor>> = None;
    let mut bit_identical = true;
    for &r in &REPLICAS {
        let dp = DataParallelTrainer::new(be.as_ref(), SPEC, r)?;
        let mut state = be.init_state(SPEC, 0)?;
        for step in 0..warmup {
            let b = &batches[step % batches.len()];
            dp.step(&mut state, &b.x, &b.y, &hyper)?;
        }
        let sw = Stopwatch::start();
        for step in 0..steps {
            let b = &batches[(warmup + step) % batches.len()];
            dp.step(&mut state, &b.x, &b.y, &hyper)?;
        }
        let wall = sw.elapsed_secs();
        let steps_per_sec = steps as f64 / wall.max(1e-9);
        match &golden {
            None => golden = Some(state.params.clone()),
            Some(g) => {
                let same = g
                    .iter()
                    .zip(&state.params)
                    .all(|(a, b)| a.data() == b.data());
                if !same {
                    bit_identical = false;
                }
            }
        }
        let speedup = steps_per_sec / sps.first().copied().unwrap_or(steps_per_sec);
        println!(
            "  R={r}: {steps_per_sec:7.2} steps/s  speedup {speedup:4.2}x  \
             efficiency {:5.1}%  ({wall:.2}s)",
            100.0 * speedup / r as f64
        );
        let mut row = BTreeMap::new();
        row.insert("steps_per_sec".to_string(), Json::Num(steps_per_sec));
        row.insert("speedup".to_string(), Json::Num(speedup));
        row.insert("efficiency".to_string(), Json::Num(speedup / r as f64));
        row.insert("wall_secs".to_string(), Json::Num(wall));
        rows.insert(format!("r{r}"), Json::Obj(row));
        sps.push(steps_per_sec);
    }
    let speedup_r4 = sps[2] / sps[0];
    // monotone within a 10% measurement-noise band over R = 1, 2, 4 only —
    // R=8 oversubscribes small hosts and its timing is noise (the CI gate
    // uses the same definition, so the artifact and the gate agree)
    let monotone = sps[..3].windows(2).all(|w| w[1] >= w[0] * 0.9);
    println!(
        "R=4 speedup {speedup_r4:.2}x (gate >= 1.6x on >= 4 cores), \
         monotone over R=1,2,4: {monotone}, bit-identical across R: {bit_identical}"
    );

    if let Some(path) = json_arg(&args, "BENCH_train.json") {
        let mut gate = BTreeMap::new();
        gate.insert("speedup_r4".to_string(), Json::Num(speedup_r4));
        gate.insert("monotone".to_string(), Json::Bool(monotone));
        gate.insert("bit_identical".to_string(), Json::Bool(bit_identical));
        let mut root = BTreeMap::new();
        root.insert("spec".to_string(), Json::Str(SPEC.to_string()));
        root.insert("backend".to_string(), Json::Str(be.name()));
        root.insert("batch".to_string(), Json::Num(spec.batch as f64));
        root.insert("steps".to_string(), Json::Num(steps as f64));
        root.insert("threads".to_string(), Json::Num(threads as f64));
        root.insert(
            "simd".to_string(),
            Json::Str(blocksparse::backend::native::simd::dispatched().label().to_string()),
        );
        root.insert("rows".to_string(), Json::Obj(rows));
        root.insert("gate".to_string(), Json::Obj(gate));
        std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}
