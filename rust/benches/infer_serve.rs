//! Inference serving bench — the train→export→serve payoff, measured.
//! Emits `BENCH_infer.json` (default; `--json <path>` overrides).
//!
//! Six panels, all fully native (never SKIP):
//!
//! 1. **kernels** — dense `matmul_nt` vs masked `block_sparse_matmul_nt`
//!    vs packed BSR forward on the Table-2 fc1 shape (304×784, 8×16
//!    blocks) at 50% / 75% / 90% block sparsity, with an in-bench
//!    correctness cross-check. Gate: BSR ≥ 2× the dense path at 75%
//!    block sparsity (the flops model predicts 4×).
//! 2. **serving** — the batched engine on a 784→304→100→10 BSR stack at
//!    75% block sparsity: per-request p50/p95/p99 latency and throughput
//!    across (micro-batch cap, client count) operating points.
//! 3. **overload** — sustained overload at 4× the engine's resident
//!    capacity with a small admission bound: shed rate, accepted-request
//!    percentiles, peak queue depth. Gates: the peak depth never exceeds
//!    the bound, the shed rate is a real number in (0, 1], and the
//!    accepted p99 is finite — bounded admission is what keeps it so.
//! 4. **hotswap** — atomic model swaps under live traffic: swap cost
//!    (one validate + `Arc` swap) and zero dropped requests across the
//!    swaps.
//! 5. **async** — the completion-slot request path: `drive_async` (one
//!    driver thread, a bounded handle window) vs the blocking path at
//!    equal in-flight load, plus a 4×-overload async run whose process
//!    thread count is recorded (the tentpole claim: N in-flight requests
//!    cost N queue slots, not N threads). Gate: async p99 within 1.25×
//!    of blocking p99.
//! 6. **int8** — per-block-row symmetric W8A32 quantization: q8 vs f32
//!    BSR kernel throughput at 75% block sparsity and full-stack logit
//!    MAE. Gate: speedup ≥ 1.5× where SIMD int8 kernels exist (waived on
//!    scalar hosts — recorded, not asserted); the MAE bound always holds.

use std::collections::BTreeMap;

use blocksparse::backend::native::{linalg, simd};
use blocksparse::bench::{json_arg, quick_bench, BenchStats, TableWriter};
use blocksparse::infer::engine::{
    drive_async, drive_synthetic, latency_summary, Engine, EngineOpts,
};
use blocksparse::infer::{bsr, quant, synth_block_sparse_weights, BsrLayer, BsrModel};
use blocksparse::util::json::Json;
use blocksparse::util::rng::Rng;
use blocksparse::util::Stopwatch;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Live thread count of this process (`/proc/self/status` `Threads:`),
/// `None` off Linux — the async panel records it to pin the "N in-flight
/// requests ≠ N threads" claim.
#[cfg(target_os = "linux")]
fn proc_thread_count() -> Option<usize> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    s.lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

#[cfg(not(target_os = "linux"))]
fn proc_thread_count() -> Option<usize> {
    None
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

fn stat_obj(s: &BenchStats) -> Json {
    let mut o = BTreeMap::new();
    o.insert("mean_ms".to_string(), Json::Num(s.mean_ns / 1e6));
    o.insert("p50_ms".to_string(), Json::Num(s.p50_ns / 1e6));
    o.insert("p95_ms".to_string(), Json::Num(s.p95_ns / 1e6));
    o.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(o)
}

/// The Table-2 16x8_8x4_4x2 stack shape as a synthetic BSR model at one
/// occupancy level per layer.
fn serve_model(rng: &mut Rng, occupancy: f64) -> BsrModel {
    let shapes: [(&str, usize, usize, usize, usize); 3] =
        [("fc1", 304, 784, 8, 16), ("fc2", 100, 304, 4, 8), ("fc3", 10, 100, 2, 4)];
    let layers = shapes
        .iter()
        .map(|&(name, m, n, m2, n2)| {
            let (w, _) = synth_block_sparse_weights(rng, m, n, m2, n2, occupancy);
            BsrLayer::from_dense(name, &w, m, n, m2, n2).expect("serve model layer")
        })
        .collect();
    BsrModel {
        spec: "t2_16x8_8x4_4x2(synthetic)".to_string(),
        method: "kpd".to_string(),
        in_dim: 784,
        out_dim: 10,
        layers,
    }
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut rng = Rng::new(0x1F5E);

    // ---- panel 1: kernel speedups across sparsity levels ----------------
    let (nb, m, n, m2, n2) = (128usize, 304usize, 784usize, 8usize, 16usize);
    let x = rand_vec(&mut rng, nb * n);
    let mut kernels = BTreeMap::new();
    let mut gate = BTreeMap::new();
    let mut table = TableWriter::new(
        "BSR inference kernels — 128×(304×784), 8×16 blocks",
        &["sparsity", "dense ms", "block-sparse ms", "BSR ms", "BSR speedup"],
    );
    for sparsity in [0.50f64, 0.75, 0.90] {
        let (w, mask) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, 1.0 - sparsity);
        let layer = BsrLayer::from_dense("fc", &w, m, n, m2, n2)?;
        // correctness cross-check before timing anything
        let dense_z = linalg::matmul_nt(&x, &w, nb, n, m);
        let masked_z = linalg::block_sparse_matmul_nt(&x, &w, &mask, nb, m, n, m2, n2)?;
        let bsr_z = bsr::bsr_forward(&x, nb, &layer)?;
        // tolerance covers f32 re-association over the 784-wide reduction
        assert!(max_diff(&dense_z, &masked_z) < 1e-2, "block-sparse kernel drifted");
        assert!(max_diff(&dense_z, &bsr_z) < 1e-2, "BSR kernel drifted");

        let tag = format!("sp{}", (sparsity * 100.0).round() as u32);
        let dense = quick_bench(&format!("infer.dense.{tag}"), || {
            std::hint::black_box(linalg::matmul_nt(&x, &w, nb, n, m));
        });
        let bsm = quick_bench(&format!("infer.block_sparse.{tag}"), || {
            std::hint::black_box(
                linalg::block_sparse_matmul_nt(&x, &w, &mask, nb, m, n, m2, n2)
                    .expect("block-sparse shapes"),
            );
        });
        let bsr_s = quick_bench(&format!("infer.bsr.{tag}"), || {
            std::hint::black_box(bsr::bsr_forward(&x, nb, &layer).expect("bsr shapes"));
        });
        let speedup = dense.mean_ns / bsr_s.mean_ns;
        println!(
            "BSR speedup at {:.0}% block sparsity: {speedup:.2}x dense \
             (flops model predicts {:.1}x)",
            sparsity * 100.0,
            1.0 / (1.0 - sparsity)
        );
        table.row(vec![
            format!("{:.0}%", sparsity * 100.0),
            format!("{:.3}", dense.mean_ns / 1e6),
            format!("{:.3}", bsm.mean_ns / 1e6),
            format!("{:.3}", bsr_s.mean_ns / 1e6),
            format!("{speedup:.2}x"),
        ]);
        let mut o = BTreeMap::new();
        o.insert("dense".to_string(), stat_obj(&dense));
        o.insert("block_sparse".to_string(), stat_obj(&bsm));
        o.insert("bsr".to_string(), stat_obj(&bsr_s));
        o.insert("bsr_speedup".to_string(), Json::Num(speedup));
        o.insert("occupancy".to_string(), Json::Num(1.0 - sparsity));
        kernels.insert(tag.clone(), Json::Obj(o));
        gate.insert(format!("bsr_speedup_{tag}"), Json::Num(speedup));
    }
    table.print();

    // ---- panel 2: batched serving latency/throughput --------------------
    let model = serve_model(&mut rng, 0.25); // 75% block sparsity
    println!(
        "serving {}: {} stored params, {} FLOPs/example ({:.1}% block sparsity)",
        model.spec,
        model.nnz_params(),
        model.infer_flops_per_example(),
        100.0 * model.block_sparsity()
    );
    let mut serve = BTreeMap::new();
    let mut stable = TableWriter::new(
        "batched BSR serving — 784→304→100→10 @ 75% block sparsity",
        &["max_batch", "clients", "requests", "p50 ms", "p95 ms", "p99 ms", "req/s"],
    );
    for &(max_batch, clients, requests) in &[(1usize, 1usize, 256usize), (8, 4, 512), (32, 16, 1024)]
    {
        let engine = Engine::new(
            model.clone(),
            // the closed-loop panel must never shed: bound >> clients
            EngineOpts { max_batch, workers: 4, queue_depth: 1024 },
        )?;
        let sw = Stopwatch::start();
        let lat_ms = drive_synthetic(&engine, requests, clients, 0xBEE)?;
        let wall = sw.elapsed_secs();
        let summary = latency_summary(&lat_ms);
        let rps = summary.count as f64 / wall.max(1e-9);
        stable.row(vec![
            max_batch.to_string(),
            clients.to_string(),
            summary.count.to_string(),
            format!("{:.3}", summary.p50_ms),
            format!("{:.3}", summary.p95_ms),
            format!("{:.3}", summary.p99_ms),
            format!("{rps:.0}"),
        ]);
        let mut o = BTreeMap::new();
        o.insert("max_batch".to_string(), Json::Num(max_batch as f64));
        o.insert("clients".to_string(), Json::Num(clients as f64));
        o.insert("requests".to_string(), Json::Num(summary.count as f64));
        // num_or_null: an empty sample summarizes to NaN fields, and RFC
        // 8259 JSON has no NaN literal — nulls keep the file parseable
        o.insert("mean_ms".to_string(), Json::num_or_null(summary.mean_ms));
        o.insert("p50_ms".to_string(), Json::num_or_null(summary.p50_ms));
        o.insert("p95_ms".to_string(), Json::num_or_null(summary.p95_ms));
        o.insert("p99_ms".to_string(), Json::num_or_null(summary.p99_ms));
        o.insert("max_ms".to_string(), Json::num_or_null(summary.max_ms));
        o.insert("throughput_rps".to_string(), Json::Num(rps));
        serve.insert(format!("b{max_batch}_c{clients}"), Json::Obj(o));
    }
    stable.print();

    // ---- panel 3: sustained overload with bounded admission -------------
    let (o_depth, o_workers, o_batch) = (8usize, 2usize, 4usize);
    let o_engine = Engine::new(
        model.clone(),
        EngineOpts { max_batch: o_batch, workers: o_workers, queue_depth: o_depth },
    )?;
    // 4× the resident capacity, zero think time: the queue must saturate
    // and the excess must shed — the bug this panel guards against is the
    // old unbounded queue absorbing all of it
    let o_clients = 4 * o_engine.capacity();
    let o_per_client = 32usize;
    let sw = Stopwatch::start();
    let rep = blocksparse::infer::engine::drive_overload(&o_engine, o_per_client, o_clients, 0xD05)?;
    let o_wall = sw.elapsed_secs();
    let o_sum = latency_summary(&rep.accepted_lat_ms);
    assert!(
        rep.peak_depth <= o_depth,
        "admission bound breached: peak depth {} > {}",
        rep.peak_depth,
        o_depth
    );
    assert_eq!(rep.accepted + rep.shed, rep.offered, "requests unaccounted for");
    println!(
        "overload: {o_clients} clients vs capacity {} ({:.1}x offered) — \
         {} offered, {} accepted, {} shed ({:.1}%) in {o_wall:.2}s; \
         accepted p99 {:.3} ms; peak queue depth {}/{o_depth}",
        rep.capacity,
        rep.offered_ratio,
        rep.offered,
        rep.accepted,
        rep.shed,
        100.0 * rep.shed_rate(),
        o_sum.p99_ms,
        rep.peak_depth
    );
    let mut overload = BTreeMap::new();
    overload.insert("queue_depth".to_string(), Json::Num(o_depth as f64));
    overload.insert("workers".to_string(), Json::Num(o_workers as f64));
    overload.insert("max_batch".to_string(), Json::Num(o_batch as f64));
    overload.insert("clients".to_string(), Json::Num(o_clients as f64));
    overload.insert("capacity".to_string(), Json::Num(rep.capacity as f64));
    overload.insert("offered_ratio".to_string(), Json::Num(rep.offered_ratio));
    overload.insert("offered".to_string(), Json::Num(rep.offered as f64));
    overload.insert("accepted".to_string(), Json::Num(rep.accepted as f64));
    overload.insert("shed".to_string(), Json::Num(rep.shed as f64));
    overload.insert("shed_rate".to_string(), Json::Num(rep.shed_rate()));
    overload.insert("accepted_p50_ms".to_string(), Json::num_or_null(o_sum.p50_ms));
    overload.insert("accepted_p95_ms".to_string(), Json::num_or_null(o_sum.p95_ms));
    overload.insert("accepted_p99_ms".to_string(), Json::num_or_null(o_sum.p99_ms));
    overload.insert("peak_depth".to_string(), Json::Num(rep.peak_depth as f64));
    overload.insert("wall_s".to_string(), Json::Num(o_wall));
    gate.insert("overload_peak_depth".to_string(), Json::Num(rep.peak_depth as f64));
    gate.insert("overload_shed_rate".to_string(), Json::Num(rep.shed_rate()));
    gate.insert("overload_p99_ms".to_string(), Json::num_or_null(o_sum.p99_ms));

    // ---- panel 4: atomic hot-swap under live traffic --------------------
    let replacement = serve_model(&mut rng, 0.25);
    let h_engine = Engine::new(
        model.clone(),
        EngineOpts { max_batch: 8, workers: 4, queue_depth: 1024 },
    )?;
    let h_requests = 512usize;
    let mut swap_ms: Vec<f64> = Vec::new();
    let h_lat: Vec<f64> = std::thread::scope(|s| -> anyhow::Result<Vec<f64>> {
        let engine_ref = &h_engine;
        let traffic = s.spawn(move || drive_synthetic(engine_ref, h_requests, 8, 0x5A4B));
        // alternate the two same-shape models while the traffic flows;
        // each swap is one validate + one Arc swap
        let variants = [&replacement, &model];
        for variant in variants.iter().cycle().take(8) {
            std::thread::sleep(std::time::Duration::from_millis(2));
            let sw = Stopwatch::start();
            h_engine.swap_model(BsrModel::clone(*variant))?;
            swap_ms.push(sw.elapsed_secs() * 1e3);
        }
        traffic.join().expect("hot-swap traffic thread panicked")
    })?;
    let swaps = swap_ms.len();
    let swap_mean = swap_ms.iter().sum::<f64>() / swaps.max(1) as f64;
    let swap_max = swap_ms.iter().fold(0.0f64, |a, &b| a.max(b));
    assert_eq!(h_lat.len(), h_requests, "a request was dropped across a hot-swap");
    println!(
        "hotswap: {swaps} swaps under {h_requests} live requests — \
         swap {swap_mean:.3} ms mean / {swap_max:.3} ms max, 0 dropped \
         (generation {})",
        h_engine.generation()
    );
    let mut hotswap = BTreeMap::new();
    hotswap.insert("swaps".to_string(), Json::Num(swaps as f64));
    hotswap.insert("swap_ms_mean".to_string(), Json::num_or_null(swap_mean));
    hotswap.insert("swap_ms_max".to_string(), Json::num_or_null(swap_max));
    hotswap.insert("requests".to_string(), Json::Num(h_requests as f64));
    hotswap.insert("requests_ok".to_string(), Json::Num(h_lat.len() as f64));
    hotswap.insert("generation".to_string(), Json::Num(h_engine.generation() as f64));
    gate.insert("hotswap_swaps".to_string(), Json::Num(swaps as f64));
    gate.insert(
        "hotswap_dropped".to_string(),
        Json::Num((h_requests - h_lat.len()) as f64),
    );

    // panels 3/4 engines are done — drop them so the async panel's
    // process thread count measures only its own engine
    drop(o_engine);
    drop(h_engine);

    // ---- panel 5: completion-slot async path ----------------------------
    // equal in-flight load: 16 blocking client threads vs one driver
    // thread holding 16 handles, same engine sizing, same request count
    let (a_requests, a_window) = (512usize, 16usize);
    let b_engine = Engine::new(
        model.clone(),
        EngineOpts { max_batch: 8, workers: 4, queue_depth: 1024 },
    )?;
    let sw = Stopwatch::start();
    let b_lat = drive_synthetic(&b_engine, a_requests, a_window, 0xA11)?;
    let b_wall = sw.elapsed_secs();
    let b_sum = latency_summary(&b_lat);
    drop(b_engine);
    let a_engine = Engine::new(
        model.clone(),
        EngineOpts { max_batch: 8, workers: 4, queue_depth: 1024 },
    )?;
    let sw = Stopwatch::start();
    let a_rep = drive_async(&a_engine, a_requests, a_window, 0xA11)?;
    let a_wall = sw.elapsed_secs();
    let a_sum = latency_summary(&a_rep.accepted_lat_ms);
    assert_eq!(a_rep.shed, 0, "equal-load async run must not shed (bound 1024)");
    assert_eq!(a_rep.accepted, a_requests, "async run lost a request");
    drop(a_engine);
    // 4×-overload through one driver thread: same load shape as panel 3's
    // 56 client threads, at zero extra threads — record the process
    // thread count mid-drive conditions to prove it
    let ao_engine = Engine::new(
        model.clone(),
        EngineOpts { max_batch: o_batch, workers: o_workers, queue_depth: o_depth },
    )?;
    let ao_window = 4 * ao_engine.capacity();
    let ao_rep = drive_async(&ao_engine, 32 * ao_engine.capacity(), ao_window, 0xA12)?;
    let ao_threads = proc_thread_count();
    assert_eq!(ao_rep.accepted + ao_rep.shed, ao_rep.offered, "async requests unaccounted");
    drop(ao_engine);
    let p99_ratio = a_sum.p99_ms / b_sum.p99_ms;
    println!(
        "async: {} requests, window {a_window} — p99 {:.3} ms vs blocking {:.3} ms \
         ({p99_ratio:.2}x), {:.0} vs {:.0} req/s; 4x-overload window {ao_window}: \
         {:.1}% shed, {} process threads",
        a_rep.offered,
        a_sum.p99_ms,
        b_sum.p99_ms,
        a_rep.accepted as f64 / a_wall.max(1e-9),
        a_requests as f64 / b_wall.max(1e-9),
        100.0 * ao_rep.shed_rate(),
        ao_threads.map(|t| t.to_string()).unwrap_or_else(|| "?".to_string()),
    );
    let mut async_panel = BTreeMap::new();
    async_panel.insert("requests".to_string(), Json::Num(a_requests as f64));
    async_panel.insert("window".to_string(), Json::Num(a_window as f64));
    async_panel.insert("async_p50_ms".to_string(), Json::num_or_null(a_sum.p50_ms));
    async_panel.insert("async_p99_ms".to_string(), Json::num_or_null(a_sum.p99_ms));
    async_panel.insert("blocking_p50_ms".to_string(), Json::num_or_null(b_sum.p50_ms));
    async_panel.insert("blocking_p99_ms".to_string(), Json::num_or_null(b_sum.p99_ms));
    async_panel.insert(
        "async_throughput_rps".to_string(),
        Json::Num(a_rep.accepted as f64 / a_wall.max(1e-9)),
    );
    async_panel.insert(
        "blocking_throughput_rps".to_string(),
        Json::Num(a_requests as f64 / b_wall.max(1e-9)),
    );
    async_panel.insert("overload_window".to_string(), Json::Num(ao_window as f64));
    async_panel.insert("overload_offered".to_string(), Json::Num(ao_rep.offered as f64));
    async_panel.insert("overload_accepted".to_string(), Json::Num(ao_rep.accepted as f64));
    async_panel.insert("overload_shed_rate".to_string(), Json::Num(ao_rep.shed_rate()));
    async_panel.insert(
        "overload_threads".to_string(),
        ao_threads.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
    );
    gate.insert("async_p99_ratio".to_string(), Json::num_or_null(p99_ratio));
    gate.insert(
        "async_overload_threads".to_string(),
        ao_threads.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
    );

    // ---- panel 6: int8-quantized BSR ------------------------------------
    let qmodel = quant::quantize_model(&model)?;
    let f32_fc1 = &model.layers[0]; // 304×784, 8×16 blocks, 75% sparse
    let q_fc1 = quant::quantize_layer(f32_fc1);
    // fidelity before timing: full-stack logits, f32 vs int8
    let xm = rand_vec(&mut rng, 64 * 784);
    let zf = bsr::model_forward(&model, &xm, 64)?;
    let zq = quant::model_forward_q8(&qmodel, &xm, 64)?;
    let mae = zf.iter().zip(&zq).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        / zf.len() as f64;
    let rms = (zf.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / zf.len() as f64)
        .sqrt();
    let mae_bound = 0.05 * rms + 1e-3;
    assert!(
        mae <= mae_bound,
        "int8 logits drifted: MAE {mae:.5} > bound {mae_bound:.5} (rms {rms:.4})"
    );
    let xq = rand_vec(&mut rng, nb * 784);
    let f32_t = quick_bench("infer.bsr_f32.sp75", || {
        std::hint::black_box(bsr::bsr_forward(&xq, nb, f32_fc1).expect("f32 shapes"));
    });
    let q8_t = quick_bench("infer.bsr_int8.sp75", || {
        std::hint::black_box(quant::q8_forward(&xq, nb, &q_fc1).expect("q8 shapes"));
    });
    let int8_speedup = f32_t.mean_ns / q8_t.mean_ns;
    // the ≥1.5× claim is about the SIMD int8 microkernels; a scalar host
    // has no vector int8 path to beat f32 with, so the gate is recorded
    // as waived there instead of failing the bench
    let int8_waived = simd::dispatched().label() == "scalar";
    println!(
        "int8: {int8_speedup:.2}x f32 BSR at 75% block sparsity \
         (f32 {:.3} ms, int8 {:.3} ms), logit MAE {mae:.5} ≤ {mae_bound:.5}{}",
        f32_t.mean_ns / 1e6,
        q8_t.mean_ns / 1e6,
        if int8_waived { " [speedup gate waived: scalar SIMD]" } else { "" },
    );
    let mut int8 = BTreeMap::new();
    int8.insert("f32".to_string(), stat_obj(&f32_t));
    int8.insert("int8".to_string(), stat_obj(&q8_t));
    int8.insert("speedup".to_string(), Json::Num(int8_speedup));
    int8.insert("logit_mae".to_string(), Json::num_or_null(mae));
    int8.insert("logit_rms".to_string(), Json::num_or_null(rms));
    int8.insert("mae_bound".to_string(), Json::num_or_null(mae_bound));
    int8.insert("waived".to_string(), Json::Bool(int8_waived));
    gate.insert("int8_speedup".to_string(), Json::Num(int8_speedup));
    gate.insert("int8_logit_mae".to_string(), Json::num_or_null(mae));
    gate.insert("int8_mae_bound".to_string(), Json::num_or_null(mae_bound));
    gate.insert("int8_gate_waived".to_string(), Json::Bool(int8_waived));

    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str("native-cpu".to_string()));
    root.insert(
        "simd".to_string(),
        Json::Str(simd::dispatched().label().to_string()),
    );
    root.insert("kernels".to_string(), Json::Obj(kernels));
    root.insert("serve".to_string(), Json::Obj(serve));
    root.insert("overload".to_string(), Json::Obj(overload));
    root.insert("hotswap".to_string(), Json::Obj(hotswap));
    root.insert("async".to_string(), Json::Obj(async_panel));
    root.insert("int8".to_string(), Json::Obj(int8));
    root.insert("gate".to_string(), Json::Obj(gate));
    // this bench always writes its JSON — an absent flag means the default
    let path = json_arg(&args, "BENCH_infer.json")
        .unwrap_or_else(|| "BENCH_infer.json".to_string());
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("wrote {path}");
    Ok(())
}
