//! Inference serving bench — the train→export→serve payoff, measured.
//! Emits `BENCH_infer.json` (default; `--json <path>` overrides).
//!
//! Two panels, both fully native (never SKIP):
//!
//! 1. **kernels** — dense `matmul_nt` vs masked `block_sparse_matmul_nt`
//!    vs packed BSR forward on the Table-2 fc1 shape (304×784, 8×16
//!    blocks) at 50% / 75% / 90% block sparsity, with an in-bench
//!    correctness cross-check. Gate: BSR ≥ 2× the dense path at 75%
//!    block sparsity (the flops model predicts 4×).
//! 2. **serving** — the batched engine on a 784→304→100→10 BSR stack at
//!    75% block sparsity: per-request p50/p95/p99 latency and throughput
//!    across (micro-batch cap, client count) operating points.

use std::collections::BTreeMap;

use blocksparse::backend::native::{linalg, simd};
use blocksparse::bench::{json_arg, quick_bench, BenchStats, TableWriter};
use blocksparse::infer::engine::{drive_synthetic, latency_summary, Engine, EngineOpts};
use blocksparse::infer::{bsr, synth_block_sparse_weights, BsrLayer, BsrModel};
use blocksparse::util::json::Json;
use blocksparse::util::rng::Rng;
use blocksparse::util::Stopwatch;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

fn stat_obj(s: &BenchStats) -> Json {
    let mut o = BTreeMap::new();
    o.insert("mean_ms".to_string(), Json::Num(s.mean_ns / 1e6));
    o.insert("p50_ms".to_string(), Json::Num(s.p50_ns / 1e6));
    o.insert("p95_ms".to_string(), Json::Num(s.p95_ns / 1e6));
    o.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(o)
}

/// The Table-2 16x8_8x4_4x2 stack shape as a synthetic BSR model at one
/// occupancy level per layer.
fn serve_model(rng: &mut Rng, occupancy: f64) -> BsrModel {
    let shapes: [(&str, usize, usize, usize, usize); 3] =
        [("fc1", 304, 784, 8, 16), ("fc2", 100, 304, 4, 8), ("fc3", 10, 100, 2, 4)];
    let layers = shapes
        .iter()
        .map(|&(name, m, n, m2, n2)| {
            let (w, _) = synth_block_sparse_weights(rng, m, n, m2, n2, occupancy);
            BsrLayer::from_dense(name, &w, m, n, m2, n2).expect("serve model layer")
        })
        .collect();
    BsrModel {
        spec: "t2_16x8_8x4_4x2(synthetic)".to_string(),
        method: "kpd".to_string(),
        in_dim: 784,
        out_dim: 10,
        layers,
    }
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut rng = Rng::new(0x1F5E);

    // ---- panel 1: kernel speedups across sparsity levels ----------------
    let (nb, m, n, m2, n2) = (128usize, 304usize, 784usize, 8usize, 16usize);
    let x = rand_vec(&mut rng, nb * n);
    let mut kernels = BTreeMap::new();
    let mut gate = BTreeMap::new();
    let mut table = TableWriter::new(
        "BSR inference kernels — 128×(304×784), 8×16 blocks",
        &["sparsity", "dense ms", "block-sparse ms", "BSR ms", "BSR speedup"],
    );
    for sparsity in [0.50f64, 0.75, 0.90] {
        let (w, mask) = synth_block_sparse_weights(&mut rng, m, n, m2, n2, 1.0 - sparsity);
        let layer = BsrLayer::from_dense("fc", &w, m, n, m2, n2)?;
        // correctness cross-check before timing anything
        let dense_z = linalg::matmul_nt(&x, &w, nb, n, m);
        let masked_z = linalg::block_sparse_matmul_nt(&x, &w, &mask, nb, m, n, m2, n2)?;
        let bsr_z = bsr::bsr_forward(&x, nb, &layer)?;
        // tolerance covers f32 re-association over the 784-wide reduction
        assert!(max_diff(&dense_z, &masked_z) < 1e-2, "block-sparse kernel drifted");
        assert!(max_diff(&dense_z, &bsr_z) < 1e-2, "BSR kernel drifted");

        let tag = format!("sp{}", (sparsity * 100.0).round() as u32);
        let dense = quick_bench(&format!("infer.dense.{tag}"), || {
            std::hint::black_box(linalg::matmul_nt(&x, &w, nb, n, m));
        });
        let bsm = quick_bench(&format!("infer.block_sparse.{tag}"), || {
            std::hint::black_box(
                linalg::block_sparse_matmul_nt(&x, &w, &mask, nb, m, n, m2, n2)
                    .expect("block-sparse shapes"),
            );
        });
        let bsr_s = quick_bench(&format!("infer.bsr.{tag}"), || {
            std::hint::black_box(bsr::bsr_forward(&x, nb, &layer).expect("bsr shapes"));
        });
        let speedup = dense.mean_ns / bsr_s.mean_ns;
        println!(
            "BSR speedup at {:.0}% block sparsity: {speedup:.2}x dense \
             (flops model predicts {:.1}x)",
            sparsity * 100.0,
            1.0 / (1.0 - sparsity)
        );
        table.row(vec![
            format!("{:.0}%", sparsity * 100.0),
            format!("{:.3}", dense.mean_ns / 1e6),
            format!("{:.3}", bsm.mean_ns / 1e6),
            format!("{:.3}", bsr_s.mean_ns / 1e6),
            format!("{speedup:.2}x"),
        ]);
        let mut o = BTreeMap::new();
        o.insert("dense".to_string(), stat_obj(&dense));
        o.insert("block_sparse".to_string(), stat_obj(&bsm));
        o.insert("bsr".to_string(), stat_obj(&bsr_s));
        o.insert("bsr_speedup".to_string(), Json::Num(speedup));
        o.insert("occupancy".to_string(), Json::Num(1.0 - sparsity));
        kernels.insert(tag.clone(), Json::Obj(o));
        gate.insert(format!("bsr_speedup_{tag}"), Json::Num(speedup));
    }
    table.print();

    // ---- panel 2: batched serving latency/throughput --------------------
    let model = serve_model(&mut rng, 0.25); // 75% block sparsity
    println!(
        "serving {}: {} stored params, {} FLOPs/example ({:.1}% block sparsity)",
        model.spec,
        model.nnz_params(),
        model.infer_flops_per_example(),
        100.0 * model.block_sparsity()
    );
    let mut serve = BTreeMap::new();
    let mut stable = TableWriter::new(
        "batched BSR serving — 784→304→100→10 @ 75% block sparsity",
        &["max_batch", "clients", "requests", "p50 ms", "p95 ms", "p99 ms", "req/s"],
    );
    for &(max_batch, clients, requests) in &[(1usize, 1usize, 256usize), (8, 4, 512), (32, 16, 1024)]
    {
        let engine = Engine::new(
            model.clone(),
            EngineOpts { max_batch, workers: 4 },
        )?;
        let sw = Stopwatch::start();
        let lat_ms = drive_synthetic(&engine, requests, clients, 0xBEE)?;
        let wall = sw.elapsed_secs();
        let summary = latency_summary(&lat_ms);
        let rps = summary.count as f64 / wall.max(1e-9);
        stable.row(vec![
            max_batch.to_string(),
            clients.to_string(),
            summary.count.to_string(),
            format!("{:.3}", summary.p50_ms),
            format!("{:.3}", summary.p95_ms),
            format!("{:.3}", summary.p99_ms),
            format!("{rps:.0}"),
        ]);
        let mut o = BTreeMap::new();
        o.insert("max_batch".to_string(), Json::Num(max_batch as f64));
        o.insert("clients".to_string(), Json::Num(clients as f64));
        o.insert("requests".to_string(), Json::Num(summary.count as f64));
        // num_or_null: an empty sample summarizes to NaN fields, and RFC
        // 8259 JSON has no NaN literal — nulls keep the file parseable
        o.insert("mean_ms".to_string(), Json::num_or_null(summary.mean_ms));
        o.insert("p50_ms".to_string(), Json::num_or_null(summary.p50_ms));
        o.insert("p95_ms".to_string(), Json::num_or_null(summary.p95_ms));
        o.insert("p99_ms".to_string(), Json::num_or_null(summary.p99_ms));
        o.insert("max_ms".to_string(), Json::num_or_null(summary.max_ms));
        o.insert("throughput_rps".to_string(), Json::Num(rps));
        serve.insert(format!("b{max_batch}_c{clients}"), Json::Obj(o));
    }
    stable.print();

    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str("native-cpu".to_string()));
    root.insert(
        "simd".to_string(),
        Json::Str(simd::dispatched().label().to_string()),
    );
    root.insert("kernels".to_string(), Json::Obj(kernels));
    root.insert("serve".to_string(), Json::Obj(serve));
    root.insert("gate".to_string(), Json::Obj(gate));
    // this bench always writes its JSON — an absent flag means the default
    let path = json_arg(&args, "BENCH_infer.json")
        .unwrap_or_else(|| "BENCH_infer.json".to_string());
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("wrote {path}");
    Ok(())
}
