//! Regenerates **Figure 3** (a/b/c): pattern selection via Eq. 7.
//!
//! For each model we jointly train K block-size candidates with the
//! staircase λ ramp (the paper's +0.002 every 5 epochs) and print the
//! per-pattern Σ‖S^{(k)}‖₁ series — the quantity Figure 3 plots. The
//! figure's claim: exactly one pattern survives the ramp, and it matches
//! the individually-best-accuracy pattern.
//!
//! Run one panel: `cargo bench --bench fig3_pattern_selection -- linear`

use blocksparse::backend::Backend;
use blocksparse::bench::driver::BenchEnv;
use blocksparse::config::TrainConfig;
use blocksparse::coordinator::{self, probe, Trainer};

fn run_panel(be: &dyn Backend, spec_key: &str, steps: usize) -> anyhow::Result<()> {
    let env = BenchEnv::from_env(steps, 1, 6144, 1024);
    let spec = be.spec(spec_key)?.clone();
    let k = spec.num_patterns().unwrap();
    // env.config picks the backend-appropriate λ schedule: the native
    // gauge calibration (backend::native::pattern::LAMBDA_CALIBRATION)
    // on the native backend, the paper's λ1 = λ2 = 0.01 (+0.002 per ramp
    // period) for AOT/PJRT executables training the original objective.
    let cfg: TrainConfig = env.config(be, spec_key)?;

    let (train, test) = coordinator::dataset_for(&spec, cfg.data_seed,
                                                 cfg.train_examples, cfg.test_examples)?;
    let trainer = Trainer::new(be, &cfg);
    let outcome = trainer.run(0, &train, &test)?;

    println!("\n== Figure 3 panel: {spec_key} ({k} patterns, {} steps) ==", cfg.steps);
    let series: Vec<Vec<(u64, f64)>> =
        (0..k).map(|p| outcome.history.series(&format!("s_l1_p{p}"))).collect();
    println!("{:<8} {}", "step",
             (0..k).map(|p| format!("{:>10}", format!("S^({p})"))).collect::<String>());
    let stride = (cfg.steps / 25).max(1);
    for i in (0..series[0].len()).step_by(stride) {
        print!("{:<8}", series[0][i].0);
        for s in &series {
            print!("{:>10.3}", s[i].1);
        }
        println!();
    }
    let finals = probe::pattern_s_norms(&spec, &outcome.state)?;
    // patterns have different S sizes, so survival is measured by norm
    // RETENTION (final / measured initial) — the paper's Figure-3 curves
    // read the same way once normalized per pattern
    let retention =
        probe::pattern_retention_measured(&spec, &outcome.state, &outcome.history)?;
    let survivor = probe::pattern_survivor(&retention);
    println!("final ‖S^(k)‖₁: {:?}",
             finals.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("retention (final/initial): {:?}",
             retention.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    println!("per-pattern accuracy: {:?}",
             outcome.pattern_accs.iter().map(|v| (v * 100.0).round() / 100.0)
                 .collect::<Vec<_>>());
    println!("surviving pattern (max retention): k={survivor} (paper: the \
              surviving pattern matches the best individually-trained one)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    // Pattern-selection specs need the AOT artifacts; skip absent panels
    // so the bench stays green on the native backend.
    let panel = |name: &str, spec: &str, steps: usize| -> anyhow::Result<()> {
        if which != name && which != "all" {
            return Ok(());
        }
        if be.spec(spec).is_err() {
            println!("SKIP {spec}: not available on backend '{}'", be.name());
            return Ok(());
        }
        run_panel(be.as_ref(), spec, steps)
    };
    panel("linear", "f3a_pattern", 1200)?; // Fig 3a
    panel("lenet", "f3b_pattern", 400)?; // Fig 3b
    panel("vit", "f3c_pattern", 250)?; // Fig 3c
    Ok(())
}
