//! Regenerates **Table 2**: per-layer block sizes on a multi-layer MNIST
//! network, five block-size combos × {group LASSO, elastic GL, blockwise
//! RigL, Ours} + iterative pruning + dense.
//!
//! Paper rows use LeNet-5's three FC layers. The default (native) backend
//! runs the `t2_*` specs on its multi-layer stand-in — a 784→304→100→10
//! MLP (LeNet-300-100 shape, first hidden width rounded 300→304 so the
//! coarsest combo's 8-row blocks tile) with the same per-layer block
//! combos and KPD rank 5 (clamped per slot by the Eq. 2 bound where the
//! block is small). A `--features pjrt` build with AOT artifacts runs the
//! real LeNet-5 graphs instead; either way every row reports whole-model
//! sparsity plus the per-layer breakdown underneath the table.

use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

const COMBOS: &[(&str, &str)] = &[
    ("16x8_8x4_4x2", "(16,8)(8,4)(4,2)"),
    ("8x4_4x4_2x2", "(8,4)(4,4)(2,2)"),
    ("4x4_4x4_2x2", "(4,4)(4,4)(2,2)"),
    ("4x4_2x2_2x2", "(4,4)(2,2)(2,2)"),
    ("2x2_2x2_2x2", "(2,2)(2,2)(2,2)"),
];

const PAPER_KPD: &[&str] = &["98.55 ± 0.56", "99.06 ± 0.52", "99.08 ± 0.53",
                             "99.08 ± 0.68", "98.66 ± 0.59"];
const PAPER_GL: &[&str] = &["98.31 ± 0.54", "97.96 ± 0.51", "98.08 ± 0.60",
                            "98.08 ± 0.53", "98.27 ± 0.73"];

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    // MLP steps are ~5-40 ms: keep the default sweep moderate
    let env = BenchEnv::from_env(250, 2, 6144, 1024);
    let mut table = TableWriter::new(
        "Table 2 — multi-layer MNIST network (paper: Table 2, LeNet-5)",
        &ROW_HEADERS,
    );
    let mut breakdowns: Vec<(String, String)> = Vec::new();

    for (i, (key, label)) in COMBOS.iter().enumerate() {
        for method in ["gl", "egl", "rigl", "kpd"] {
            let spec = format!("t2_{method}_{key}");
            let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, &spec)? else {
                continue;
            };
            driver::record_row("table2", label, &res)?;
            let paper = match method {
                "kpd" => Some(PAPER_KPD[i]),
                "gl" => Some(PAPER_GL[i]),
                _ => None,
            };
            table.row(driver::cells(label, &res.method, &res, paper));
            if let Some(b) = driver::layer_breakdown(&res) {
                breakdowns.push((spec, b));
            }
        }
    }
    for spec in ["t2_prune", "t2_dense"] {
        let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, spec)? else {
            continue;
        };
        driver::record_row("table2", "-", &res)?;
        let paper = if res.method == "iter_prune" { Some("98.02 ± 0.82") } else { None };
        table.row(driver::cells("-", &res.method, &res, paper));
        if let Some(b) = driver::layer_breakdown(&res) {
            breakdowns.push((spec.to_string(), b));
        }
    }
    table.print();
    if !breakdowns.is_empty() {
        println!("per-layer sparsity:");
        for (spec, b) in &breakdowns {
            println!("  {spec:<22} {b}");
        }
    }
    println!("rows emitted: {}", table.rows.len());
    println!("shape checks:");
    println!("  - Ours params shrink with block coarseness: ~18K at (16,8)(8,4)(4,2)");
    println!("    vs ~270K dense (paper col 5 direction)");
    println!("  - Ours FLOPs < baselines at the coarse-block combos (paper col 6)");
    Ok(())
}
