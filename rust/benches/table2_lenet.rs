//! Regenerates **Table 2**: LeNet-5 on (synthetic) MNIST with per-layer
//! block sizes for the three FC layers.
//!
//! Paper rows: five block-size combos × {group LASSO, elastic GL,
//! blockwise RigL, Ours} + iterative pruning. The KPD rank is 5 (clamped
//! per-slot by the Eq. 2 bound where the block is small).

use blocksparse::bench::driver::{self, BenchEnv, ROW_HEADERS};
use blocksparse::bench::TableWriter;

const COMBOS: &[(&str, &str)] = &[
    ("16x8_8x4_4x2", "(16,8)(8,4)(4,2)"),
    ("8x4_4x4_2x2", "(8,4)(4,4)(2,2)"),
    ("4x4_4x4_2x2", "(4,4)(4,4)(2,2)"),
    ("4x4_2x2_2x2", "(4,4)(2,2)(2,2)"),
    ("2x2_2x2_2x2", "(2,2)(2,2)(2,2)"),
];

const PAPER_KPD: &[&str] = &["98.55 ± 0.56", "99.06 ± 0.52", "99.08 ± 0.53",
                             "99.08 ± 0.68", "98.66 ± 0.59"];
const PAPER_GL: &[&str] = &["98.31 ± 0.54", "97.96 ± 0.51", "98.08 ± 0.60",
                            "98.08 ± 0.53", "98.27 ± 0.73"];

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let be = blocksparse::backend::open_default()?;
    // LeNet steps are ~30-70 ms: keep the default sweep moderate
    let env = BenchEnv::from_env(250, 2, 6144, 1024);
    let mut table = TableWriter::new(
        "Table 2 — LeNet-5 on synthetic-MNIST (paper: Table 2)",
        &ROW_HEADERS,
    );

    for (i, (key, label)) in COMBOS.iter().enumerate() {
        for method in ["gl", "egl", "rigl", "kpd"] {
            let spec = format!("t2_{method}_{key}");
            let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, &spec)? else {
                continue; // LeNet specs need the AOT artifacts (pjrt build)
            };
            driver::record_row("table2", label, &res)?;
            let paper = match method {
                "kpd" => Some(PAPER_KPD[i]),
                "gl" => Some(PAPER_GL[i]),
                _ => None,
            };
            table.row(driver::cells(label, &res.method, &res, paper));
        }
    }
    for spec in ["t2_prune", "t2_dense"] {
        let Some(res) = driver::run_row_or_skip(be.as_ref(), &env, spec)? else {
            continue;
        };
        driver::record_row("table2", "-", &res)?;
        let paper = if res.method == "iter_prune" { Some("98.02 ± 0.82") } else { None };
        table.row(driver::cells("-", &res.method, &res, paper));
    }
    table.print();
    println!("shape checks:");
    println!("  - Ours params 6-23K vs 61K dense across combos (paper col 5)");
    println!("  - Ours FLOPs < baselines at every combo (paper col 6)");
    Ok(())
}
