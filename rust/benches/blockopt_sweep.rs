//! Hardware-in-the-loop block-size search bench — the blockopt v2 payoff,
//! measured. Emits `BENCH_blockopt.json` (default; `--json <path>`
//! overrides).
//!
//! One end-to-end pass over the Figure-3a candidate grid:
//!
//! 1. **calibrate** — time the BSR forward across the spec's candidate
//!    block shapes × occupancies and fit the per-shape cost model.
//! 2. **sweep** — one short joint `pattern_kpd` training run measures
//!    retention / accuracy / S occupancy per candidate; the cost model
//!    prices each; the (retention ↑, predicted latency ↓) Pareto front
//!    comes out, with the unconstrained pick (= the Figure-3 survivor)
//!    and the pick under a tight budget (the cheapest front member's
//!    predicted latency).
//! 3. **verify** — the budgeted pick and the most expensive front member
//!    are *re-measured* on the real kernels at their measured
//!    occupancies. Gate: whenever the model predicts the budgeted pick is
//!    ≥ 1.3× faster than the worst front member, the measured timings
//!    must confirm ≥ 1.3× — the cost model's ordering claims have to
//!    survive contact with the hardware.
//!
//! Scale knobs: BS_STEPS / BS_TRAIN_N / BS_TEST_N (see bench::driver).

use std::collections::BTreeMap;

use blocksparse::backend::native::simd;
use blocksparse::bench::driver::BenchEnv;
use blocksparse::bench::json_arg;
use blocksparse::blockopt::cost::{self, CostModel};
use blocksparse::blockopt::sweep::{self, Measured, SweepOutcome};
use blocksparse::coordinator::probe;
use blocksparse::infer::{bsr, synth_block_sparse_weights, BsrLayer};
use blocksparse::util::json::Json;
use blocksparse::util::rng::Rng;

const SPEC: &str = "f3a_pattern";
const BATCH: usize = 32;
/// the gate threshold: predicted ordering gaps at least this wide must
/// reproduce on the hardware
const SPEEDUP_GATE: f64 = 1.3;

/// Re-measure one candidate's slot stack on the real BSR kernels at its
/// measured occupancy: summed p50 across slots, in ms.
fn measure_stack_p50_ms(m: &Measured, nb: usize, rng: &mut Rng) -> anyhow::Result<f64> {
    let mut total_ns = 0.0;
    for &(sm, sn, m2, n2) in &m.slots {
        let (w, _) = synth_block_sparse_weights(rng, sm, sn, m2, n2, m.occupancy);
        let layer = BsrLayer::from_dense("slot", &w, sm, sn, m2, n2)?;
        let x: Vec<f32> = (0..nb * sn).map(|_| rng.normal()).collect();
        total_ns += bsr::time_layer(&x, nb, &layer)?.p50_ns;
    }
    Ok(total_ns / 1e6)
}

fn candidate_json(out: &SweepOutcome) -> Json {
    let mut arr = Vec::with_capacity(out.candidates.len());
    for c in &out.candidates {
        let mut o = BTreeMap::new();
        o.insert("pattern".to_string(), Json::Num(c.pattern as f64));
        o.insert("block".to_string(), Json::Str(format!("{}x{}", c.m2, c.n2)));
        o.insert("rank".to_string(), Json::Num(c.rank as f64));
        o.insert("retention".to_string(), Json::num_or_null(c.retention));
        o.insert("accuracy".to_string(), Json::num_or_null(c.accuracy));
        o.insert("occupancy".to_string(), Json::num_or_null(c.occupancy));
        o.insert("pred_latency_ms".to_string(), Json::num_or_null(c.pred_latency_ms));
        arr.push(Json::Obj(o));
    }
    Json::Arr(arr)
}

fn front_json(out: &SweepOutcome) -> Json {
    let mut arr = Vec::with_capacity(out.front.len());
    for p in &out.front {
        let mut o = BTreeMap::new();
        o.insert("index".to_string(), Json::Num(p.index as f64));
        o.insert("retention".to_string(), Json::num_or_null(p.retention));
        o.insert("latency_ms".to_string(), Json::num_or_null(p.latency_ms));
        arr.push(Json::Obj(o));
    }
    Json::Arr(arr)
}

fn pick_json(m: &Measured, pred_ms: f64, measured_p50_ms: Option<f64>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("index".to_string(), Json::Num(m.pattern as f64));
    o.insert("block".to_string(), Json::Str(format!("{}x{}", m.m2, m.n2)));
    o.insert("occupancy".to_string(), Json::num_or_null(m.occupancy));
    o.insert("pred_latency_ms".to_string(), Json::num_or_null(pred_ms));
    o.insert(
        "measured_p50_ms".to_string(),
        measured_p50_ms.map(Json::num_or_null).unwrap_or(Json::Null),
    );
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let be = blocksparse::backend::open_default()?;
    if be.spec(SPEC).is_err() {
        println!("SKIP {SPEC}: not available on backend '{}'", be.name());
        return Ok(());
    }
    let env = BenchEnv::from_env(240, 1, 6144, 1024);
    let cfg = env.config(be.as_ref(), SPEC)?;
    let spec = be.spec(SPEC)?.clone();
    let nb = BATCH;

    // ---- phase 1: calibrate the cost model on this host -----------------
    let shapes = sweep::candidate_shapes(&spec)?;
    let model: CostModel = cost::calibrate(&shapes, &cost::DEFAULT_OCCUPANCIES, nb)?;
    println!(
        "calibrated {} candidate shapes on simd '{}' (batch {nb}):",
        model.entries.len(),
        model.simd
    );
    let mut calib = BTreeMap::new();
    for e in model.entries.values() {
        println!("  {:>2}x{:<3} a = {:.4} ns/MAC  c = {:.0} ns", e.m2, e.n2, e.a_ns, e.c_ns);
        let mut o = BTreeMap::new();
        o.insert("a_ns".to_string(), Json::num_or_null(e.a_ns));
        o.insert("c_ns".to_string(), Json::num_or_null(e.c_ns));
        o.insert("points".to_string(), Json::Num(e.points.len() as f64));
        calib.insert(cost::shape_key(e.m2, e.n2), Json::Obj(o));
    }

    // ---- phase 2: one training pass, scored twice -----------------------
    let measured = sweep::measure_candidates(be.as_ref(), &cfg)?;
    let unconstrained = sweep::score(&measured, &model, nb, None)?;
    // the tight budget: only the cheapest front member fits
    let budget_ms = unconstrained.front[0].latency_ms;
    let budgeted = sweep::score(&measured, &model, nb, Some(budget_ms))?;

    println!(
        "\n== block-size sweep: {SPEC} ({} candidates, {} steps) ==",
        unconstrained.candidates.len(),
        cfg.steps
    );
    for c in &unconstrained.candidates {
        let on_front = unconstrained.front.iter().any(|p| p.index == c.pattern);
        println!(
            "  k={} {:>2}x{:<3} retention {:.3}  acc {:.2}%  occupancy {:.3}  pred {:.4} ms{}",
            c.pattern,
            c.m2,
            c.n2,
            c.retention,
            c.accuracy,
            c.occupancy,
            c.pred_latency_ms,
            if on_front { "  [front]" } else { "" }
        );
    }
    println!("figure-3 survivor (max retention): k={}", unconstrained.survivor);
    println!("unconstrained recommendation: k={}", unconstrained.recommended);
    println!(
        "budgeted recommendation ({budget_ms:.4} ms): k={}",
        budgeted.recommended
    );
    let rets: Vec<f64> = unconstrained.candidates.iter().map(|c| c.retention).collect();
    let lats: Vec<f64> =
        unconstrained.candidates.iter().map(|c| c.pred_latency_ms).collect();
    let blend = probe::pattern_survivor_cost_aware(&rets, &lats, 0.5)?;
    let cost_aware = unconstrained.candidates[blend].pattern;
    println!("cost-aware survivor (alpha=0.5): k={cost_aware}");

    // ---- phase 3: re-measure the picks on the real kernels --------------
    let by_pattern = |idx: usize| -> &Measured {
        measured.iter().find(|m| m.pattern == idx).expect("scored candidate exists")
    };
    let pred_of = |idx: usize| -> f64 {
        unconstrained
            .candidates
            .iter()
            .find(|c| c.pattern == idx)
            .map(|c| c.pred_latency_ms)
            .expect("scored candidate exists")
    };
    let rec = by_pattern(budgeted.recommended);
    let worst_point = *unconstrained.front.last().expect("front is non-empty");
    let worst = by_pattern(worst_point.index);
    let mut rng = Rng::new(0x5EEB);
    let (rec_ms, worst_ms, measured_speedup) = if rec.pattern == worst.pattern {
        let ms = measure_stack_p50_ms(rec, nb, &mut rng)?;
        (Some(ms), Some(ms), None)
    } else {
        let rec_ms = measure_stack_p50_ms(rec, nb, &mut rng)?;
        let worst_ms = measure_stack_p50_ms(worst, nb, &mut rng)?;
        let speedup = worst_ms / rec_ms.max(1e-12);
        (Some(rec_ms), Some(worst_ms), Some(speedup))
    };
    let predicted_speedup = worst_point.latency_ms / pred_of(rec.pattern).max(1e-12);
    println!(
        "budgeted pick {}x{} measured p50 {:.4} ms; worst front member {}x{} \
         measured p50 {:.4} ms (predicted {predicted_speedup:.2}x apart)",
        rec.m2,
        rec.n2,
        rec_ms.unwrap_or(f64::NAN),
        worst.m2,
        worst.n2,
        worst_ms.unwrap_or(f64::NAN)
    );
    if let Some(s) = measured_speedup {
        println!("measured speedup (worst front / budgeted pick): {s:.2}x");
    }

    // ---- the gate -------------------------------------------------------
    let front_len = unconstrained.front.len();
    let recommended_on_front =
        unconstrained.front.iter().any(|p| p.index == budgeted.recommended);
    let max_ret = rets.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // value comparison, not index: argmax tie-breaking must not fail this
    let unc_ret = unconstrained
        .candidates
        .iter()
        .find(|c| c.pattern == unconstrained.recommended)
        .map(|c| c.retention)
        .unwrap_or(f64::NEG_INFINITY);
    let retention_ok = unc_ret >= max_ret - 1e-12;
    // the model's ordering claim only binds when it predicts a gap at
    // least as wide as the gate threshold
    let pass = recommended_on_front
        && retention_ok
        && (front_len < 2
            || predicted_speedup < SPEEDUP_GATE
            || measured_speedup.map(|s| s >= SPEEDUP_GATE).unwrap_or(false));
    println!(
        "gate: front_len={front_len} recommended_on_front={recommended_on_front} \
         retention_ok={retention_ok} predicted_speedup={predicted_speedup:.2} \
         measured_speedup={measured_speedup:?} -> pass={pass}"
    );

    let mut gate = BTreeMap::new();
    gate.insert("front_len".to_string(), Json::Num(front_len as f64));
    gate.insert("recommended_on_front".to_string(), Json::Bool(recommended_on_front));
    gate.insert(
        "unconstrained_matches_survivor".to_string(),
        Json::Bool(retention_ok),
    );
    gate.insert("retention_ok".to_string(), Json::Bool(retention_ok));
    gate.insert("speedup_gate".to_string(), Json::Num(SPEEDUP_GATE));
    gate.insert("predicted_speedup".to_string(), Json::num_or_null(predicted_speedup));
    gate.insert(
        "measured_speedup".to_string(),
        measured_speedup.map(Json::num_or_null).unwrap_or(Json::Null),
    );
    gate.insert("pass".to_string(), Json::Bool(pass));

    let mut unc = BTreeMap::new();
    unc.insert(
        "recommended_index".to_string(),
        Json::Num(unconstrained.recommended as f64),
    );
    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str(be.name()));
    root.insert("simd".to_string(), Json::Str(simd::dispatched().label().to_string()));
    root.insert("spec".to_string(), Json::Str(SPEC.to_string()));
    root.insert("batch".to_string(), Json::Num(nb as f64));
    root.insert("steps".to_string(), Json::Num(cfg.steps as f64));
    root.insert("calibration".to_string(), Json::Obj(calib));
    root.insert("candidates".to_string(), candidate_json(&unconstrained));
    root.insert("front".to_string(), front_json(&unconstrained));
    root.insert("survivor_index".to_string(), Json::Num(unconstrained.survivor as f64));
    root.insert("cost_aware_survivor".to_string(), Json::Num(cost_aware as f64));
    root.insert("unconstrained".to_string(), Json::Obj(unc));
    root.insert("budget_ms".to_string(), Json::num_or_null(budget_ms));
    root.insert(
        "recommended".to_string(),
        pick_json(rec, pred_of(rec.pattern), rec_ms),
    );
    root.insert(
        "worst_front".to_string(),
        pick_json(worst, worst_point.latency_ms, worst_ms),
    );
    root.insert("gate".to_string(), Json::Obj(gate));

    let path = json_arg(&args, "BENCH_blockopt.json")
        .unwrap_or_else(|| "BENCH_blockopt.json".to_string());
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!(
        "recommended block size: k={} ({}x{}) predicted {:.3} ms",
        budgeted.recommended,
        rec.m2,
        rec.n2,
        pred_of(rec.pattern)
    );
    println!("wrote {path}");
    if !pass {
        anyhow::bail!("blockopt sweep gate failed (see BENCH_blockopt.json gate object)");
    }
    Ok(())
}
