//! Performance microbenches for the §Perf pass: per-layer hot paths.
//!
//!  - runtime.step.*      PJRT execute latency per model family (L3 view)
//!  - runtime.overhead    no-op-sized executable round-trip (framework tax)
//!  - data.batch.*        batch assembly throughput (host pipeline)
//!  - tensor.*            host-side measurement ops (sparsity probes)
//!  - infer.block_sparse  materialized block-sparse inference vs dense
//!    (the §4 inference claim, via the flops model + host matmul)

use blocksparse::bench::{quick_bench, TableWriter};
use blocksparse::coordinator::dataset_for;
use blocksparse::data::{assemble_batch, Batcher};
use blocksparse::runtime::Runtime;
use blocksparse::tensor::Tensor;
use blocksparse::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    blocksparse::util::log::set_level(blocksparse::util::log::Level::Warn);
    let rt = Runtime::new(blocksparse::artifact_dir())?;
    let mut stats = Vec::new();

    // ---- L3 runtime: one train step per model family --------------------
    for spec_key in ["t1_kpd_b2x2", "t1_gl_b2x2", "t2_kpd_16x8_8x4_4x2",
                     "t3_vit_t_kpd", "it_lm_kpd"] {
        let spec = rt.spec(spec_key)?.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let mut state = rt.init_state(spec_key, 0)?;
        let hyper: Vec<f32> = spec.hyper.iter().map(|h| match h.as_str() {
            "lr" => 0.05,
            _ => 0.01,
        }).collect();
        stats.push(quick_bench(&format!("runtime.step.{spec_key}"), || {
            rt.train_step(&mut state, &batch.x, &batch.y, &hyper).expect("step");
        }));
    }

    // ---- framework overhead: smallest executable we have ----------------
    {
        let spec = rt.spec("qs_kpd")?.clone();
        let (train, _) = dataset_for(&spec, 7, spec.batch * 2, spec.batch)?;
        let idx: Vec<usize> = (0..spec.batch).collect();
        let batch = assemble_batch(&train, &idx)?;
        let state = rt.init_state("qs_kpd", 0)?;
        stats.push(quick_bench("runtime.overhead.eval_qs", || {
            rt.eval_step(&state, &batch.x, &batch.y).expect("eval");
        }));
    }

    // ---- data pipeline ---------------------------------------------------
    {
        let spec = rt.spec("t1_kpd_b2x2")?.clone();
        let (train, _) = dataset_for(&spec, 7, 8192, 128)?;
        let mut b = Batcher::new(&train, 128, 1, true);
        stats.push(quick_bench("data.batch.mnist128", || {
            let _ = b.next_batch().expect("batch");
        }));
    }

    // ---- host tensor probes ----------------------------------------------
    {
        let mut rng = Rng::new(3);
        let w = Tensor::from_fn(&[120, 400], |_| rng.normal());
        stats.push(quick_bench("tensor.block_fro_120x400", || {
            std::hint::black_box(w.block_fro_norms(8, 16).unwrap());
        }));
        let s = Tensor::from_fn(&[15, 25], |_| rng.normal());
        let a = Tensor::from_fn(&[5, 15, 25], |_| rng.normal());
        let bt = Tensor::from_fn(&[5, 8, 16], |_| rng.normal());
        stats.push(quick_bench("tensor.kpd_reconstruct_120x400_r5", || {
            std::hint::black_box(Tensor::kpd_reconstruct(&s, &a, &bt).unwrap());
        }));
    }

    // ---- inference: block-sparse vs dense host matmul ---------------------
    {
        let mut rng = Rng::new(4);
        let m = 120;
        let n = 400;
        let dense = Tensor::from_fn(&[m, n], |_| rng.normal());
        // 50% block-sparse copy (8x16 blocks)
        let mut sp = dense.clone();
        for bi in 0..(m / 8) {
            for bj in 0..(n / 16) {
                if (bi + bj) % 2 == 0 {
                    for i in 0..8 {
                        for j in 0..16 {
                            sp.set2(bi * 8 + i, bj * 16 + j, 0.0);
                        }
                    }
                }
            }
        }
        let x = Tensor::from_fn(&[n, 64], |_| rng.normal());
        let d = quick_bench("infer.dense_120x400x64", || {
            std::hint::black_box(dense.matmul(&x).unwrap());
        });
        let s = quick_bench("infer.block_sparse50_120x400x64", || {
            std::hint::black_box(sp.matmul(&x).unwrap());
        });
        println!("block-sparse/dense inference speedup: {:.2}x (flops model predicts ~2x at 50%)",
                 d.mean_ns / s.mean_ns);
        stats.push(d);
        stats.push(s);
    }

    let mut t = TableWriter::new("perf microbenches", &["bench", "mean ms", "p50 ms", "p95 ms", "/s"]);
    for s in &stats {
        t.row(vec![
            s.name.clone(),
            format!("{:.3}", s.mean_ns / 1e6),
            format!("{:.3}", s.p50_ns / 1e6),
            format!("{:.3}", s.p95_ns / 1e6),
            format!("{:.1}", s.throughput_per_sec()),
        ]);
    }
    t.print();
    Ok(())
}
